"""grid_eval pallas kernel vs oracle + parametric scorer consistency."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import distributions as dist
from compile import model
from compile.kernels.grid_eval import mmde_cdf_grid, mmde_cdf_ref

SETTINGS = hypothesis.settings(max_examples=20, deadline=None)


@SETTINGS
@hypothesis.given(
    r=st.integers(1, 8),
    m=st.integers(1, 4),
    g=st.sampled_from([256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle(r, m, g, seed):
    rng = np.random.default_rng(seed)
    w = rng.dirichlet(np.ones(m), size=r).astype(np.float32)
    lam = (0.5 + 5 * rng.random((r, m))).astype(np.float32)
    d = rng.random((r, m)).astype(np.float32)
    t = jnp.arange(g, dtype=jnp.float32) * 0.02
    out = mmde_cdf_grid(jnp.asarray(w), jnp.asarray(lam), jnp.asarray(d), t)
    ref = mmde_cdf_ref(t, jnp.asarray(w), jnp.asarray(lam), jnp.asarray(d))
    np.testing.assert_allclose(out, ref, atol=1e-6)


def test_oracle_matches_distributions_module():
    """mmde_cdf_ref == DelayedTail mixtures from distributions.py."""
    t = jnp.arange(512, dtype=jnp.float32) * 0.02
    mm = dist.MultiModal(
        [dist.delayed_exponential(4.0, T=0.3), dist.delayed_exponential(1.0, T=1.0)],
        [0.7, 0.3],
    )
    w = jnp.asarray([[0.7, 0.3]], jnp.float32)
    lam = jnp.asarray([[4.0, 1.0]], jnp.float32)
    d = jnp.asarray([[0.3, 1.0]], jnp.float32)
    got = mmde_cdf_ref(t, w, lam, d)[0]
    want = mm.cdf(t)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_padding_modes_are_inert():
    """Zero-weight modes must not change the law (the rust packer pads)."""
    t = jnp.arange(256, dtype=jnp.float32) * 0.05
    one = mmde_cdf_ref(
        t,
        jnp.asarray([[1.0]], jnp.float32),
        jnp.asarray([[2.0]], jnp.float32),
        jnp.asarray([[0.1]], jnp.float32),
    )
    padded = mmde_cdf_ref(
        t,
        jnp.asarray([[1.0, 0.0, 0.0, 0.0]], jnp.float32),
        jnp.asarray([[2.0, 1.0, 1.0, 1.0]], jnp.float32),
        jnp.asarray([[0.1, 0.0, 0.0, 0.0]], jnp.float32),
    )
    np.testing.assert_allclose(one, padded, atol=1e-7)


def test_parametric_scorer_matches_grid_scorer():
    """score_fig6_mmde(params) == score_fig6_fast(grids built host-side)."""
    G, B, dt = 1024, 2, 0.02
    rng = np.random.default_rng(1)
    lam = (2.0 + 6.0 * rng.random((B, 6, 1))).astype(np.float32)
    w = np.ones((B, 6, 1), np.float32)
    delay = np.zeros((B, 6, 1), np.float32)

    s_param, tot_param = model.score_fig6_mmde(
        jnp.asarray(w), jnp.asarray(lam), jnp.asarray(delay), jnp.float32(dt), G=G
    )

    # host-built grids for the same laws
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdf = jnp.stack(
        [jnp.stack([dist.exp_pdf(t, float(lam[b, s, 0])) for s in range(6)]) for b in range(B)]
    )
    cdf = jnp.stack(
        [jnp.stack([dist.exp_cdf(t, float(lam[b, s, 0])) for s in range(6)]) for b in range(B)]
    )
    s_grid, tot_grid = model.score_fig6_fast(pdf, cdf, jnp.float32(dt))
    # the parametric path derives slot PDFs by central differences while
    # the grid path gets exact PDFs: mean/var track to <0.1%, the p99
    # quantile crosses a flat CDF region (a few grid cells of wobble)
    np.testing.assert_allclose(s_param[:, :2], s_grid[:, :2], rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(s_param[:, 2], s_grid[:, 2], atol=5 * dt)
    np.testing.assert_allclose(tot_param, tot_grid, rtol=1e-2, atol=5e-3)
