"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/seeds; fixed tests pin the paper's closed forms
(Eq. 2 hypoexponential, Eq. 4 max-of-exponentials).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.cdfprod import cdf_from_pdf, cdf_product, parallel_compose, pdf_from_cdf
from compile.kernels.conv import conv_pdf, conv_pdf_fft, serial_compose, toeplitz_diags
from compile.kernels import ref
from compile import distributions as dist

SETTINGS = hypothesis.settings(max_examples=25, deadline=None)


def _rand_pdf(rng, b, g):
    """Random positive grids (not normalized — conv is bilinear, so
    correctness on arbitrary positive vectors covers PDFs)."""
    return jnp.asarray(rng.random((b, g)) + 0.01, jnp.float32)


# ------------------------------------------------------------------ conv


@SETTINGS
@hypothesis.given(
    g=st.sampled_from([128, 256, 384, 512]),
    b=st.integers(1, 4),
    tile=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(g, b, tile, seed):
    if g % tile != 0:
        hypothesis.assume(False)
    rng = np.random.default_rng(seed)
    f, h = _rand_pdf(rng, b, g), _rand_pdf(rng, b, g)
    dt = jnp.float32(0.05)
    out = conv_pdf(f, h, dt, tile=tile)
    want = jnp.stack([ref.conv_pdf_ref(f[i], h[i], dt) for i in range(b)])
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@SETTINGS
@hypothesis.given(
    g=st.sampled_from([128, 256, 512]),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_fft_matches_pallas(g, b, seed):
    """The CPU-optimized FFT lowering must be numerically interchangeable
    with the pallas Toeplitz-matmul kernel (same *_fast artifact contract)."""
    rng = np.random.default_rng(seed)
    f, h = _rand_pdf(rng, b, g), _rand_pdf(rng, b, g)
    dt = jnp.float32(0.03)
    a = conv_pdf(f, h, dt)
    c = conv_pdf_fft(f, h, dt)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_conv_commutative():
    rng = np.random.default_rng(1)
    f, h = _rand_pdf(rng, 2, 256), _rand_pdf(rng, 2, 256)
    dt = jnp.float32(0.02)
    np.testing.assert_allclose(
        conv_pdf(f, h, dt), conv_pdf(h, f, dt), rtol=1e-4, atol=1e-6
    )


def test_conv_preserves_mass():
    """Mass of f*g equals mass(f)*mass(g) up to grid truncation: the
    composed distribution of two PDFs is a PDF (trapezoid convention)."""
    G, dt = 2048, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    f = dist.exp_pdf(t, 3.0)[None]
    g = dist.exp_pdf(t, 5.0)[None]
    out = conv_pdf(f, g, jnp.float32(dt))
    mass = float(jnp.sum(out) * dt - dt * (out[0, 0] + out[0, -1]) / 2)
    assert abs(mass - 1.0) < 5e-3, mass


def test_conv_1d_entrypoint():
    rng = np.random.default_rng(3)
    f, h = _rand_pdf(rng, 1, 128)[0], _rand_pdf(rng, 1, 128)[0]
    dt = jnp.float32(0.1)
    np.testing.assert_allclose(
        conv_pdf(f, h, dt), ref.conv_pdf_ref(f, h, dt), rtol=1e-4, atol=1e-5
    )


def test_toeplitz_structure():
    """T[d, a, b] must equal g[d*tile + b - a] (0 when negative index)."""
    g = jnp.arange(1.0, 257.0, dtype=jnp.float32)
    T = toeplitz_diags(g, 64)
    gnp = np.asarray(g)
    Tnp = np.asarray(T)
    for d in range(4):
        for a in range(0, 64, 17):
            for b in range(0, 64, 13):
                k = d * 64 + b - a
                want = gnp[k] if k >= 0 else 0.0
                assert Tnp[d, a, b] == want, (d, a, b)


def test_conv_hypoexp_eq2():
    """Paper Eq. 2: Exp(l1) * Exp(l2) = hypoexponential."""
    G, dt = 2048, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    l1, l2 = 2.0, 5.0
    f1 = dist.exp_pdf(t, l1)[None]
    f2 = dist.exp_pdf(t, l2)[None]
    out_cdf = cdf_from_pdf(conv_pdf(f1, f2, jnp.float32(dt))[0], dt)
    want = dist.hypoexp2_cdf(t, l1, l2)
    np.testing.assert_allclose(out_cdf, want, atol=0.02)


@SETTINGS
@hypothesis.given(
    n=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_serial_compose_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    G, dt = 256, 0.05
    pdfs = jnp.asarray(rng.random((n, G)) * 0.2, jnp.float32)
    out = serial_compose(pdfs, jnp.float32(dt))
    want = ref.serial_compose_ref(pdfs, dt)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-5)


# ------------------------------------------------------------------ cdfprod


@SETTINGS
@hypothesis.given(
    g=st.sampled_from([256, 512, 1024]),
    n=st.integers(2, 6),
    b=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_cdf_product_matches_ref(g, n, b, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(np.sort(rng.random((b, n, g)), axis=-1), jnp.float32)
    out = cdf_product(c)
    want = jnp.stack([ref.cdf_product_ref(c[i]) for i in range(b)])
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-7)


def test_max_exp2_eq4():
    """Paper Eq. 4: CDF of max(Exp(l1), Exp(l2)) = F1*F2."""
    G, dt = 1024, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    l1, l2 = 3.0, 7.0
    cdfs = jnp.stack([dist.exp_cdf(t, l1), dist.exp_cdf(t, l2)])[None]
    out = cdf_product(cdfs)[0]
    want = dist.max_exp2_cdf(t, l1, l2)
    np.testing.assert_allclose(out, want, atol=1e-6)


def test_cdf_monotone_after_product():
    rng = np.random.default_rng(7)
    c = jnp.asarray(np.sort(rng.random((1, 4, 512)), axis=-1), jnp.float32)
    out = np.asarray(cdf_product(c))[0]
    assert np.all(np.diff(out) >= -1e-6)


def test_pdf_from_cdf_roundtrip():
    """pdf->cdf->pdf is near-identity for a smooth density."""
    G, dt = 1024, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdf = dist.erlang_pdf(t, 4, 2.0)
    cdf = cdf_from_pdf(pdf, dt)
    back = pdf_from_cdf(cdf, jnp.float32(dt))
    # central differences smear one cell; compare away from the edges
    np.testing.assert_allclose(back[2:-2], pdf[2:-2], atol=0.05)


def test_parallel_compose_pair():
    G, dt = 512, 0.02
    t = jnp.arange(G, dtype=jnp.float32) * dt
    cdfs = jnp.stack([dist.exp_cdf(t, 2.0), dist.exp_cdf(t, 4.0)])[None]
    cdf, pdf = parallel_compose(cdfs, jnp.float32(dt))
    np.testing.assert_allclose(cdf[0], dist.max_exp2_cdf(t, 2.0, 4.0), atol=1e-6)
    # pdf integrates to ~the captured mass
    assert abs(float(jnp.sum(pdf[0]) * dt) - float(cdf[0, -1])) < 0.05


# ------------------------------------------------------------- moments/score


def test_moments_erlang():
    """Erlang(n, lam): mean n/lam, var n/lam^2 — grid moments must agree."""
    G, dt = 4096, 0.005
    t = jnp.arange(G, dtype=jnp.float32) * dt
    n, lam = 5, 2.0
    pdf = dist.erlang_pdf(t, n, lam)
    mean, var = ref.moments_ref(pdf, dt)
    assert abs(float(mean) - n / lam) < 0.01
    assert abs(float(var) - n / lam**2) < 0.02


def test_quantile_exponential():
    G, dt = 4096, 0.005
    t = jnp.arange(G, dtype=jnp.float32) * dt
    lam = 1.0
    pdf = dist.exp_pdf(t, lam)
    p99 = float(ref.quantile_ref(pdf, dt, 0.99))
    assert abs(p99 - (-np.log(0.01) / lam)) < 0.05
