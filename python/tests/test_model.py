"""L2 model tests: workflow composition vs oracles and closed forms."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import distributions as dist
from compile import model
from compile.kernels import ref
from compile.kernels.cdfprod import cdf_from_pdf

SETTINGS = hypothesis.settings(max_examples=15, deadline=None)


def _grids_for(servers, G, dt):
    """Per-server service PDFs/CDFs on the grid for exp rates `servers`."""
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdf = jnp.stack([dist.exp_pdf(t, mu) for mu in servers])
    cdf = jnp.stack([dist.exp_cdf(t, mu) for mu in servers])
    return pdf[None], cdf[None]  # B = 1


def _fig6_ref(pdf, cdf, dt):
    """Pure-jnp Fig.6 composition (no pallas): the L2 oracle."""
    p0 = ref.pdf_from_cdf_ref(ref.cdf_product_ref(cdf[model.FIG6_PARALLEL_0, :]), dt)
    p1 = ref.serial_compose_ref(pdf[model.FIG6_SERIAL_1, :], dt)
    p2 = ref.pdf_from_cdf_ref(ref.cdf_product_ref(cdf[model.FIG6_PARALLEL_2, :]), dt)
    return ref.conv_pdf_ref(ref.conv_pdf_ref(p0, p1, dt), p2, dt)


def test_fig6_total_matches_ref():
    G, dt = 1024, 0.02
    rates = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0]
    pdf, cdf = _grids_for(rates, G, dt)
    total = model.fig6_total_pdf(pdf, cdf, jnp.float32(dt))[0]
    want = _fig6_ref(pdf[0], cdf[0], dt)
    np.testing.assert_allclose(total, want, rtol=1e-3, atol=1e-4)


@SETTINGS
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_fig6_scorer_batch_consistency(seed):
    """Every batch row must score exactly like a singleton evaluation."""
    rng = np.random.default_rng(seed)
    G, dt, B = 512, 0.02, 3
    rates = 2.0 + 8.0 * rng.random((B, 6))
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdf = jnp.stack([jnp.stack([dist.exp_pdf(t, m) for m in row]) for row in rates])
    cdf = jnp.stack([jnp.stack([dist.exp_cdf(t, m) for m in row]) for row in rates])
    scores, total = model.score_fig6(pdf, cdf, jnp.float32(dt))
    for b in range(B):
        s1, t1 = model.score_fig6(pdf[b : b + 1], cdf[b : b + 1], jnp.float32(dt))
        np.testing.assert_allclose(scores[b], s1[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(total[b], t1[0], rtol=1e-4, atol=1e-5)


def test_fig6_mean_bounds():
    """End-to-end mean must exceed the slowest single stage's mean and be
    below the sum of all six means (series of 4 effective stages)."""
    G, dt = 2048, 0.01
    rates = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0]
    pdf, cdf = _grids_for(rates, G, dt)
    scores, _ = model.score_fig6(pdf, cdf, jnp.float32(dt))
    mean = float(scores[0, 0])
    assert mean > max(1.0 / r for r in rates)
    assert mean < sum(1.0 / r for r in rates)


def test_serial_block_erlang():
    """SDCC of n iid Exp(lam) must match the Erlang closed form."""
    G, dt, n, lam = 2048, 0.01, 4, 2.0
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdfs = jnp.stack([dist.exp_pdf(t, lam)] * n)[None]
    out = model.serial_block(pdfs, jnp.float32(dt))[0]
    want = dist.erlang_pdf(t, n, lam)
    np.testing.assert_allclose(out, want, atol=0.02)


def test_parallel_block_mean_grows_with_n():
    """Fig. 3 effect: mean of max grows (logarithmically) with fan-out."""
    G, dt = 2048, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    means = []
    for n in (2, 8, 32):
        cdfs = jnp.stack([dist.exp_cdf(t, 1.0)] * n)[None]
        pdfs = jnp.stack([dist.exp_pdf(t, 1.0)] * n)[None]
        p, _ = model.parallel_block(pdfs, cdfs, jnp.float32(dt))
        mean, _ = model.moments(p[0], jnp.float32(dt))
        means.append(float(mean))
    assert means[0] < means[1] < means[2]
    # harmonic-number growth: H_32 ~ 4.06, H_2 = 1.5
    assert means[2] > 2.0 * means[0] * 0.8


def test_score_pdf_triple():
    G, dt = 2048, 0.01
    t = jnp.arange(G, dtype=jnp.float32) * dt
    pdf = dist.exp_pdf(t, 2.0)
    s = model.score_pdf(pdf, jnp.float32(dt))
    assert abs(float(s[0]) - 0.5) < 0.01          # mean 1/2
    assert abs(float(s[1]) - 0.25) < 0.01         # var 1/4
    assert abs(float(s[2]) - (-np.log(0.01) / 2.0)) < 0.05  # p99


def test_delayed_families_compose():
    """Table-1 families flow through the same composition machinery."""
    G, dt = 1024, 0.02
    t = jnp.arange(G, dtype=jnp.float32) * dt
    de = dist.delayed_exponential(3.0, T=0.5)
    dp = dist.delayed_pareto(4.0, T=0.3)
    mm = dist.MultiModal([dist.delayed_exponential(5.0, T=0.2),
                          dist.delayed_exponential(1.0, T=2.0)], [0.9, 0.1])
    pdfs = jnp.stack([d.pdf_grid(t) for d in (de, dp, mm)])[None]
    out = model.serial_block(pdfs, jnp.float32(dt))[0]
    mean, var = model.moments(out, jnp.float32(dt))
    # series mean adds; each component mean > its delay T
    assert float(mean) > 0.5 + 0.3 + 0.2
    assert float(var) > 0.0


def test_multimodal_weights_validation():
    import pytest

    with pytest.raises(ValueError):
        dist.MultiModal([dist.delayed_exponential(1.0)], [0.5])
    with pytest.raises(ValueError):
        dist.MultiModal(
            [dist.delayed_exponential(1.0), dist.delayed_exponential(2.0)],
            [1.5, -0.5],
        )


def test_delayed_exp_atom_alpha():
    """alpha < 1 puts an atom of mass (1 - alpha) at T."""
    t = jnp.arange(4096, dtype=jnp.float32) * 0.005
    d = dist.delayed_exponential(2.0, T=1.0, alpha=0.7)
    c = np.asarray(d.cdf(t))
    jump_idx = int(np.searchsorted(np.asarray(t), 1.0)) + 1
    assert abs(c[jump_idx] - 0.3) < 0.02
    assert c[jump_idx - 2] == 0.0
