"""AOT artifact tests: manifest contract + HLO-text executability.

Compiles the emitted HLO text back through xla_client's local CPU client
and checks the numbers against the live-jax evaluation — the same
round-trip the rust runtime performs via the PJRT C API.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile import distributions as dist

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ARTDIR, "manifest.json")
    if not os.path.exists(path):
        aot.lower_all(ARTDIR)
    with open(path) as fh:
        return json.load(fh)


def test_manifest_lists_all_artifacts(manifest):
    assert set(manifest["artifacts"]) == set(aot.ARTIFACTS)
    assert manifest["grid"] == aot.G
    for name, meta in manifest["artifacts"].items():
        assert os.path.exists(os.path.join(ARTDIR, meta["path"])), name
        assert meta["hlo_bytes"] > 0
        assert meta["num_outputs"] >= 1


def test_hlo_text_parseable(manifest):
    """Every artifact must be valid HLO text with an ENTRY computation."""
    for meta in manifest["artifacts"].values():
        text = open(os.path.join(ARTDIR, meta["path"])).read()
        assert "ENTRY" in text
        assert "HloModule" in text


def _parse_hlo(path):
    """Parse HLO text back into an HloModule — the same text parser the
    rust runtime invokes through HloModuleProto::from_text_file. The
    numeric execute-and-compare roundtrip lives in rust
    (rust/tests/integration_runtime.rs), on the actual deployment path."""
    return xc._xla.hlo_module_from_text(open(path).read())


def test_conv_pair_artifact_parses_with_contract(manifest):
    meta = manifest["artifacts"][f"conv_pair_b{aot.B_PAIR}_g{aot.G}"]
    mod = _parse_hlo(os.path.join(ARTDIR, meta["path"]))
    text = mod.to_string()
    # entry signature must carry the manifest shapes
    assert f"f32[{aot.B_PAIR},{aot.G}]" in text
    assert meta["inputs"] == [[aot.B_PAIR, aot.G], [aot.B_PAIR, aot.G], []]
    assert meta["num_outputs"] == 1


def test_score_fig6_artifact_parses_with_contract(manifest):
    meta = manifest["artifacts"][f"score_fig6_b{aot.B_SCORE}_g{aot.G}"]
    mod = _parse_hlo(os.path.join(ARTDIR, meta["path"]))
    text = mod.to_string()
    assert f"f32[{aot.B_SCORE},6,{aot.G}]" in text
    assert f"f32[{aot.B_SCORE},3]" in text  # score triple output
    assert meta["num_outputs"] == 2


def test_live_jax_matches_scorer_semantics(manifest):
    # the jitted fig6 scorer (what was lowered) agrees with the pure-jnp
    # reference composition on random inputs — guards the artifact's
    # semantics without needing a local PJRT execute API
    G, B, dt = 256, 2, 0.02
    t = jnp.arange(G, dtype=jnp.float32) * dt
    rng = np.random.default_rng(0)
    rates = 2.0 + 8.0 * rng.random((B, 6)).astype(np.float32)
    pdf = jnp.stack([jnp.stack([dist.exp_pdf(t, m) for m in row]) for row in rates])
    cdf = jnp.stack([jnp.stack([dist.exp_cdf(t, m) for m in row]) for row in rates])
    scores, total = jax.jit(model.score_fig6)(pdf, cdf, jnp.float32(dt))
    assert scores.shape == (B, 3)
    assert total.shape == (B, G)
    assert bool(jnp.all(scores[:, 0] > 0)) and bool(jnp.all(scores[:, 1] > 0))


def test_lowering_is_deterministic(tmp_path):
    """Same inputs -> byte-identical HLO text (keeps `make artifacts`
    reproducible and the rust-side executable cache coherent)."""
    name = f"score_batch_b{aot.B_SCORE}_g{aot.G}"
    fn, specs, _ = aot.ARTIFACTS[name]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2
