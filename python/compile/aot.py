"""AOT lowering: jax -> stablehlo -> XlaComputation -> **HLO text**.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Emits one .hlo.txt per entry in ARTIFACTS plus manifest.json describing
every artifact's inputs/outputs, consumed by rust/src/runtime/registry.
All functions are lowered with return_tuple=True; the rust side unwraps
with to_tupleN(). Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Canonical grid / batch shapes. G and B are fixed per artifact (PJRT
# executables are monomorphic); rust pads the candidate wavefront to B.
G = 1024
B_SCORE = 64
B_PAIR = 8

F32 = jnp.float32
M_MODES = 4  # mixture modes in the parametric scorer


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _fn_score_fig6(pdf, cdf, dt):
    scores, total = model.score_fig6(pdf, cdf, dt)
    return scores, total


def _fn_score_fig6_fast(pdf, cdf, dt):
    scores, total = model.score_fig6_fast(pdf, cdf, dt)
    return scores, total


def _fn_score_fig6_mmde(w, lam, delay, dt):
    scores, total = model.score_fig6_mmde(w, lam, delay, dt, G=G)
    return scores, total


def _fn_conv_pair(f, g, dt):
    return (model.conv_pair(f, g, dt),)


def _fn_max_pair(cf, cg, dt):
    cdf, pdf = model.max_pair(cf, cg, dt)
    return cdf, pdf


def _fn_score_batch(pdf, dt):
    return (model.score_batch(pdf, dt),)


# name -> (fn, example args, doc). Shapes here are the contract with
# rust/src/runtime — changing them requires regenerating artifacts AND
# keeping runtime/registry.rs constants in sync (manifest.json is the
# single source of truth the rust side actually reads).
ARTIFACTS = {
    f"score_fig6_b{B_SCORE}_g{G}": (
        _fn_score_fig6,
        (_spec(B_SCORE, 6, G), _spec(B_SCORE, 6, G), _spec()),
        "batched Fig.6 allocation scorer: (pdf[B,6,G], cdf[B,6,G], dt) -> (scores[B,3], total_pdf[B,G])",
    ),
    f"score_fig6_fast_b{B_SCORE}_g{G}": (
        _fn_score_fig6_fast,
        (_spec(B_SCORE, 6, G), _spec(B_SCORE, 6, G), _spec()),
        "CPU-optimized Fig.6 scorer (FFT conv instead of the pallas Toeplitz kernel); same contract",
    ),
    f"score_fig6_mmde_b{B_SCORE}_m{M_MODES}_g{G}": (
        _fn_score_fig6_mmde,
        (
            _spec(B_SCORE, 6, M_MODES),
            _spec(B_SCORE, 6, M_MODES),
            _spec(B_SCORE, 6, M_MODES),
            _spec(),
        ),
        "fully-fused parametric Fig.6 scorer: (w[B,6,M], lam[B,6,M], delay[B,6,M], dt) -> (scores[B,3], total_pdf[B,G]); grids built on-device from MMDE mixture params",
    ),
    f"conv_pair_b{B_PAIR}_g{G}": (
        _fn_conv_pair,
        (_spec(B_PAIR, G), _spec(B_PAIR, G), _spec()),
        "serial pair composition: (f[B,G], g[B,G], dt) -> (out[B,G],)",
    ),
    f"max_pair_b{B_PAIR}_g{G}": (
        _fn_max_pair,
        (_spec(B_PAIR, G), _spec(B_PAIR, G), _spec()),
        "parallel pair composition: (cdf_f[B,G], cdf_g[B,G], dt) -> (cdf[B,G], pdf[B,G])",
    ),
    f"score_batch_b{B_SCORE}_g{G}": (
        _fn_score_batch,
        (_spec(B_SCORE, G), _spec()),
        "moment offload: (pdf[B,G], dt) -> (scores[B,3],)",
    ),
}


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"grid": G, "artifacts": {}}
    for name, (fn, specs, doc) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = {
            "path": path,
            "doc": doc,
            "inputs": [list(s.shape) for s in specs],
            "num_outputs": len(lowered.out_info),
            "hlo_bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    print(f"lowering {len(ARTIFACTS)} artifacts to {args.out} (G={G})")
    lower_all(args.out)
    print("AOT done")


if __name__ == "__main__":
    main()
