"""L1 pallas kernel: Table-1 grid evaluation on-device.

Evaluates batched multi-modal delayed-exponential CDFs on a uniform time
grid directly from parameter tensors, so the whole scorer pipeline
(grids -> composition -> moments) can run as one fused artifact without
the host building 6xG grids per candidate:

    cdf[b, s, k] = sum_m w[b,s,m] * (1 - alpha * e^{-lam[b,s,m] (t_k - T[b,s,m])})+

Pure elementwise math over the grid axis -> VPU kernel, tiled like
cdfprod. The exponential clock is the only family lowered on-device
(pareto/weibull laws arrive as host-built grids; their clocks need
transcendentals per *mode* that profile as host-cheap anyway).

alpha is the continuous choice exp(lam*(m(T)-T)) == 1 for the exp clock,
i.e. no atom; mixtures with atoms are host-built.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray

TILE = 256


def mmde_cdf_ref(t: Array, w: Array, lam: Array, delay: Array) -> Array:
    """Oracle: multi-modal delayed-exp CDF.

    t: [G]; w, lam, delay: [..., M] -> cdf [..., G].
    """
    tt = t.reshape((1,) * (w.ndim - 1) + (-1, 1))  # [..., G, 1]
    ww = w[..., None, :]  # [..., 1, M]
    ll = lam[..., None, :]
    dd = delay[..., None, :]
    mode = (1.0 - jnp.exp(-ll * (tt - dd))) * (tt >= dd)
    return jnp.clip(jnp.sum(ww * mode, axis=-1), 0.0, 1.0)


def _grid_kernel(w_ref, lam_ref, d_ref, t_ref, o_ref):
    """One (b*s, grid-tile) step: evaluate the mixture on a grid tile."""
    t = t_ref[...]  # [1, TILE]
    w = w_ref[...]  # [1, M]
    lam = lam_ref[...]
    d = d_ref[...]
    tt = t[0][:, None]  # [TILE, M] broadcast
    mode = (1.0 - jnp.exp(-lam[0][None, :] * (tt - d[0][None, :]))) * (
        tt >= d[0][None, :]
    )
    o_ref[...] = jnp.clip(jnp.sum(w[0][None, :] * mode, axis=-1), 0.0, 1.0)[None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mmde_cdf_grid(
    w: Array, lam: Array, delay: Array, t: Array, *, tile: int = TILE, interpret: bool = True
) -> Array:
    """Batched mixture-CDF grids: ([R,M],[R,M],[R,M],[G]) -> [R,G].

    R collapses any leading batch/slot structure; M = modes; G % tile == 0.
    """
    R, M = w.shape
    G = t.shape[0]
    if G % tile != 0:
        raise ValueError(f"grid size {G} not a multiple of tile {tile}")
    nt = G // tile
    t2 = t[None, :]  # [1, G]

    return pl.pallas_call(
        _grid_kernel,
        grid=(R, nt),
        in_specs=[
            pl.BlockSpec((1, M), lambda r, i: (r, 0)),
            pl.BlockSpec((1, M), lambda r, i: (r, 0)),
            pl.BlockSpec((1, M), lambda r, i: (r, 0)),
            pl.BlockSpec((1, tile), lambda r, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda r, i: (r, i)),
        out_shape=jax.ShapeDtypeStruct((R, G), jnp.float32),
        interpret=interpret,
    )(w, lam, delay, t2)
