"""L1 pallas kernel: parallel (fork-join) composition on the VPU.

Parallel DCC composition (paper Eq. 3) is an elementwise product of the
branch CDFs: F_max(t) = prod_i F_i(t). Pure elementwise work -> VPU, not
MXU; the kernel tiles the grid axis so each step touches one
(N, tile) VMEM block, reducing over the (small, static) branch axis.

The PDF of the composed distribution (needed when the fork-join feeds a
downstream serial stage) is recovered by central differences at L2 —
computing  sum_i f_i * prod_{j!=i} F_j  directly divides by F_i ~ 0 near
the origin and is numerically poor on float32 grids.

interpret=True everywhere (CPU image): numerics only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray

TILE = 256


def _prod_kernel(c_ref, o_ref):
    """One grid step: o_tile = prod over branch axis of cdf block."""
    o_ref[...] = jnp.prod(c_ref[0], axis=0, keepdims=False)[None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def cdf_product(cdfs: Array, *, tile: int = TILE, interpret: bool = True) -> Array:
    """Batched CDF product: [B, N, G] (or [N, G]) -> [B, G] / [G]."""
    if cdfs.ndim == 2:
        return cdf_product(cdfs[None], tile=tile, interpret=interpret)[0]
    B, N, G = cdfs.shape
    if G % tile != 0:
        raise ValueError(f"grid size {G} not a multiple of tile {tile}")
    nt = G // tile

    out = pl.pallas_call(
        _prod_kernel,
        grid=(B, nt),
        in_specs=[pl.BlockSpec((1, N, tile), lambda b, i: (b, 0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, G), jnp.float32),
        interpret=interpret,
    )(cdfs)
    return out


def pdf_from_cdf(cdf: Array, dt: Array) -> Array:
    """Central-difference PDF (matches ref.pdf_from_cdf_ref; L2-level jnp,
    the shift crosses tile boundaries so it stays out of the kernel).
    Interior central over 2dt, edges one-sided over dt (mass-preserving)."""
    interior = (cdf[..., 2:] - cdf[..., :-2]) / (2.0 * dt)
    first = (cdf[..., 1:2] - cdf[..., 0:1]) / dt
    last = (cdf[..., -1:] - cdf[..., -2:-1]) / dt
    return jnp.concatenate([first, interior, last], axis=-1)


def cdf_from_pdf(pdf: Array, dt: Array) -> Array:
    """Trapezoid cumulative integral, clipped to [0, 1]."""
    cs = jnp.cumsum(pdf, axis=-1) * dt
    return jnp.clip(cs - dt * (pdf + pdf[..., :1]) / 2.0, 0.0, 1.0)


def parallel_compose(cdfs: Array, dt: Array, *, tile: int = TILE, interpret: bool = True):
    """Fork-join composition returning (cdf, pdf) of the max."""
    cdf = cdf_product(cdfs, tile=tile, interpret=interpret)
    return cdf, pdf_from_cdf(cdf, dt)
