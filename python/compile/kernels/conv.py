"""L1 pallas kernel: PDF convolution as tiled Toeplitz matmuls.

Serial DCC composition (paper Eq. 1) is a truncated linear convolution

    out[k] = dt * sum_{j<=k} f[j] * g[k-j],   k in [0, G)

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): on TPU the MACs
should land on the MXU, so instead of a scalar/VPU sliding window we
block the output into tiles of TILE and express each (output-tile i,
diagonal d) contribution as a TILE x TILE matmul

    out_tile(i) += f_tile(i-d) @ T_d          for d = 0..i

where T_d[a, b] = g[d*TILE + b - a] (a banded Toeplitz block built once
per g by `toeplitz_diags` — a gather, left to XLA at L2). The kernel
below is then a canonical pallas matmul-accumulate pipeline: grid
(B, i, d) with the output block revisited along the innermost reduction
dimension d.

VMEM per grid step: 3 blocks * TILE*TILE * 4 B = 192 KiB at TILE=128 —
far under the 16 MiB VMEM budget, leaving room for double buffering.
interpret=True everywhere (CPU image): numerics only; see DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray

TILE = 128


def toeplitz_diags(g: Array, tile: int = TILE) -> Array:
    """Build the banded Toeplitz blocks T[d, a, b] = g[d*tile + b - a].

    g: [..., G] PDF grid (G must be a multiple of `tile`).
    Returns [..., D, tile, tile] with D = G // tile. Out-of-range indices
    (b - a < -d*tile) hit the zero padding — they encode the causal
    (j <= k) triangle of the convolution.
    """
    G = g.shape[-1]
    if G % tile != 0:
        raise ValueError(f"grid size {G} not a multiple of tile {tile}")
    nt = G // tile
    zeros = jnp.zeros(g.shape[:-1] + (G,), g.dtype)
    gp = jnp.concatenate([zeros, g], axis=-1)  # gp[..., G+m] = g[..., m]
    d = jnp.arange(nt)[:, None, None]
    a = jnp.arange(tile)[None, :, None]
    b = jnp.arange(tile)[None, None, :]
    idx = G + d * tile + (b - a)  # in [G - tile + 1, 2G - 1]
    return gp[..., idx]


def _conv_kernel(f_ref, t_ref, o_ref):
    """One (i, d) grid step: accumulate f_tile(i-d) @ T_d into out_tile(i)."""
    i = pl.program_id(1)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(d <= i)
    def _acc():
        o_ref[...] += jnp.dot(
            f_ref[...], t_ref[0, 0], preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def conv_pdf(f: Array, g: Array, dt: Array, *, tile: int = TILE, interpret: bool = True) -> Array:
    """Batched truncated PDF convolution: ([B,G], [B,G], scalar) -> [B,G].

    Matches `ref.conv_pdf_ref` per batch row to float32 tolerance.
    """
    if f.ndim == 1:
        return conv_pdf(f[None], g[None], dt, tile=tile, interpret=interpret)[0]
    B, G = f.shape
    nt = G // tile
    diags = toeplitz_diags(g, tile)  # [B, nt, tile, tile]

    out = pl.pallas_call(
        _conv_kernel,
        grid=(B, nt, nt),
        in_specs=[
            # f block (1, tile) at row b, tile max(i-d, 0) (clamped; masked by pl.when)
            pl.BlockSpec((1, tile), lambda b, i, d: (b, jnp.maximum(i - d, 0))),
            # T block (1, 1, tile, tile) at row b, diagonal d
            pl.BlockSpec((1, 1, tile, tile), lambda b, i, d: (b, d, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda b, i, d: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, G), jnp.float32),
        interpret=interpret,
    )(f, diags)
    # Trapezoid endpoint correction (see ref.conv_pdf_ref): elementwise,
    # XLA fuses it into the epilogue.
    return dt * (out - (f[:, :1] * g + f * g[:, :1]) / 2.0)


@functools.partial(jax.jit, static_argnames=())
def conv_pdf_fft(f: Array, g: Array, dt: Array) -> Array:
    """FFT-path truncated PDF convolution: ([..., G], [..., G], dt) -> [..., G].

    Numerically equivalent to `conv_pdf` (same trapezoid endpoint
    correction). This is the **CPU-optimized** lowering used by the
    `*_fast` AOT artifacts: interpret-mode pallas turns into an XLA
    while-loop of dynamic slices on CPU (seconds per call), whereas the
    rfft/irfft pair lowers to XLA's native FFT (sub-millisecond). The
    pallas kernel remains the TPU-shaped artifact (MXU Toeplitz matmul);
    see DESIGN.md §Perf.
    """
    G = f.shape[-1]
    n = 2 * G
    fz = jnp.fft.rfft(f, n=n, axis=-1)
    gz = jnp.fft.rfft(g, n=n, axis=-1)
    full = jnp.fft.irfft(fz * gz, n=n, axis=-1)[..., :G]
    return dt * (full - (f[..., :1] * g + f * g[..., :1]) / 2.0)


def serial_compose(pdfs: Array, dt: Array, *, tile: int = TILE, interpret: bool = True) -> Array:
    """Fold conv_pdf over a stack [N, G] (or [B, N, G]) -> [G] / [B, G].

    N is static (python loop unrolls into the jaxpr) — each workflow
    template is lowered once at AOT time, so this is build-time only.
    """
    batched = pdfs.ndim == 3
    stack = pdfs if batched else pdfs[None]  # [B, N, G]
    out = stack[:, 0, :]
    for i in range(1, stack.shape[1]):
        out = conv_pdf(out, stack[:, i, :], dt, tile=tile, interpret=interpret)
    return out if batched else out[0]
