"""Pure-jnp oracles for the L1 pallas kernels.

These are the CORE correctness signal: every pallas kernel must match its
oracle here to float32 tolerance under pytest (python/tests/), and the
rust native engine reimplements the same math (cross-checked in rust
integration tests through the AOT artifacts).
"""

from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def conv_pdf_ref(f: Array, g: Array, dt: float) -> Array:
    """Serial composition (paper Eq. 1): linear convolution of two PDF
    grids, truncated to the grid length.

    out[k] = dt * ( sum_{j=0..k} f[j] * g[k-j]  -  (f[0]g[k] + f[k]g[0]) / 2 )

    i.e. the *trapezoid* rule for the convolution integral (the endpoint
    correction cuts the error of the plain Riemann sum by ~500x for
    exponential-family PDFs, which jump at their left support edge).
    Truncation to G points assumes the grid was sized to hold the
    composed support (rust sizes t_max accordingly).
    """
    G = f.shape[-1]
    full = jnp.convolve(f, g, mode="full")  # length 2G-1
    return dt * (full[:G] - (f[..., :1] * g + f * g[..., :1]) / 2.0)


def serial_compose_ref(pdfs: Array, dt: float) -> Array:
    """Fold conv_pdf_ref over a stack [N, G] -> [G]."""
    out = pdfs[0]
    for i in range(1, pdfs.shape[0]):
        out = conv_pdf_ref(out, pdfs[i], dt)
    return out


def cdf_product_ref(cdfs: Array) -> Array:
    """Parallel (fork-join) composition (paper Eq. 3): product of CDFs."""
    return jnp.prod(cdfs, axis=0)


def pdf_from_cdf_ref(cdf: Array, dt: float) -> Array:
    """Central-difference PDF of a CDF grid.

    Interior: (c[k+1]-c[k-1])/(2dt); edges one-sided over dt (a /2dt edge
    halves the boundary density and leaks ~f(0)*dt/2 of mass per
    composition). Matches the rust engine (`dist::central_diff`) exactly.
    """
    interior = (cdf[2:] - cdf[:-2]) / (2.0 * dt)
    first = (cdf[1:2] - cdf[0:1]) / dt
    last = (cdf[-1:] - cdf[-2:-1]) / dt
    return jnp.concatenate([first, interior, last])


def cdf_from_pdf_ref(pdf: Array, dt: float) -> Array:
    """Trapezoid cumulative integral, clipped to [0, 1]."""
    cs = jnp.cumsum(pdf) * dt
    return jnp.clip(cs - dt * (pdf + pdf[..., :1]) / 2.0, 0.0, 1.0)


def moments_ref(pdf: Array, dt: float) -> tuple[Array, Array]:
    """(mean, variance) of a PDF grid by Riemann sums.

    Normalizes by the captured mass so that grid truncation does not bias
    the moments of the retained part (rust does the same).
    """
    G = pdf.shape[-1]
    t = jnp.arange(G, dtype=pdf.dtype) * dt
    mass = jnp.sum(pdf) * dt
    mass = jnp.maximum(mass, 1e-12)
    mean = jnp.sum(t * pdf) * dt / mass
    ex2 = jnp.sum(t * t * pdf) * dt / mass
    return mean, ex2 - mean * mean


def quantile_ref(pdf: Array, dt: float, q: float) -> Array:
    """Smallest grid time with CDF >= q."""
    cdf = cdf_from_pdf_ref(pdf, dt)
    idx = jnp.argmax(cdf >= q)
    # if never reached, report the grid end
    idx = jnp.where(cdf[-1] < q, pdf.shape[-1] - 1, idx)
    return idx.astype(pdf.dtype) * dt


def score_ref(pdf: Array, dt: float, q: float = 0.99) -> Array:
    """[mean, var, p_q] — the allocation-scorer output triple."""
    mean, var = moments_ref(pdf, dt)
    return jnp.stack([mean, var, quantile_ref(pdf, dt, q)])
