"""Table-1 service-time distribution families (build-time jnp versions).

The paper (Table 1) models server service times with six delayed-tail
families. These jnp implementations are the *authoring / test* versions:
they generate PDF/CDF grids for the L2 model tests and the pytest oracles.
The production grid generation lives in rust (`rust/src/dist`) — python is
never on the request path.

All CDFs share the shape  F(t) = (1 - alpha * exp(-lam * (m(t) - T))) * U(t - T)
with a monotone "tail clock" m(t):
  * delayed exponential : m(t) = t
  * delayed pareto      : m(t) = ln(t + 1)
  * delayed weibull     : m(t) = t**k   (our generic-m(t) instance)
Multi-modal variants are convex mixtures sum_i p_i F_i.

`alpha` controls the atom at T: F(T+) = 1 - alpha * exp(-lam*(m(T) - T)).
`alpha=None` picks the continuous choice alpha = exp(lam * (m(T) - T)) so
that F(T+) = 0 (no atom).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

Array = jnp.ndarray


def _u(t: Array, T: float) -> Array:
    """Delayed step U(t - T)."""
    return (t >= T).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class DelayedTail:
    """F(t) = (1 - alpha * exp(-lam * (m(t) - T))) * U(t - T)."""

    lam: float
    T: float = 0.0
    alpha: float | None = None  # None => continuous at T
    kind: str = "exp"  # "exp" | "pareto" | "weibull"
    weibull_k: float = 2.0

    def m(self, t: Array) -> Array:
        if self.kind == "exp":
            return t
        if self.kind == "pareto":
            return jnp.log1p(jnp.maximum(t, 0.0))
        if self.kind == "weibull":
            return jnp.maximum(t, 0.0) ** self.weibull_k
        raise ValueError(f"unknown tail kind {self.kind!r}")

    def _alpha(self) -> float:
        if self.alpha is not None:
            return self.alpha
        mT = float(self.m(jnp.asarray(self.T)))
        return float(jnp.exp(self.lam * (mT - self.T)))

    def cdf(self, t: Array) -> Array:
        a = self._alpha()
        val = 1.0 - a * jnp.exp(-self.lam * (self.m(t) - self.T))
        return jnp.clip(val, 0.0, 1.0) * _u(t, self.T)

    def pdf_grid(self, t: Array) -> Array:
        """Numerical PDF on a uniform grid (central differences of cdf).

        Matches how the rust engine and the L1 kernels treat parallel
        compositions, so oracles line up bit-for-bit in method.
        """
        c = self.cdf(t)
        dt = t[1] - t[0]
        interior = (c[2:] - c[:-2]) / (2.0 * dt)
        first = (c[1:2] - c[0:1]) / dt
        last = (c[-1:] - c[-2:-1]) / dt
        return jnp.concatenate([first, interior, last])


def delayed_exponential(lam: float, T: float = 0.0, alpha: float | None = None) -> DelayedTail:
    return DelayedTail(lam=lam, T=T, alpha=alpha, kind="exp")


def delayed_pareto(lam: float, T: float = 0.0, alpha: float | None = None) -> DelayedTail:
    return DelayedTail(lam=lam, T=T, alpha=alpha, kind="pareto")


def delayed_weibull(lam: float, k: float, T: float = 0.0) -> DelayedTail:
    return DelayedTail(lam=lam, T=T, kind="weibull", weibull_k=k)


@dataclasses.dataclass(frozen=True)
class MultiModal:
    """Convex mixture: F(t) = sum_i p_i F_i(t)  (paper's multi-modal rows)."""

    components: Sequence[DelayedTail]
    weights: Sequence[float]

    def __post_init__(self):
        w = jnp.asarray(self.weights)
        if not jnp.allclose(jnp.sum(w), 1.0, atol=1e-6):
            raise ValueError("mixture weights must sum to 1")
        if jnp.any(w < 0):
            raise ValueError("mixture weights must be non-negative")

    def cdf(self, t: Array) -> Array:
        acc = jnp.zeros_like(t)
        for p, c in zip(self.weights, self.components):
            acc = acc + p * c.cdf(t)
        return acc

    def pdf_grid(self, t: Array) -> Array:
        acc = jnp.zeros_like(t)
        for p, c in zip(self.weights, self.components):
            acc = acc + p * c.pdf_grid(t)
        return acc


# ---------------------------------------------------------------- closed forms


def exp_cdf(t: Array, lam: float) -> Array:
    """Plain exponential (delayed exp with T=0, alpha=1)."""
    return (1.0 - jnp.exp(-lam * t)) * _u(t, 0.0)


def exp_pdf(t: Array, lam: float) -> Array:
    return lam * jnp.exp(-lam * t) * _u(t, 0.0)


def erlang_pdf(t: Array, n: int, lam: float) -> Array:
    """Sum of n iid Exp(lam): the closed form behind paper Fig. 2."""
    from jax.scipy.special import gammaln

    logpdf = (
        n * jnp.log(lam)
        + (n - 1) * jnp.log(jnp.maximum(t, 1e-30))
        - lam * t
        - gammaln(float(n))
    )
    return jnp.exp(logpdf) * _u(t, 0.0)


def hypoexp2_cdf(t: Array, lam1: float, lam2: float) -> Array:
    """Paper Eq. (2): CDF of Exp(lam1) + Exp(lam2), lam1 != lam2."""
    c1 = lam2 / (lam2 - lam1)
    c2 = lam1 / (lam2 - lam1)
    return (1.0 - c1 * jnp.exp(-lam1 * t) + c2 * jnp.exp(-lam2 * t)) * _u(t, 0.0)


def max_exp2_cdf(t: Array, lam1: float, lam2: float) -> Array:
    """Paper Eq. (4): CDF of max(Exp(lam1), Exp(lam2))."""
    return (1.0 - jnp.exp(-lam1 * t)) * (1.0 - jnp.exp(-lam2 * t)) * _u(t, 0.0)
