"""L2: jax workflow evaluator — the allocation-scoring compute graph.

This is the paper's "model": given the per-server *response-time* grids
(PDF and CDF, already conditioned on the candidate allocation and DAP
rates by the rust L3), compose the workflow's end-to-end response-time
distribution and its score triple [mean, variance, p99]:

  * serial DCC   -> PDF convolution      (Eq. 1, L1 kernel conv.py)
  * parallel DCC -> CDF product          (Eq. 3, L1 kernel cdfprod.py)

Everything here is build-time: `aot.py` lowers these functions ONCE to
HLO text; the rust coordinator executes the compiled artifacts on its
request path (runtime/scorer.rs). Python is never on the request path.

The Fig. 6 workflow template (the paper's evaluation workflow) is

    DAP0 --> DCC0 = PDCC(slot0 || slot1)      lambda_DAP0 = 8
         --> DAP1 --> DCC1 = SDCC(slot2 ; slot3)   lambda_DAP1 = 4
         --> DAP2 --> DCC2 = PDCC(slot4 || slot5)  lambda_DAP2 = 2
         --> DAP3

(the paper fixes 3 DCCs and 6 offered servers; the 2/2/2 split is the
smallest shape consistent with the figure — see DESIGN.md substitutions).
The scorer is batched over B candidate allocations so that one PJRT
execute scores a whole wavefront of the optimizer's search.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.cdfprod import cdf_from_pdf, cdf_product, pdf_from_cdf
from .kernels.conv import conv_pdf, conv_pdf_fft

Array = jnp.ndarray

# Fig. 6 template: slot indices per DCC.
FIG6_PARALLEL_0 = (0, 1)
FIG6_SERIAL_1 = (2, 3)
FIG6_PARALLEL_2 = (4, 5)
FIG6_SLOTS = 6


def moments(pdf: Array, dt: Array) -> tuple[Array, Array]:
    """Batched (mean, var) of PDF grids [..., G], mass-normalized."""
    G = pdf.shape[-1]
    t = jnp.arange(G, dtype=pdf.dtype) * dt
    mass = jnp.maximum(jnp.sum(pdf, axis=-1) * dt, 1e-12)
    mean = jnp.sum(t * pdf, axis=-1) * dt / mass
    ex2 = jnp.sum(t * t * pdf, axis=-1) * dt / mass
    return mean, ex2 - mean * mean


def quantile(pdf: Array, dt: Array, q: float = 0.99) -> Array:
    """Batched q-quantile (first grid point with CDF >= q)."""
    cdf = cdf_from_pdf(pdf, dt)
    idx = jnp.argmax(cdf >= q, axis=-1)
    idx = jnp.where(cdf[..., -1] < q, pdf.shape[-1] - 1, idx)
    return idx.astype(pdf.dtype) * dt


def score_pdf(pdf: Array, dt: Array, q: float = 0.99) -> Array:
    """[..., G] PDF -> [..., 3] score triple (mean, var, p_q)."""
    mean, var = moments(pdf, dt)
    return jnp.stack([mean, var, quantile(pdf, dt, q)], axis=-1)


def parallel_block(pdfs: Array, cdfs: Array, dt: Array) -> tuple[Array, Array]:
    """PDCC: [B, N, G] branch grids -> (pdf[B, G], cdf[B, G]) of the max."""
    cdf = cdf_product(cdfs)
    return pdf_from_cdf(cdf, dt), cdf


def serial_block(pdfs: Array, dt: Array) -> Array:
    """SDCC: [B, N, G] stage PDFs -> composed PDF [B, G]."""
    out = pdfs[:, 0, :]
    for i in range(1, pdfs.shape[1]):
        out = conv_pdf(out, pdfs[:, i, :], dt)
    return out


def fig6_total_pdf(pdf: Array, cdf: Array, dt: Array) -> Array:
    """End-to-end response-time PDF of the Fig. 6 workflow.

    pdf, cdf: [B, 6, G] per-slot response-time grids.
    """
    p0, _ = parallel_block(pdf[:, FIG6_PARALLEL_0, :], cdf[:, FIG6_PARALLEL_0, :], dt)
    p1 = serial_block(pdf[:, FIG6_SERIAL_1, :], dt)
    p2, _ = parallel_block(pdf[:, FIG6_PARALLEL_2, :], cdf[:, FIG6_PARALLEL_2, :], dt)
    total = conv_pdf(p0, p1, dt)
    total = conv_pdf(total, p2, dt)
    return total


def score_fig6(pdf: Array, cdf: Array, dt: Array) -> tuple[Array, Array]:
    """Batched Fig. 6 scorer: ([B,6,G], [B,6,G], dt) -> ([B,3], [B,G]).

    Returns the score triple per candidate and the total PDF (the latter
    feeds Fig. 7 curves and rust-side cross-checks).
    """
    total = fig6_total_pdf(pdf, cdf, dt)
    return score_pdf(total, dt), total


# ------------------------------------------------------- generic primitives
# Pairwise primitives: the rust engine composes ARBITRARY series-parallel
# topologies by folding these (fixed shapes keep the PJRT executables
# monomorphic; the fig6 scorer above fuses the whole template instead).


def conv_pair(f: Array, g: Array, dt: Array) -> Array:
    """([B,G], [B,G], dt) -> [B,G] serial pair composition."""
    return conv_pdf(f, g, dt)


def max_pair(cf: Array, cg: Array, dt: Array) -> tuple[Array, Array]:
    """([B,G], [B,G]) CDFs -> (cdf[B,G], pdf[B,G]) of the max."""
    cdf = cdf_product(jnp.stack([cf, cg], axis=1))
    return cdf, pdf_from_cdf(cdf, dt)


def score_batch(pdf: Array, dt: Array) -> Array:
    """[B,G] PDFs -> [B,3] score triples (moment offload primitive)."""
    return score_pdf(pdf, dt)


# --------------------------------------------------------- CPU-fast variant
# Same math with the FFT convolution (conv_pdf_fft) instead of the pallas
# kernel: interpret-mode pallas lowers to an XLA while-loop of dynamic
# slices that executes in seconds on CPU; the rfft/irfft pair executes in
# sub-millisecond. The pallas artifact stays the TPU-shaped build; rust
# prefers a `*_fast` artifact when the manifest offers one (§Perf).


def serial_block_fast(pdfs: Array, dt: Array) -> Array:
    """SDCC via FFT conv: [B, N, G] -> [B, G]."""
    out = pdfs[:, 0, :]
    for i in range(1, pdfs.shape[1]):
        out = conv_pdf_fft(out, pdfs[:, i, :], dt)
    return out


def fig6_total_pdf_fast(pdf: Array, cdf: Array, dt: Array) -> Array:
    """End-to-end Fig. 6 PDF, FFT path (matches fig6_total_pdf)."""
    p0, _ = parallel_block(pdf[:, FIG6_PARALLEL_0, :], cdf[:, FIG6_PARALLEL_0, :], dt)
    p1 = serial_block_fast(pdf[:, FIG6_SERIAL_1, :], dt)
    p2, _ = parallel_block(pdf[:, FIG6_PARALLEL_2, :], cdf[:, FIG6_PARALLEL_2, :], dt)
    total = conv_pdf_fft(p0, p1, dt)
    total = conv_pdf_fft(total, p2, dt)
    return total


def score_fig6_fast(pdf: Array, cdf: Array, dt: Array) -> tuple[Array, Array]:
    """Batched Fig. 6 scorer, FFT path: same contract as score_fig6."""
    total = fig6_total_pdf_fast(pdf, cdf, dt)
    return score_pdf(total, dt), total


# ------------------------------------------------------ parametric scorer
# Fully-fused pipeline: the host sends only the per-slot response-law
# PARAMETERS (multi-modal delayed-exponential mixtures — every law our
# rust ResponseModels emit), and the device builds the grids itself.
# Marshalling drops from 2·B·6·G floats to 3·B·6·M (M = 4): ~170x less
# host->device traffic per scoring wave (§Perf iteration 4).


def mmde_grids(w: Array, lam: Array, delay: Array, dt: Array, G: int) -> tuple[Array, Array]:
    """[B,S,M] mixture params -> (pdf[B,S,G], cdf[B,S,G]).

    Modes with w == 0 are padding. Math matches
    `kernels.grid_eval.mmde_cdf_ref` / the rust `ServiceDist` exactly
    (continuous alpha, central-difference PDF with one-sided edges).
    """
    from .kernels.grid_eval import mmde_cdf_ref

    B, S, M = w.shape
    t = jnp.arange(G, dtype=jnp.float32) * dt
    cdf = mmde_cdf_ref(t, w.reshape(B * S, M), lam.reshape(B * S, M), delay.reshape(B * S, M))
    cdf = cdf.reshape(B, S, G)
    pdf = pdf_from_cdf(cdf, dt)
    return pdf, cdf


def score_fig6_mmde(w: Array, lam: Array, delay: Array, dt: Array, G: int = 1024):
    """Parametric Fig. 6 scorer: ([B,6,M]×3, dt) -> ([B,3], [B,G])."""
    pdf, cdf = mmde_grids(w, lam, delay, dt, G)
    total = fig6_total_pdf_fast(pdf, cdf, dt)
    return score_pdf(total, dt), total
