//! Straggler mitigation: detect a multi-modal (straggling) service law
//! online, re-fit it to the Table-1 family, and re-balance — plus the
//! cloning (speculative execution) ablation from the straggler
//! literature the paper cites [6, 7, 16].
//!
//! ```bash
//! cargo run --release --example straggler_mitigation
//! ```

use dcflow::compose::maxcomp::{cloning_compose, parallel_compose};
use dcflow::compose::moments::moments;
use dcflow::prelude::*;
use dcflow::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);

    // A mapper that straggles: 92% fast exp(10), 8% stuck at ~exp(0.4)
    // (the "100x degradation" shape of [6, 7]).
    let truth = ServiceDist::straggler(10.0, 0.4, 0.08, 0.01);
    println!("hidden law: straggler(fast=10, slow=0.4, p=0.08)");
    println!(
        "  true mean={:.4} var={:.4} p99={:.4}\n",
        truth.mean(),
        truth.variance(),
        truth.quantile(0.99)
    );

    // --- 1. online detection ------------------------------------------
    let mut monitor = ServerMonitor::new(4_096);
    let clean = ServiceDist::exponential(10.0);
    for _ in 0..2_000 {
        monitor.observe(clean.sample(&mut rng)); // healthy phase
    }
    for _ in 0..2_000 {
        monitor.observe(truth.sample(&mut rng)); // straggling begins
    }
    let report = detect_drift(&monitor.window_samples(), 256).expect("enough samples");
    println!(
        "drift detector: ks={:.4} threshold={:.4} drifted={}",
        report.ks, report.threshold, report.drifted
    );
    assert!(report.drifted, "the onset must be detected");

    // --- 2. family re-fit ------------------------------------------------
    // after the window fills with straggling samples
    for _ in 0..4_096 {
        monitor.observe(truth.sample(&mut rng));
    }
    let (family, fitted, ks) = select_family(&monitor.window_samples());
    println!(
        "\nre-fit: family={:?} ks={:.4} fitted mean={:.4} (true {:.4})",
        family,
        ks,
        fitted.mean(),
        truth.mean()
    );
    assert_eq!(family, Family::MultiModalExp);

    let (_, straggle_frac) = fit_multimodal_exp(&monitor.window_samples(), 100);
    println!("estimated straggler fraction: {:.3} (true 0.080)", straggle_frac);

    // --- 3. mitigation: cloning ablation --------------------------------
    // fork-join over 8 straggling mappers vs speculative duplicates
    // (min-composition): Eq. 3 vs the cloning primitive.
    let grid = GridSpec::new(truth.quantile(0.9999) * 2.0 / 1024.0, 1024);
    let branch_cdfs: Vec<Vec<f64>> = (0..8).map(|_| truth.cdf_grid(grid.dt, grid.n)).collect();
    let (_, join_pdf) = parallel_compose(&branch_cdfs, grid.dt);
    let (join_mean, join_var) = moments(&join_pdf, grid.dt);

    // each logical task runs as 2 clones; completion = min of the pair,
    // then the stage joins over 8 logical branches
    let pair: Vec<Vec<f64>> = (0..2).map(|_| truth.cdf_grid(grid.dt, grid.n)).collect();
    let (clone_cdf, _) = cloning_compose(&pair, grid.dt);
    let cloned_branches: Vec<Vec<f64>> = (0..8).map(|_| clone_cdf.clone()).collect();
    let (_, cloned_pdf) = parallel_compose(&cloned_branches, grid.dt);
    let (cloned_mean, cloned_var) = moments(&cloned_pdf, grid.dt);

    println!("\nfork-join over 8 straggling mappers:");
    println!("  plain      : mean={join_mean:.4} var={join_var:.4}");
    println!("  2x cloning : mean={cloned_mean:.4} var={cloned_var:.4}");
    println!(
        "  cloning cuts the stage mean by {:.1}% (at 2x the work)",
        100.0 * (join_mean - cloned_mean) / join_mean
    );
    assert!(cloned_mean < join_mean);

    // --- 4. re-score through the empirical backend ----------------------
    // the planner scores against the *measured* law directly: server 0 is
    // believed healthy Exp(10) but the monitor window says it straggles.
    // No grid pinning needed — the planner sizes its evaluation grid
    // against the backend's scoring laws, so the measured tail fits.
    let believed = Server::pool_exponential(&[10.0, 9.0, 8.0]);
    let wf = Workflow::tandem(3, 2.0);
    let backend = EmpiricalBackend::new().with_samples(0, &monitor.window_samples());
    let optimistic = Planner::new(&wf, &believed)
        .plan(&SdccPolicy)
        .expect("feasible");
    let measured = Planner::new(&wf, &believed)
        .backend(&backend)
        .plan(&SdccPolicy)
        .expect("feasible");
    println!(
        "\nre-scoring a 3-stage chain ({} measured server):",
        backend.measured_servers()
    );
    println!("  believed laws          : mean={:.4}", optimistic.score.mean);
    println!("  measured (empirical)   : mean={:.4}", measured.score.mean);
    assert!(
        measured.score.mean > optimistic.score.mean,
        "the straggler must surface in the measured score"
    );
    assert!(
        measured.score.mass > 0.95,
        "auto grid must cover the measured tail (mass {})",
        measured.score.mass
    );
}
