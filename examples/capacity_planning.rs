//! Capacity planning: "how much load can this cluster take, and what do
//! I have to buy to take more?" — the throughput dual of the paper's
//! response-time optimization (§3), plus multi-job pool partitioning.
//!
//! ```bash
//! cargo run --release --example capacity_planning
//! ```

use dcflow::flow::dag::FlowDag;
use dcflow::prelude::*;

fn main() {
    let model = ResponseModel::Mm1;

    // ---- 1. raw and SLA-constrained capacity of the Fig. 6 workflow ----
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let raw = max_throughput(&wf, &servers, model).expect("feasible");
    println!("fig6 on mu=9..4:");
    println!("  declared entry rate : {:.2} tasks/s", wf.arrival_rate);
    println!("  max sustainable     : {raw:.2} tasks/s ({:.0}% headroom)",
        100.0 * (raw / wf.arrival_rate - 1.0));
    for bound in [3.0, 2.0, 1.6] {
        let t = max_throughput_under_sla(&wf, &servers, model, Sla::Mean(bound))
            .expect("feasible");
        println!("  under mean <= {bound:<4}: {t:.2} tasks/s");
    }
    let t99 = max_throughput_under_sla(&wf, &servers, model, Sla::P99(5.0)).expect("feasible");
    println!("  under p99  <= 5.0 : {t99:.2} tasks/s");

    // ---- 2. what uniform hardware would be needed ----------------------
    let mu = required_speedup(&wf, model);
    println!(
        "\nuniform-pool equivalent: {} x Exp({mu:.2}) sustains the declared load",
        wf.slots()
    );

    // ---- 3. a workflow arriving as a general DAG ------------------------
    // ingest -> {2-branch fork} -> merge -> sink, written as edges
    let dag = FlowDag::new()
        .stage(0, 1, "ingest")
        .stage(1, 2, "transform-a")
        .stage(1, 2, "transform-b")
        .stage(2, 3, "sink-write");
    let tree = dag.to_series_parallel(0, 3).expect("TTSP");
    let dag_wf = Workflow::new(tree, 3.0).expect("valid");
    let pool = Server::pool_exponential(&[10.0, 8.0, 6.0, 5.0]);
    let cap = max_throughput(&dag_wf, &pool, model).expect("feasible");
    println!("\nDAG workflow ({} stages): capacity {cap:.2} tasks/s", dag_wf.slots());

    // ---- 4. multi-job cluster partitioning ------------------------------
    let heavy = Workflow::fig6();
    let light = Workflow::tandem(3, 1.5);
    let jobs = [&heavy, &light];
    let cluster = Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let plans = Planner::new(&heavy, &cluster)
        .model(model)
        .objective(Objective::Mean)
        .plan_jobs(&jobs)
        .expect("fits");
    println!("\nmulti-job partition over a 9-server cluster:");
    for p in &plans {
        println!(
            "  job {}: servers {:?}  mean={:.3} var={:.3}",
            p.job,
            p.alloc.slot_server,
            p.score.mean,
            p.score.var
        );
    }
    println!(
        "  load-weighted cluster objective: {:.3}",
        cluster_objective(&plans, &jobs, Objective::Mean)
    );
}
