//! Deterministic soak of the live re-planning service: sustained seeded
//! load across every workload-zoo class, with the service's own latency
//! (planner wall time per admitted re-plan) as the headline number.
//!
//! Every scenario runs **twice** under the same [`ServeConfig`]; the
//! soak gates on bit-identical run reports, execution traces and
//! admission counters before it reports anything (identity is the gate,
//! latency is the payload). The admission invariants — `offered ==
//! admitted + shed`, `shed == shed_inflight + shed_debounce`,
//! `peak_inflight <= max_inflight` — are re-checked here on every run,
//! not just in the test suite. A `sim::network` Monte-Carlo pass over
//! the first scenario's final allocation cross-checks the analytic
//! plan (and exercises the pinned `cdf_at` edge behavior), with a
//! `sim::queueing` station-level reference alongside.
//!
//! ```text
//! cargo run --release --example serve_soak            # full soak (~24k requests)
//! cargo run --release --example serve_soak -- --smoke # CI smoke (~1.8k requests)
//! DCFLOW_TRACE=1 cargo run --release --example serve_soak -- --smoke
//! ```
//!
//! Output: a deterministic JSON report (schema in `docs/BENCHMARKS.md`)
//! plus, under `DCFLOW_TRACE=1`, the telemetry JSONL / Chrome-trace
//! exports of one instrumented short soak. Exit codes: 0 = every
//! scenario deterministic and every invariant held, 1 = divergence
//! (the report is still written first), 2 = CLI error.

use std::collections::BTreeMap;
use std::time::Instant;

use dcflow::prelude::*;
use dcflow::scenario::reports_identical;
use dcflow::sim::queueing::simulate_station;
use dcflow::util::cli::Cli;
use dcflow::util::json::Json;
use dcflow::util::rng::Rng;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Mean / max / count summary of the real planner wall times.
fn timing_json(secs: &[f64]) -> Json {
    let n = secs.len();
    let mean = if n == 0 {
        0.0
    } else {
        secs.iter().sum::<f64>() / n as f64
    };
    let max = secs.iter().copied().fold(0.0_f64, f64::max);
    obj(vec![
        ("count", Json::Num(n as f64)),
        ("mean_s", Json::Num(mean)),
        ("max_s", Json::Num(max)),
    ])
}

fn admission_json(st: &AdmissionStats) -> Json {
    obj(vec![
        ("offered", Json::Num(st.offered as f64)),
        ("admitted", Json::Num(st.admitted as f64)),
        ("shed", Json::Num(st.shed as f64)),
        ("shed_inflight", Json::Num(st.shed_inflight as f64)),
        ("shed_debounce", Json::Num(st.shed_debounce as f64)),
        ("forced", Json::Num(st.forced as f64)),
        ("peak_inflight", Json::Num(st.peak_inflight as f64)),
        ("swaps_applied", Json::Num(st.swaps_applied as f64)),
    ])
}

struct ReportCtx {
    out_path: String,
    cfg: ServeConfig,
    tasks: usize,
    sim_tasks: usize,
    seed: u64,
    smoke: bool,
}

impl ReportCtx {
    fn write(&self, results: &[Json], sim_check: &Json, identical: bool, telemetry: &Json) {
        let report = obj(vec![
            ("bench", Json::Str("serve_soak".into())),
            ("crate_version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            (
                "config",
                obj(vec![
                    ("max_inflight", Json::Num(self.cfg.max_inflight as f64)),
                    ("debounce", Json::Num(self.cfg.debounce as f64)),
                    ("replan_hold", Json::Num(self.cfg.replan_hold as f64)),
                    ("shards", Json::Num(self.cfg.shards as f64)),
                    ("wave_depth", Json::Num(self.cfg.wave_depth as f64)),
                    ("tasks_per_scenario", Json::Num(self.tasks as f64)),
                    ("sim_tasks", Json::Num(self.sim_tasks as f64)),
                    ("seed", Json::Num(self.seed as f64)),
                    ("smoke", Json::Bool(self.smoke)),
                ]),
            ),
            ("results", Json::Arr(results.to_vec())),
            ("sim_check", sim_check.clone()),
            ("deterministic", Json::Bool(identical)),
            ("telemetry", telemetry.clone()),
        ]);
        std::fs::write(&self.out_path, report.to_string() + "\n").expect("write SOAK json");
    }
}

fn main() {
    let cli = Cli::new(
        "serve_soak",
        "deterministic soak of the live re-planning service over the workload zoo",
    )
    .opt("out", "SOAK_serve.json", "output path for the JSON report")
    .opt(
        "trace-out",
        "TRACE_serve_soak.jsonl",
        "telemetry JSONL path (written when DCFLOW_TRACE=1)",
    )
    .opt(
        "chrome-out",
        "TRACE_serve_soak.chrome.json",
        "Chrome trace-event path (written when DCFLOW_TRACE=1)",
    )
    .opt("tasks", "4000", "arrival-stream length per zoo scenario")
    .opt("sim-tasks", "50000", "Monte-Carlo samples for the sim cross-check")
    .opt("seed", "0", "XORed into every scenario seed (0 = the pinned zoo seeds)")
    .opt("max-inflight", "1", "admission: concurrent re-plan slot cap")
    .opt("debounce", "400", "admission: min completions between admitted re-plans")
    .opt("replan-hold", "250", "admission: completions each admitted re-plan holds its slot")
    .opt("shards", "2", "scoring-fabric workers behind the async backend")
    .opt("wave-depth", "2", "in-flight chunk depth of the async backend")
    .flag("smoke", "short streams (CI smoke run)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let out_path = args.get("out").to_string();
    let trace_out = args.get("trace-out").to_string();
    let chrome_out = args.get("chrome-out").to_string();
    let smoke = args.has("smoke");
    // --smoke only lowers the *defaults*; explicit --tasks/--sim-tasks win
    let passed = |name: &str| {
        argv.iter()
            .any(|a| a == &format!("--{name}") || a.starts_with(&format!("--{name}=")))
    };
    let tasks: usize = if smoke && !passed("tasks") {
        300
    } else {
        args.get_as("tasks").expect("--tasks")
    };
    let sim_tasks: usize = if smoke && !passed("sim-tasks") {
        5_000
    } else {
        args.get_as("sim-tasks").expect("--sim-tasks")
    };
    let seed: u64 = args.get_as("seed").expect("--seed");
    let cfg = ServeConfig {
        max_inflight: args.get_as("max-inflight").expect("--max-inflight"),
        debounce: args.get_as("debounce").expect("--debounce"),
        replan_hold: args.get_as("replan-hold").expect("--replan-hold"),
        shards: args.get_as("shards").expect("--shards"),
        wave_depth: args.get_as("wave-depth").expect("--wave-depth"),
    };
    let ctx = ReportCtx {
        out_path,
        cfg,
        tasks,
        sim_tasks,
        seed,
        smoke,
    };

    let specs: Vec<ScenarioSpec> = ScenarioSpec::zoo()
        .into_iter()
        .map(|s| {
            let scenario_seed = s.seed ^ seed;
            s.with_seed(scenario_seed).with_tasks(tasks)
        })
        .collect();
    println!(
        "serve_soak: {} scenarios x {tasks} tasks, admission cap {} / debounce {} / hold {}{}",
        specs.len(),
        cfg.max_inflight,
        cfg.debounce,
        cfg.replan_hold,
        if smoke { " (smoke)" } else { "" }
    );

    let mut results: Vec<Json> = Vec::new();
    let mut identical = true;
    let mut total_completed: u64 = 0;
    // first scenario's outcome feeds the Monte-Carlo cross-check below
    let mut sim_subject: Option<(ScenarioSpec, Allocation)> = None;

    for spec in &specs {
        let started = Instant::now();
        let (r1, t1) = Service::run_spec(spec, cfg)
            .unwrap_or_else(|e| panic!("{}: service run failed: {e}", spec.name));
        let (r2, t2) = Service::run_spec(spec, cfg)
            .unwrap_or_else(|e| panic!("{}: service re-run failed: {e}", spec.name));
        let wall_s = started.elapsed().as_secs_f64();

        // determinism gate: same seed twice => same decisions, bit for bit
        let deterministic =
            reports_identical(&r1.run, &r2.run) && t1 == t2 && r1.admission == r2.admission;
        if !deterministic {
            eprintln!(
                "serve_soak: '{}' is NOT deterministic across identical runs \
                 (admission {:?} vs {:?})",
                spec.name, r1.admission, r2.admission
            );
            identical = false;
        }
        // admission invariants, re-checked on every soak run
        let st = r1.admission;
        if st.offered != st.admitted + st.shed
            || st.shed != st.shed_inflight + st.shed_debounce
            || st.peak_inflight > cfg.max_inflight.max(1)
        {
            eprintln!(
                "serve_soak: '{}' broke an admission invariant: {st:?}",
                spec.name
            );
            identical = false;
        }

        let m = &r1.run.metrics;
        total_completed += m.completed;
        println!(
            "  {:<24} tasks {:>6}  virt p99 {:>8.4}  replans {}/{} (shed {})  plan mean \
             {:>9.6} s",
            spec.name,
            m.completed,
            m.latency_quantile(0.99),
            st.admitted,
            st.offered,
            st.shed,
            r1.replan_secs.iter().sum::<f64>() / r1.replan_secs.len().max(1) as f64
        );
        results.push(obj(vec![
            ("scenario", Json::Str(spec.name.clone())),
            ("class", Json::Str(spec.class.label().into())),
            ("seed", Json::Num(spec.seed as f64)),
            ("completed", Json::Num(m.completed as f64)),
            ("mean_latency", Json::Num(m.mean_latency())),
            ("p50_latency", Json::Num(m.latency_quantile(0.5))),
            ("p99_latency", Json::Num(m.latency_quantile(0.99))),
            ("throughput", Json::Num(m.throughput())),
            ("makespan", Json::Num(m.makespan)),
            ("reoptimizations", Json::Num(m.reoptimizations as f64)),
            ("admission", admission_json(&st)),
            // the latency of the service itself: real planner wall time
            ("replan_wall", timing_json(&r1.replan_secs)),
            ("wall_s", Json::Num(wall_s)),
            ("deterministic", Json::Bool(deterministic)),
        ]));
        if sim_subject.is_none() {
            sim_subject = Some((spec.clone(), r1.run.final_allocation.clone()));
        }
    }
    println!("total simulated requests: {}", 2 * total_completed);

    // Monte-Carlo cross-check: simulate the first scenario's final
    // allocation end to end and read the response CDF at the virtual
    // quantiles — exercising the pinned cdf_at edge contract — plus a
    // Lindley station-level reference for slot 0
    let sim_check = match &sim_subject {
        Some((spec, alloc)) if alloc.slot_server.iter().all(|&s| s < spec.initial_view().len()) => {
            let servers = spec.initial_view();
            let scfg = SimConfig {
                n_tasks: sim_tasks,
                warmup: sim_tasks / 20,
                seed: 0xD0C5 ^ seed,
                queueing: true,
            };
            let sim = simulate(&spec.workflow(), alloc, &servers, &scfg);
            assert_eq!(sim.cdf_at(f64::NEG_INFINITY), 0.0, "cdf lower edge");
            assert_eq!(sim.cdf_at(f64::INFINITY), 1.0, "cdf upper edge");
            let mut rng = Rng::new(scfg.seed);
            let station = simulate_station(
                &servers[alloc.server_for(0)].dist,
                alloc.rate_for(0),
                scfg.n_tasks,
                scfg.warmup,
                &mut rng,
            );
            let station_mean = station.iter().sum::<f64>() / station.len() as f64;
            obj(vec![
                ("scenario", Json::Str(spec.name.clone())),
                ("sim_mean", Json::Num(sim.mean)),
                ("sim_p50", Json::Num(sim.p50)),
                ("sim_p99", Json::Num(sim.p99)),
                ("cdf_at_p50", Json::Num(sim.cdf_at(sim.p50))),
                ("cdf_at_p99", Json::Num(sim.cdf_at(sim.p99))),
                ("station0_mean", Json::Num(station_mean)),
            ])
        }
        _ => Json::Str("skipped: final allocation references a departed server".into()),
    };

    // telemetry capture: re-run one short soak instrumented so the
    // exported trace is a single clean serve.run -> serve.replan ->
    // backend.wave -> backend.chunk tree, then validate + export it
    let telemetry = if dcflow::obs::enabled() {
        let _ = dcflow::obs::drain();
        let spec = ScenarioSpec::serve_soak_short();
        let (report, _) = Service::run_spec(&spec, cfg).expect("instrumented soak runs");
        let events = dcflow::obs::drain();
        let summary = match dcflow::obs::validate(&events) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve_soak: telemetry trace failed validation: {e}");
                std::process::exit(1);
            }
        };
        std::fs::write(&trace_out, dcflow::obs::to_jsonl(&events))
            .expect("write telemetry JSONL");
        std::fs::write(&chrome_out, dcflow::obs::to_chrome_trace(&events))
            .expect("write Chrome trace");
        println!(
            "wrote {trace_out} + {chrome_out} ({} spans, max depth {})",
            summary.spans, summary.max_depth
        );
        let snap = dcflow::obs::registry().snapshot();
        let mut counters = BTreeMap::new();
        for (name, v) in snap.counters {
            counters.insert(name, Json::Num(v as f64));
        }
        obj(vec![
            ("enabled", Json::Bool(true)),
            ("scenario", Json::Str(spec.name.clone())),
            ("spans", Json::Num(summary.spans as f64)),
            ("instants", Json::Num(summary.instants as f64)),
            ("roots", Json::Num(summary.roots as f64)),
            ("max_depth", Json::Num(summary.max_depth as f64)),
            ("soak_offered", Json::Num(report.admission.offered as f64)),
            ("trace_jsonl", Json::Str(trace_out.clone())),
            ("trace_chrome", Json::Str(chrome_out.clone())),
            ("counters", Json::Obj(counters)),
        ])
    } else {
        obj(vec![("enabled", Json::Bool(false))])
    };

    ctx.write(&results, &sim_check, identical, &telemetry);
    println!("wrote {} (deterministic: {identical})", ctx.out_path);
    if !identical {
        std::process::exit(1);
    }
}
