//! End-to-end driver: a MapReduce-style analytics chain on a simulated
//! heterogeneous cluster, coordinated by the full system (leader +
//! worker threads + monitors + Algorithm-3 re-optimization) over a
//! bursty arrival trace with injected server degradation.
//!
//! This is the repository's headline end-to-end validation (recorded in
//! EXPERIMENTS.md): it exercises every layer the library has —
//! workflows, Table-1 laws, allocation + rate scheduling, monitoring,
//! drift detection, and the coordinator runtime — on a realistic
//! workload, and reports the paper's headline metric (mean/variance/p99
//! response-time improvement of the proposed scheme over the baseline).
//!
//! ```bash
//! cargo run --release --example mapreduce_chain
//! ```

use dcflow::coordinator::{Coordinator, CoordinatorConfig, Policy, WorkerSpec};
use dcflow::prelude::*;
use dcflow::sim::trace::{ArrivalProcess, Trace};
use dcflow::util::rng::Rng;

/// The chain: ingest -> map fan-out (4) -> shuffle -> reduce fan-out (2).
/// DAP rates taper 6 -> 6 -> 3 -> 1.5 like the paper's Fig. 6.
fn workflow() -> Workflow {
    let root = Dcc::serial_with_rates(
        vec![
            Dcc::queue(),                                              // ingest
            Dcc::parallel((0..4).map(|_| Dcc::queue()).collect()),     // map
            Dcc::queue(),                                              // shuffle
            Dcc::parallel((0..2).map(|_| Dcc::queue()).collect()),     // reduce
        ],
        vec![Some(6.0), Some(6.0), Some(3.0), Some(1.5)],
    );
    Workflow::new(root, 6.0).expect("valid chain")
}

/// Heterogeneous 8-server cluster. Two servers are stragglers-in-waiting:
/// they degrade mid-run (resource contention onset), which only the
/// monitor loop can catch.
fn cluster(seedless_prior: &mut Vec<Server>) -> Vec<WorkerSpec> {
    let rates = [14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0];
    *seedless_prior = Server::pool_exponential(&rates);
    rates
        .iter()
        .enumerate()
        .map(|(i, &mu)| {
            if i == 1 {
                // fast server that degrades to 30% speed after 8k tasks
                WorkerSpec::drifting(
                    i,
                    ServiceDist::exponential(mu),
                    8_000,
                    ServiceDist::exponential(mu * 0.3),
                )
            } else if i == 6 {
                // a straggling mode appears after 12k tasks
                WorkerSpec::drifting(
                    i,
                    ServiceDist::exponential(mu),
                    12_000,
                    ServiceDist::straggler(mu, mu * 0.08, 0.10, 0.0),
                )
            } else {
                WorkerSpec::stable(i, ServiceDist::exponential(mu))
            }
        })
        .collect()
}

fn bursty_trace(n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    Trace::generate(
        ArrivalProcess::Mmpp {
            base_rate: 1.2,
            burst_rate: 3.5,
            base_dwell: 40.0,
            burst_dwell: 8.0,
        },
        n,
        &mut rng,
    )
}

fn run(policy: Policy, adaptive: bool) -> dcflow::coordinator::RunReport {
    let mut prior = Vec::new();
    let specs = cluster(&mut prior);
    let cfg = CoordinatorConfig {
        seed: 2026,
        policy,
        reopt_every: if adaptive { 1_000 } else { 0 },
        reopt_on_drift_only: true,
        monitor_window: 2_048,
        min_fit_samples: 384,
        ..Default::default()
    };
    let mut coord = Coordinator::new(specs, prior, cfg);
    let job = coord.submit("mapreduce-chain", workflow());
    let trace = bursty_trace(40_000, 99);
    let report = coord.run_job(&job, &trace).expect("feasible");
    coord.shutdown();
    report
}

fn main() {
    println!("== MapReduce chain on 8-server heterogeneous cluster ==");
    println!("40k bursty arrivals (MMPP), drift injected at tasks 8k (degrade) and 12k (stragglers)\n");

    let configs: [(&str, Policy, bool); 4] = [
        ("baseline/static", Policy::Baseline, false),
        ("baseline/adaptive", Policy::Baseline, true),
        ("proposed/static", Policy::Proposed, false),
        ("proposed/adaptive", Policy::Proposed, true),
    ];

    let mut rows = Vec::new();
    for (name, policy, adaptive) in configs {
        let r = run(policy, adaptive);
        println!(
            "{name:<20} mean={:<8.4} var={:<8.4} p99={:<8.4} swaps={} ({})",
            r.metrics.mean_latency(),
            r.metrics.var_latency(),
            r.metrics.latency_quantile(0.99),
            r.metrics.reoptimizations,
            r.swaps
                .iter()
                .map(|(at, why)| format!("@{at}:{why}"))
                .collect::<Vec<_>>()
                .join(", "),
        );
        rows.push((name, r));
    }

    let base = &rows[0].1.metrics;
    let ours = &rows[3].1.metrics;
    println!("\nheadline (proposed/adaptive vs baseline/static):");
    println!(
        "  mean  improvement: {:+.1}%",
        100.0 * (base.mean_latency() - ours.mean_latency()) / base.mean_latency()
    );
    println!(
        "  var   improvement: {:+.1}%",
        100.0 * (base.var_latency() - ours.var_latency()) / base.var_latency()
    );
    println!(
        "  p99   improvement: {:+.1}%",
        100.0 * (base.latency_quantile(0.99) - ours.latency_quantile(0.99))
            / base.latency_quantile(0.99)
    );
}
