//! Telemetry trace inspector: validate a JSONL trace, print its span
//! tree, and optionally re-export it as Chrome trace-event JSON.
//!
//! Reads a trace written by `dcflow::obs::to_jsonl` (e.g. the
//! `TRACE_multijob.jsonl` emitted by `multijob_bench` under
//! `DCFLOW_TRACE=1`), validates its structure (unique ids, parents
//! present, child windows nested inside parents), and prints the span
//! hierarchy with wall-clock offsets. With no `--in` it captures a small
//! self-demo trace by planning a two-job set on a sharded backend, so
//! the tool is runnable (and CI-checkable) without any input file.
//!
//! ```text
//! cargo run --release --example trace_viz -- --in TRACE_multijob.jsonl
//! cargo run --release --example trace_viz -- --in t.jsonl --chrome t.chrome.json
//! cargo run --release --example trace_viz            # self-demo capture
//! ```
//!
//! Exit codes: 0 valid, 1 invalid/unparseable trace, 2 usage/IO error.

use std::collections::BTreeMap;

use dcflow::obs::{self, Event};
use dcflow::prelude::*;
use dcflow::util::cli::Cli;

/// Print the span hierarchy, children sorted by start time.
fn print_span_tree(events: &[Event]) {
    // id -> (name, start_us, dur_us, tid, attr count)
    let mut spans: BTreeMap<u64, (&str, u64, u64, u64, usize)> = BTreeMap::new();
    let mut children: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let mut roots: Vec<u64> = Vec::new();
    for ev in events {
        if let Event::Span {
            id,
            parent,
            name,
            tid,
            start_us,
            dur_us,
            attrs,
        } = ev
        {
            spans.insert(*id, (name.as_str(), *start_us, *dur_us, *tid, attrs.len()));
            match parent {
                Some(p) => children.entry(*p).or_default().push(*id),
                None => roots.push(*id),
            }
        }
    }
    roots.sort_by_key(|id| (spans[id].1, *id));
    for ids in children.values_mut() {
        ids.sort_by_key(|id| (spans[id].1, *id));
    }
    // depth-first walk with an explicit stack (children pushed reversed
    // so they pop in start order)
    let mut stack: Vec<(u64, usize)> = roots.iter().rev().map(|&r| (r, 0)).collect();
    while let Some((id, depth)) = stack.pop() {
        let (name, start, dur, tid, nattrs) = spans[&id];
        let attrs = if nattrs > 0 {
            format!("  ({nattrs} attrs)")
        } else {
            String::new()
        };
        println!(
            "{:indent$}{name}  [{start} us +{dur} us, tid {tid}]{attrs}",
            "",
            indent = 2 * depth
        );
        if let Some(kids) = children.get(&id) {
            for &k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
}

/// Capture a self-demo trace: plan a two-job set (fig6 + tandem rider)
/// on a sharded incremental configuration with a pinned coarse grid.
fn demo_capture() -> Vec<Event> {
    let _ = obs::drain(); // start from a clean sink
    let recorder = Recorder::global();
    {
        let _capture = recorder.activate();
        let servers =
            Server::pool_exponential(&[18.0, 16.0, 14.0, 12.0, 10.0, 8.0, 6.0, 4.0]);
        let jobs_owned = vec![Workflow::fig6(), Workflow::tandem(2, 1.0)];
        let jobs: Vec<&Workflow> = jobs_owned.iter().collect();
        let backend = ShardedBackend::new(&AnalyticBackend, 2).min_parallel_wave(2);
        let planner = Planner::new(jobs[0], &servers)
            .objective(Objective::Mean)
            .backend(&backend)
            .swap_engine(SwapEngine::Incremental)
            .grid(GridSpec::new(0.05, 256));
        planner.plan_jobs(&jobs).expect("demo job set is feasible");
    }
    obs::drain()
}

fn main() {
    let cli = Cli::new(
        "trace_viz",
        "validate a dcflow telemetry trace, print its span tree, export Chrome JSON",
    )
    .opt("in", "", "input telemetry JSONL; empty = capture a self-demo trace")
    .opt("chrome", "", "Chrome trace-event output path; empty = skip export");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let in_path = args.get("in").to_string();
    let chrome_path = args.get("chrome").to_string();

    let events = if in_path.is_empty() {
        println!("trace_viz: no --in, capturing a self-demo trace");
        demo_capture()
    } else {
        let text = match std::fs::read_to_string(&in_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("trace_viz: cannot read {in_path}: {e}");
                std::process::exit(2);
            }
        };
        match obs::parse_jsonl(&text) {
            Ok(ev) => ev,
            Err(e) => {
                eprintln!("trace_viz: {e}");
                std::process::exit(1);
            }
        }
    };

    let summary = match obs::validate(&events) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace_viz: invalid trace: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "valid: {} spans ({} roots, max depth {}), {} instants ({} warns)",
        summary.spans, summary.roots, summary.max_depth, summary.instants, summary.warns
    );
    print_span_tree(&events);

    if !chrome_path.is_empty() {
        std::fs::write(&chrome_path, obs::to_chrome_trace(&events))
            .expect("write Chrome trace");
        println!("wrote {chrome_path}");
    }
}
