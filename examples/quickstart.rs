//! Quickstart: allocate, score and simulate the paper's Fig. 6 workflow.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dcflow::compose::grid::GridSpec;
use dcflow::compose::score::score_allocation_with;
use dcflow::prelude::*;
use dcflow::sched::{baseline_allocate_split, proposed_allocate, ResponseModel, SplitPolicy};
use dcflow::sim::network::{simulate, SimConfig};

fn main() {
    // Six heterogeneous servers: exponential service, rates 9..4
    // (the paper's evaluation pool).
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);

    // The paper's Fig. 6 workflow: PDCC ; SDCC ; PDCC with DAP rates 8/4/2.
    let wf = Workflow::fig6();
    let model = ResponseModel::Mm1;

    // --- the paper's scheme: Alg. 1/2 seed + §3 balancing ------------
    let (ours, ours_score) =
        proposed_allocate(&wf, &servers, model, Objective::Mean).expect("feasible");
    let grid = GridSpec::auto_response(&ours, &servers, model);

    println!("proposed allocation (slot -> server rate):");
    for slot in 0..wf.slots() {
        println!(
            "  slot {slot}: server {} (mu = {:.1}, lambda = {:.3})",
            ours.server_for(slot),
            servers[ours.server_for(slot)].service_rate(),
            ours.rate_for(slot),
        );
    }
    println!(
        "analytic score: mean={:.4} var={:.4} p99={:.4}",
        ours_score.mean, ours_score.var, ours_score.p99
    );

    // --- comparators ---------------------------------------------------
    println!("\n{:<16} {:>9} {:>9} {:>9}", "policy", "mean", "var", "p99");
    let mut row = |name: &str, alloc: &Allocation| {
        let s = score_allocation_with(&wf, alloc, &servers, &grid, model);
        println!("{name:<16} {:>9.4} {:>9.4} {:>9.4}", s.mean, s.var, s.p99);
    };
    row("proposed", &ours);
    if let Ok(b) = baseline_allocate(&wf, &servers, model) {
        row("baseline", &b);
    }
    if let Ok(b) = baseline_allocate_split(&wf, &servers, model, SplitPolicy::Equilibrium) {
        row("fair-baseline", &b);
    }
    if let Ok((o, _)) = optimal_allocate(&wf, &servers, &grid, Objective::Mean, model) {
        row("optimal", &o);
    }

    // --- Monte-Carlo cross-check ----------------------------------------
    let sim = simulate(
        &wf,
        &ours,
        &servers,
        &SimConfig {
            n_tasks: 200_000,
            warmup: 10_000,
            seed: 42,
            queueing: true,
        },
    );
    println!(
        "\nDES cross-check (proposed): mean={:.4} var={:.4} p99={:.4}",
        sim.mean, sim.var, sim.p99
    );
    println!(
        "analytic vs sim mean gap: {:+.2}%",
        100.0 * (ours_score.mean - sim.mean) / sim.mean
    );
}
