//! Quickstart: plan, score and simulate the paper's Fig. 6 workflow
//! through the unified `Planner` surface.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dcflow::prelude::*;

fn main() {
    // Six heterogeneous servers: exponential service, rates 9..4
    // (the paper's evaluation pool).
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);

    // The paper's Fig. 6 workflow: PDCC ; SDCC ; PDCC with DAP rates 8/4/2.
    let wf = Workflow::fig6();

    // One builder holds the whole request configuration.
    let planner = Planner::new(&wf, &servers)
        .model(ResponseModel::Mm1)
        .objective(Objective::Mean);

    // --- the paper's scheme: Alg. 1/2 seed + §3 balancing ------------
    let ours = planner
        .plan(&ProposedPolicy::default())
        .expect("fig6 is feasible");

    println!("proposed allocation (slot -> server rate):");
    for slot in 0..wf.slots() {
        println!(
            "  slot {slot}: server {} (mu = {:.1}, lambda = {:.3})",
            ours.allocation.server_for(slot),
            servers[ours.allocation.server_for(slot)].service_rate(),
            ours.allocation.rate_for(slot),
        );
    }
    println!(
        "analytic score: mean={:.4} var={:.4} p99={:.4}",
        ours.score.mean, ours.score.var, ours.score.p99
    );

    // --- comparators: every policy scored on one common grid ----------
    let fair = BaselinePolicy {
        split: SplitPolicy::Equilibrium,
    };
    println!("\n{:<16} {:>9} {:>9} {:>9}", "policy", "mean", "var", "p99");
    for result in planner.compare(&[
        &ProposedPolicy::default(),
        &BaselinePolicy::default(),
        &fair,
        &OptimalPolicy,
    ]) {
        match result {
            Ok(plan) => println!(
                "{:<16} {:>9.4} {:>9.4} {:>9.4}",
                plan.policy_name, plan.score.mean, plan.score.var, plan.score.p99
            ),
            Err(e) => println!("{e}"),
        }
    }

    // --- Monte-Carlo cross-check ----------------------------------------
    let sim = simulate(
        &wf,
        &ours.allocation,
        &servers,
        &SimConfig {
            n_tasks: 200_000,
            warmup: 10_000,
            seed: 42,
            queueing: true,
        },
    );
    println!(
        "\nDES cross-check (proposed): mean={:.4} var={:.4} p99={:.4}",
        sim.mean, sim.var, sim.p99
    );
    println!(
        "analytic vs sim mean gap: {:+.2}%",
        100.0 * (ours.score.mean - sim.mean) / sim.mean
    );
}
