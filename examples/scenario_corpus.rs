//! Golden-corpus maintenance CLI for the scenario subsystem.
//!
//! Default mode is the same gate CI runs: every workload-zoo scenario
//! (plus the serve soak scenario, `serve_soak_short`) is checked
//! against its committed golden files under `rust/tests/golden/`
//! (replay twice, bit-compare, byte-compare the summary), blessing any
//! scenario whose files are missing. `--regen` re-captures and rewrites
//! the corpus unconditionally — use it after an *intentional* behavior
//! change, then review the diff.
//!
//! ```text
//! cargo run --release --example scenario_corpus                 # check / bless
//! cargo run --release --example scenario_corpus -- --regen      # refresh all
//! cargo run --release --example scenario_corpus -- --regen --scenario worker_churn
//! ```
//!
//! Exit status: 0 all scenarios OK (matched, blessed, or regenerated),
//! 1 on any divergence or capture error, 2 on CLI misuse.

use dcflow::scenario::{check_or_bless, regenerate, GoldenStatus, ScenarioSpec};
use dcflow::util::cli::Cli;

fn main() {
    let cli = Cli::new(
        "scenario_corpus",
        "check, bless or regenerate the golden scenario corpus",
    )
    .opt("scenario", "", "restrict to one zoo scenario by name")
    .flag("regen", "re-capture and overwrite the corpus (intentional changes only)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let regen = args.has("regen");
    let only = args.get("scenario").to_string();

    // the full corpus: one zoo entry per class plus the serve soak
    // scenario the live re-planning service is goldened against
    let mut zoo = ScenarioSpec::zoo();
    zoo.push(ScenarioSpec::serve_soak_short());
    if !only.is_empty() && !zoo.iter().any(|s| s.name == only) {
        eprintln!(
            "unknown scenario '{only}'; corpus: {}",
            zoo.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
        );
        std::process::exit(2);
    }

    let mut failed = false;
    for spec in &zoo {
        if !only.is_empty() && spec.name != only {
            continue;
        }
        let status = if regen {
            regenerate(spec)
        } else {
            check_or_bless(spec)
        };
        match status {
            Ok(GoldenStatus::Match) => {
                println!("{:<24} OK (matches committed golden)", spec.name);
            }
            Ok(GoldenStatus::Blessed) => {
                println!(
                    "{:<24} BLESSED{} — commit rust/tests/golden/",
                    spec.name,
                    if regen { " (regenerated)" } else { "" }
                );
            }
            Ok(GoldenStatus::Divergence(msg)) => {
                failed = true;
                println!("{:<24} DIVERGED", spec.name);
                eprintln!("  {msg}");
            }
            Err(e) => {
                failed = true;
                println!("{:<24} ERROR", spec.name);
                eprintln!("  {e}");
            }
        }
    }

    if failed {
        eprintln!("scenario_corpus: corpus check failed (see above)");
        std::process::exit(1);
    }
}
