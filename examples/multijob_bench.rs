//! Reproducible multi-job swap benchmark harness: a scenario × engine ×
//! shards matrix.
//!
//! For each bench scenario (heterogeneous pool, DAG pipeline jobs,
//! heavy-tail pool) this runs the cross-job swap refinement serial
//! reference pass and the wave engine across shard counts {1, 2, 8},
//! verifies every configuration produces bit-identical plans to the
//! scenario's serial reference, and emits a machine-readable
//! `BENCH_multijob.json` (schema documented in `docs/BENCHMARKS.md`)
//! so the perf trajectory of the multi-job engine is recorded across
//! workload shapes, not anecdotal.
//!
//! ```text
//! cargo run --release --example multijob_bench            # full matrix
//! cargo run --release --example multijob_bench -- --smoke # CI smoke
//! cargo run --release --example multijob_bench -- --out target/BENCH_multijob.json
//! ```

use std::collections::BTreeMap;

use dcflow::prelude::*;
use dcflow::util::bench::bench;
use dcflow::util::cli::Cli;
use dcflow::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// One row of the bench matrix's scenario axis: a job set + a pool.
struct BenchScenario {
    name: &'static str,
    jobs: Vec<Workflow>,
    servers: Vec<Server>,
}

fn scenarios(smoke: bool) -> Vec<BenchScenario> {
    // heterogeneous pool: the paper's Fig. 6 job plus light tandem /
    // fork-join companions (the original multijob bench workload)
    let hetero = if smoke {
        BenchScenario {
            name: "hetero_pool",
            jobs: vec![Workflow::fig6(), Workflow::tandem(3, 1.0)],
            servers: Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        }
    } else {
        BenchScenario {
            name: "hetero_pool",
            jobs: vec![
                Workflow::fig6(),
                Workflow::tandem(3, 1.0),
                Workflow::forkjoin(2, 2.0),
                Workflow::tandem(2, 3.0),
            ],
            servers: Server::pool_exponential(&[
                18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
            ]),
        }
    };

    // DAG pipeline: the zoo's TTSP-reduced stage graph (8 slots) plus a
    // small tandem rider, on the zoo's 10-server pool + 2 extras
    let dag = BenchScenario {
        name: "dag_pipeline",
        jobs: vec![
            ScenarioSpec::by_name("dag_pipeline")
                .expect("zoo scenario exists")
                .workflow(),
            Workflow::tandem(2, 0.6),
        ],
        servers: Server::pool_exponential(&[
            14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.5, 2.0,
        ]),
    };

    // heavy-tail pool: Table-1 delayed-tail laws at uncomfortable
    // parameters (the regime where FFT-grid scoring earns its keep)
    let heavy = BenchScenario {
        name: "heavy_tail",
        jobs: vec![Workflow::chain(2, 2, 1.2), Workflow::tandem(2, 0.8)],
        servers: vec![
            Server::new(0, ServiceDist::exponential(3.0)),
            Server::new(1, ServiceDist::exponential(2.5)),
            Server::new(2, ServiceDist::straggler(8.0, 0.6, 0.2, 0.0)),
            Server::new(3, ServiceDist::exponential(2.0)),
            Server::new(4, ServiceDist::delayed_pareto(3.0, 0.02)),
            Server::new(5, ServiceDist::exponential(1.8)),
            Server::new(6, ServiceDist::exponential(1.5)),
            Server::new(7, ServiceDist::delayed_weibull(1.6, 0.7, 0.05)),
        ],
    };

    vec![hetero, dag, heavy]
}

fn main() {
    let cli = Cli::new(
        "multijob_bench",
        "scenario x engine x shards multi-job swap matrix, JSON output",
    )
    .opt("out", "BENCH_multijob.json", "output path for the JSON report")
    .opt("iters", "3", "measured iterations per configuration")
    .opt("warmup", "1", "unmeasured warmup iterations")
    .flag("smoke", "smaller hetero job set + pinned coarse grid (CI smoke run)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let out_path = args.get("out").to_string();
    let smoke = args.has("smoke");
    // --smoke only lowers the *defaults*; explicitly passed --iters or
    // --warmup always win
    let passed = |name: &str| {
        argv.iter()
            .any(|a| a == &format!("--{name}") || a.starts_with(&format!("--{name}=")))
    };
    let iters: usize = if smoke && !passed("iters") {
        1
    } else {
        args.get_as("iters").expect("--iters")
    };
    let warmup: usize = if smoke && !passed("warmup") {
        0
    } else {
        args.get_as("warmup").expect("--warmup")
    };

    // the smoke run pins a coarse grid so CI measures the engine, not
    // the FFTs; the full run keeps the auto-sized shared grid
    let pinned = if smoke { Some(GridSpec::new(0.05, 256)) } else { None };
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let matrix = scenarios(smoke);
    println!(
        "multijob_bench: {} scenarios, {cpus} cpus, iters {iters}, warmup {warmup}{}",
        matrix.len(),
        if smoke { " (smoke)" } else { "" }
    );

    let mut results: Vec<Json> = Vec::new();
    let mut scenario_cfgs: Vec<Json> = Vec::new();
    let mut identical = true;

    for sc in &matrix {
        let jobs: Vec<&Workflow> = sc.jobs.iter().collect();
        scenario_cfgs.push(obj(vec![
            ("name", Json::Str(sc.name.into())),
            ("jobs", Json::Num(jobs.len() as f64)),
            ("servers", Json::Num(sc.servers.len() as f64)),
        ]));

        // serial reference pass for this scenario
        let mut serial_planner = Planner::new(jobs[0], &sc.servers)
            .objective(Objective::Mean)
            .swap_engine(SwapEngine::Serial);
        if let Some(g) = pinned {
            serial_planner = serial_planner.grid(g);
        }
        let reference = serial_planner.plan_jobs(&jobs).expect("job set is feasible");
        let t_serial = bench(warmup, iters, || serial_planner.plan_jobs(&jobs).unwrap());
        let ref_objective = cluster_objective(&reference, &jobs, Objective::Mean);
        println!(
            "  {:<12} serial   : {:>10.6} s  (objective {:.4})",
            sc.name, t_serial.mean_s, ref_objective
        );
        results.push(obj(vec![
            ("scenario", Json::Str(sc.name.into())),
            ("engine", Json::Str("serial".into())),
            ("shards", Json::Num(1.0)),
            ("mean_s", Json::Num(t_serial.mean_s)),
            ("std_s", Json::Num(t_serial.std_s)),
            ("speedup_vs_serial", Json::Num(1.0)),
            ("cluster_objective", Json::Num(ref_objective)),
        ]));

        // wave engine × shard counts, each checked bit-identical first
        for shards in [1usize, 2, 8] {
            let backend = ShardedBackend::new(&AnalyticBackend, shards);
            let mut planner = Planner::new(jobs[0], &sc.servers)
                .objective(Objective::Mean)
                .backend(&backend);
            if let Some(g) = pinned {
                planner = planner.grid(g);
            }
            let got = planner.plan_jobs(&jobs).expect("job set is feasible");
            let same = got.len() == reference.len()
                && got.iter().zip(reference.iter()).all(|(g, r)| {
                    g.alloc == r.alloc
                        && g.score.mean == r.score.mean
                        && g.score.p99 == r.score.p99
                        && g.grid == r.grid
                });
            identical &= same;
            let t = bench(warmup, iters, || planner.plan_jobs(&jobs).unwrap());
            let objective = cluster_objective(&got, &jobs, Objective::Mean);
            println!(
                "  {:<12} wave x{shards:<2} : {:>10.6} s  (speedup {:.2}x, identical: {same})",
                sc.name,
                t.mean_s,
                t_serial.mean_s / t.mean_s
            );
            results.push(obj(vec![
                ("scenario", Json::Str(sc.name.into())),
                ("engine", Json::Str("wave".into())),
                ("shards", Json::Num(shards as f64)),
                ("mean_s", Json::Num(t.mean_s)),
                ("std_s", Json::Num(t.std_s)),
                ("speedup_vs_serial", Json::Num(t_serial.mean_s / t.mean_s)),
                ("cluster_objective", Json::Num(objective)),
            ]));
        }
    }

    let grid_json = match pinned {
        Some(g) => obj(vec![("dt", Json::Num(g.dt)), ("n", Json::Num(g.n as f64))]),
        None => Json::Str("auto".into()),
    };
    let report = obj(vec![
        ("bench", Json::Str("multijob_matrix".into())),
        ("crate_version", Json::Str(env!("CARGO_PKG_VERSION").into())),
        (
            "config",
            obj(vec![
                ("scenarios", Json::Arr(scenario_cfgs)),
                ("cpus", Json::Num(cpus as f64)),
                ("swap_rounds", Json::Num(MultiJobConfig::default().swap_rounds as f64)),
                ("max_wave", Json::Num(MultiJobConfig::default().max_wave as f64)),
                ("iters", Json::Num(iters as f64)),
                ("warmup", Json::Num(warmup as f64)),
                ("grid", grid_json),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        ("results", Json::Arr(results)),
        ("identical", Json::Bool(identical)),
    ]);

    std::fs::write(&out_path, report.to_string() + "\n").expect("write BENCH json");
    println!("wrote {out_path} (identical: {identical})");
    if !identical {
        eprintln!("multijob_bench: wave plans diverged from a serial reference");
        std::process::exit(1);
    }
}
