//! Reproducible multi-job swap benchmark harness: a scenario × engine ×
//! dispatch × shards matrix.
//!
//! For each bench scenario (heterogeneous pool, DAG pipeline jobs,
//! heavy-tail pool) this runs the cross-job swap refinement serial
//! reference pass and then the wave and incremental engines across
//! dispatch modes {pooled fabric, spawn-per-wave scoped pool} and
//! shard counts {1, 2, 8}. Every configuration's plans are checked
//! bit-identical to the scenario's serial reference BEFORE any timing
//! loop runs — a divergent engine fails the run immediately with exit
//! code 1, so a fast-but-wrong engine can never post a number. The
//! harness emits a machine-readable `BENCH_multijob.json` (schema
//! documented in `docs/BENCHMARKS.md`); incremental rows carry an
//! additive `memo` object recording hit/miss/invalidation counters and
//! the per-round scoring trajectory, and sharded rows carry an additive
//! `fabric` object with the scoring-pool counters (workers, waves
//! inline/dispatched, chunks, queue depth high-water mark, scratch
//! allocations), so pool behavior is part of the recorded perf history.
//!
//! With `DCFLOW_TRACE=1` the run additionally captures a structured
//! telemetry trace (see `dcflow::obs`): after the matrix completes, the
//! first scenario is re-planned once on a fixed sharded/incremental
//! configuration, the resulting span tree is validated, and the trace is
//! written as versioned JSONL (`--trace-out`) plus a Chrome trace-event
//! file (`--chrome-out`, loadable in `chrome://tracing` / Perfetto). The
//! report then carries an additive `telemetry` object with the trace
//! summary and a metrics-registry snapshot; with tracing off the object
//! is just `{"enabled": false}`.
//!
//! ```text
//! cargo run --release --example multijob_bench            # full matrix
//! cargo run --release --example multijob_bench -- --smoke # CI smoke
//! cargo run --release --example multijob_bench -- --out target/BENCH_multijob.json
//! DCFLOW_TRACE=1 cargo run --release --example multijob_bench -- --smoke
//! ```

use std::collections::BTreeMap;

use dcflow::prelude::*;
use dcflow::util::bench::bench;
use dcflow::util::cli::Cli;
use dcflow::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// One row of the bench matrix's scenario axis: a job set + a pool.
struct BenchScenario {
    name: &'static str,
    jobs: Vec<Workflow>,
    servers: Vec<Server>,
}

fn scenarios() -> Vec<BenchScenario> {
    // heterogeneous pool: the paper's Fig. 6 job plus tandem / fork-join
    // companions. Four jobs, not two, even in smoke: with fewer jobs a
    // single applied swap touches every plan and the memo can never hit,
    // so the smoke run would not exercise the incremental engine's whole
    // point. Smoke keeps its cost down via the pinned coarse grid.
    let hetero = BenchScenario {
        name: "hetero_pool",
        jobs: vec![
            Workflow::fig6(),
            Workflow::tandem(3, 1.0),
            Workflow::forkjoin(2, 2.0),
            Workflow::tandem(2, 3.0),
        ],
        servers: Server::pool_exponential(&[
            18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
        ]),
    };

    // DAG pipeline: the zoo's TTSP-reduced stage graph (8 slots) plus a
    // small tandem rider, on the zoo's 10-server pool + 2 extras
    let dag = BenchScenario {
        name: "dag_pipeline",
        jobs: vec![
            ScenarioSpec::by_name("dag_pipeline")
                .expect("zoo scenario exists")
                .workflow(),
            Workflow::tandem(2, 0.6),
        ],
        servers: Server::pool_exponential(&[
            14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.5, 2.0,
        ]),
    };

    // heavy-tail pool: Table-1 delayed-tail laws at uncomfortable
    // parameters (the regime where FFT-grid scoring earns its keep)
    let heavy = BenchScenario {
        name: "heavy_tail",
        jobs: vec![Workflow::chain(2, 2, 1.2), Workflow::tandem(2, 0.8)],
        servers: vec![
            Server::new(0, ServiceDist::exponential(3.0)),
            Server::new(1, ServiceDist::exponential(2.5)),
            Server::new(2, ServiceDist::straggler(8.0, 0.6, 0.2, 0.0)),
            Server::new(3, ServiceDist::exponential(2.0)),
            Server::new(4, ServiceDist::delayed_pareto(3.0, 0.02)),
            Server::new(5, ServiceDist::exponential(1.8)),
            Server::new(6, ServiceDist::exponential(1.5)),
            Server::new(7, ServiceDist::delayed_weibull(1.6, 0.7, 0.05)),
        ],
    };

    vec![hetero, dag, heavy]
}

/// Bit-level plan identity: allocation, grid, and score bits must all
/// agree (`to_bits`, not `==`, so a `-0.0`/`0.0` slip is caught too).
fn plans_identical(got: &[JobPlan], reference: &[JobPlan]) -> bool {
    got.len() == reference.len()
        && got.iter().zip(reference.iter()).all(|(g, r)| {
            g.alloc == r.alloc
                && g.score.mean.to_bits() == r.score.mean.to_bits()
                && g.score.p99.to_bits() == r.score.p99.to_bits()
                && g.grid == r.grid
        })
}

/// Everything needed to write `BENCH_multijob.json`, bundled so the
/// report can also be flushed mid-run when an engine diverges.
struct ReportCtx {
    out_path: String,
    cpus: usize,
    iters: usize,
    warmup: usize,
    pinned: Option<GridSpec>,
    smoke: bool,
}

impl ReportCtx {
    fn write(&self, scenario_cfgs: &[Json], results: &[Json], identical: bool, telemetry: &Json) {
        let grid_json = match self.pinned {
            Some(g) => obj(vec![("dt", Json::Num(g.dt)), ("n", Json::Num(g.n as f64))]),
            None => Json::Str("auto".into()),
        };
        let report = obj(vec![
            ("bench", Json::Str("multijob_matrix".into())),
            ("crate_version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            (
                "config",
                obj(vec![
                    ("scenarios", Json::Arr(scenario_cfgs.to_vec())),
                    ("cpus", Json::Num(self.cpus as f64)),
                    ("swap_rounds", Json::Num(MultiJobConfig::default().swap_rounds as f64)),
                    ("max_wave", Json::Num(MultiJobConfig::default().max_wave as f64)),
                    ("iters", Json::Num(self.iters as f64)),
                    ("warmup", Json::Num(self.warmup as f64)),
                    ("grid", grid_json),
                    ("smoke", Json::Bool(self.smoke)),
                ]),
            ),
            ("results", Json::Arr(results.to_vec())),
            ("identical", Json::Bool(identical)),
            ("telemetry", telemetry.clone()),
        ]);
        std::fs::write(&self.out_path, report.to_string() + "\n").expect("write BENCH json");
    }
}

fn main() {
    let cli = Cli::new(
        "multijob_bench",
        "scenario x engine x dispatch x shards multi-job swap matrix, JSON output",
    )
    .opt("out", "BENCH_multijob.json", "output path for the JSON report")
    .opt(
        "trace-out",
        "TRACE_multijob.jsonl",
        "telemetry JSONL path (written when DCFLOW_TRACE=1)",
    )
    .opt(
        "chrome-out",
        "TRACE_multijob.chrome.json",
        "Chrome trace-event path (written when DCFLOW_TRACE=1)",
    )
    .opt("iters", "3", "measured iterations per configuration")
    .opt("warmup", "1", "unmeasured warmup iterations")
    .flag("smoke", "pinned coarse grid + 1 iteration (CI smoke run)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let out_path = args.get("out").to_string();
    let trace_out = args.get("trace-out").to_string();
    let chrome_out = args.get("chrome-out").to_string();
    let smoke = args.has("smoke");
    // --smoke only lowers the *defaults*; explicitly passed --iters or
    // --warmup always win
    let passed = |name: &str| {
        argv.iter()
            .any(|a| a == &format!("--{name}") || a.starts_with(&format!("--{name}=")))
    };
    let iters: usize = if smoke && !passed("iters") {
        1
    } else {
        args.get_as("iters").expect("--iters")
    };
    let warmup: usize = if smoke && !passed("warmup") {
        0
    } else {
        args.get_as("warmup").expect("--warmup")
    };

    // the smoke run pins a coarse grid so CI measures the engine, not
    // the FFTs; the full run keeps the auto-sized shared grid
    let pinned = if smoke { Some(GridSpec::new(0.05, 256)) } else { None };
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let ctx = ReportCtx {
        out_path,
        cpus,
        iters,
        warmup,
        pinned,
        smoke,
    };

    let matrix = scenarios();
    println!(
        "multijob_bench: {} scenarios, {cpus} cpus, iters {iters}, warmup {warmup}{}",
        matrix.len(),
        if smoke { " (smoke)" } else { "" }
    );

    let mut results: Vec<Json> = Vec::new();
    let mut scenario_cfgs: Vec<Json> = Vec::new();

    for sc in &matrix {
        let jobs: Vec<&Workflow> = sc.jobs.iter().collect();
        scenario_cfgs.push(obj(vec![
            ("name", Json::Str(sc.name.into())),
            ("jobs", Json::Num(jobs.len() as f64)),
            ("servers", Json::Num(sc.servers.len() as f64)),
        ]));

        // serial reference pass for this scenario
        let mut serial_planner = Planner::new(jobs[0], &sc.servers)
            .objective(Objective::Mean)
            .swap_engine(SwapEngine::Serial);
        if let Some(g) = ctx.pinned {
            serial_planner = serial_planner.grid(g);
        }
        let reference = serial_planner.plan_jobs(&jobs).expect("job set is feasible");
        let t_serial = bench(warmup, iters, || serial_planner.plan_jobs(&jobs).unwrap());
        let ref_objective = cluster_objective(&reference, &jobs, Objective::Mean);
        println!(
            "  {:<12} {:<16}: {:>10.6} s  (objective {:.4})",
            sc.name, "serial", t_serial.mean_s, ref_objective
        );
        results.push(obj(vec![
            ("scenario", Json::Str(sc.name.into())),
            ("engine", Json::Str("serial".into())),
            ("shards", Json::Num(1.0)),
            ("mean_s", Json::Num(t_serial.mean_s)),
            ("std_s", Json::Num(t_serial.std_s)),
            ("speedup_vs_serial", Json::Num(1.0)),
            ("cluster_objective", Json::Num(ref_objective)),
        ]));

        // wave and incremental engines × dispatch modes × shard counts
        for (engine_name, engine) in [
            ("wave", SwapEngine::Wave),
            ("incremental", SwapEngine::Incremental),
        ] {
            for (dispatch_name, dispatch) in [
                ("pooled", Dispatch::Pooled),
                ("scoped", Dispatch::SpawnPerWave),
            ] {
                for shards in [1usize, 2, 8] {
                    let backend =
                        ShardedBackend::new(&AnalyticBackend, shards).dispatch(dispatch);
                    let mut planner = Planner::new(jobs[0], &sc.servers)
                        .objective(Objective::Mean)
                        .backend(&backend)
                        .swap_engine(engine);
                    if let Some(g) = ctx.pinned {
                        planner = planner.grid(g);
                    }
                    // identity is the gate, timing is the payload: check
                    // the plans against the serial reference BEFORE any
                    // timing loop so a divergent engine can never post a
                    // number
                    let (got, stats) =
                        planner.plan_jobs_report(&jobs).expect("job set is feasible");
                    if !plans_identical(&got, &reference) {
                        eprintln!(
                            "multijob_bench: {engine_name} {dispatch_name} x{shards} plans \
                             diverged from the serial reference on scenario '{}'",
                            sc.name
                        );
                        let tele = obj(vec![("enabled", Json::Bool(dcflow::obs::enabled()))]);
                        ctx.write(&scenario_cfgs, &results, false, &tele);
                        std::process::exit(1);
                    }
                    // every side is accounted for: fresh + memo = 2 sides
                    // per candidate exchange, every round, any engine
                    for (i, r) in stats.rounds.iter().enumerate() {
                        assert_eq!(
                            r.scored + r.memo_hits,
                            2 * r.candidates,
                            "'{}' {engine_name} {dispatch_name} x{shards} round {i}: \
                             side accounting broke",
                            sc.name
                        );
                    }
                    // when pairs survive round 1 untouched the memo must
                    // actually pay: hits land in round 2 and scoring work
                    // drops below the 2-sides-per-candidate ceiling
                    if engine == SwapEngine::Incremental
                        && stats.rounds.len() >= 2
                        && jobs.len() >= 2 * stats.rounds[0].applied + 2
                    {
                        assert!(
                            stats.rounds[1].memo_hits > 0 && stats.hit_rate() > 0.0,
                            "'{}' x{shards}: pairs survived round 1 untouched but the memo \
                             never hit",
                            sc.name
                        );
                        assert!(
                            stats.rounds[1].scored < 2 * stats.rounds[1].candidates,
                            "'{}' x{shards}: memo hits saved no scoring work after round 1",
                            sc.name
                        );
                    }
                    let t = bench(warmup, iters, || planner.plan_jobs(&jobs).unwrap());
                    let objective = cluster_objective(&got, &jobs, Objective::Mean);
                    let label = format!("{engine_name} {dispatch_name} x{shards}");
                    if engine == SwapEngine::Incremental {
                        println!(
                            "  {:<12} {label:<24}: {:>10.6} s  (speedup {:.2}x, memo hit \
                             rate {:.3})",
                            sc.name,
                            t.mean_s,
                            t_serial.mean_s / t.mean_s,
                            stats.hit_rate()
                        );
                    } else {
                        println!(
                            "  {:<12} {label:<24}: {:>10.6} s  (speedup {:.2}x)",
                            sc.name,
                            t.mean_s,
                            t_serial.mean_s / t.mean_s
                        );
                    }
                    let mut row = vec![
                        ("scenario", Json::Str(sc.name.into())),
                        ("engine", Json::Str(engine_name.into())),
                        ("dispatch", Json::Str(dispatch_name.into())),
                        ("shards", Json::Num(shards as f64)),
                        ("mean_s", Json::Num(t.mean_s)),
                        ("std_s", Json::Num(t.std_s)),
                        ("speedup_vs_serial", Json::Num(t_serial.mean_s / t.mean_s)),
                        ("cluster_objective", Json::Num(objective)),
                    ];
                    if engine == SwapEngine::Incremental {
                        let rounds_json: Vec<Json> = stats
                            .rounds
                            .iter()
                            .map(|r| {
                                obj(vec![
                                    ("candidates", Json::Num(r.candidates as f64)),
                                    ("scored", Json::Num(r.scored as f64)),
                                    ("memo_hits", Json::Num(r.memo_hits as f64)),
                                    ("applied", Json::Num(r.applied as f64)),
                                ])
                            })
                            .collect();
                        row.push((
                            "memo",
                            obj(vec![
                                ("hits", Json::Num(stats.memo_hits as f64)),
                                ("misses", Json::Num(stats.memo_misses as f64)),
                                ("invalidated", Json::Num(stats.memo_invalidated as f64)),
                                ("hit_rate", Json::Num(stats.hit_rate())),
                                ("rounds", Json::Arr(rounds_json)),
                            ]),
                        ));
                    }
                    // fabric counters (workers, inline/dispatched waves,
                    // chunks, queue depth, scratch allocs) — cumulative
                    // over the identity-gate call, captured before timing
                    if let Some(fs) = stats.fabric {
                        row.push((
                            "fabric",
                            obj(vec![
                                ("workers", Json::Num(fs.workers as f64)),
                                ("pinned", Json::Bool(fs.pinned)),
                                ("waves_inline", Json::Num(fs.waves_inline as f64)),
                                ("waves_dispatched", Json::Num(fs.waves_dispatched as f64)),
                                ("chunks_dispatched", Json::Num(fs.chunks_dispatched as f64)),
                                ("max_queue_depth", Json::Num(fs.max_queue_depth as f64)),
                                ("scratch_allocs", Json::Num(fs.scratch_allocs as f64)),
                            ]),
                        ));
                    }
                    results.push(obj(row));
                }
            }
        }
    }

    // telemetry capture: with DCFLOW_TRACE=1 the matrix above already
    // ran instrumented, but its events interleave every configuration.
    // Discard those, re-plan the first scenario once on a fixed
    // sharded/incremental configuration so the exported trace is one
    // clean plan → swap-round → wave → chunk tree, validate it, and
    // write the JSONL + Chrome exports.
    let telemetry = if dcflow::obs::enabled() {
        let _ = dcflow::obs::drain();
        let sc = &matrix[0];
        let jobs: Vec<&Workflow> = sc.jobs.iter().collect();
        let backend = ShardedBackend::new(&AnalyticBackend, 2).min_parallel_wave(2);
        let mut planner = Planner::new(jobs[0], &sc.servers)
            .objective(Objective::Mean)
            .backend(&backend)
            .swap_engine(SwapEngine::Incremental);
        if let Some(g) = ctx.pinned {
            planner = planner.grid(g);
        }
        planner.plan_jobs(&jobs).expect("job set is feasible");
        let events = dcflow::obs::drain();
        let summary = match dcflow::obs::validate(&events) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("multijob_bench: telemetry trace failed validation: {e}");
                std::process::exit(1);
            }
        };
        std::fs::write(&trace_out, dcflow::obs::to_jsonl(&events))
            .expect("write telemetry JSONL");
        std::fs::write(&chrome_out, dcflow::obs::to_chrome_trace(&events))
            .expect("write Chrome trace");
        println!(
            "wrote {trace_out} + {chrome_out} ({} spans, max depth {})",
            summary.spans, summary.max_depth
        );
        // registry snapshot: counters are cumulative over the whole
        // process (matrix + traced re-run), which is what we want in a
        // perf-history artifact
        let snap = dcflow::obs::registry().snapshot();
        let mut counters = BTreeMap::new();
        for (name, v) in snap.counters {
            counters.insert(name, Json::Num(v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, v) in snap.gauges {
            gauges.insert(name, Json::Num(v));
        }
        let mut hists = BTreeMap::new();
        for (name, h) in snap.histograms {
            hists.insert(
                name,
                obj(vec![
                    ("count", Json::Num(h.count as f64)),
                    ("mean", Json::Num(h.mean())),
                    ("p50", Json::Num(h.p50())),
                    ("p99", Json::Num(h.p99())),
                ]),
            );
        }
        obj(vec![
            ("enabled", Json::Bool(true)),
            ("scenario", Json::Str(sc.name.into())),
            ("spans", Json::Num(summary.spans as f64)),
            ("instants", Json::Num(summary.instants as f64)),
            ("warns", Json::Num(summary.warns as f64)),
            ("roots", Json::Num(summary.roots as f64)),
            ("max_depth", Json::Num(summary.max_depth as f64)),
            ("trace_jsonl", Json::Str(trace_out.clone())),
            ("trace_chrome", Json::Str(chrome_out.clone())),
            (
                "registry",
                obj(vec![
                    ("counters", Json::Obj(counters)),
                    ("gauges", Json::Obj(gauges)),
                    ("histograms", Json::Obj(hists)),
                ]),
            ),
        ])
    } else {
        obj(vec![("enabled", Json::Bool(false))])
    };

    // a divergence exits above, so reaching this point means every
    // engine × dispatch × shards configuration matched its serial
    // reference
    ctx.write(&scenario_cfgs, &results, true, &telemetry);
    println!("wrote {} (identical: true)", ctx.out_path);
}
