//! Reproducible multi-job swap benchmark harness.
//!
//! Runs the cross-job swap refinement serial reference pass and the
//! wave engine across shard counts {1, 2, 8} on a fixed job set,
//! verifies every configuration produces bit-identical plans, and
//! emits a machine-readable `BENCH_multijob.json` (schema documented
//! in `docs/BENCHMARKS.md`) so the perf trajectory of the multi-job
//! engine is recorded, not anecdotal.
//!
//! ```text
//! cargo run --release --example multijob_bench            # full grid
//! cargo run --release --example multijob_bench -- --smoke # CI smoke
//! cargo run --release --example multijob_bench -- --out target/BENCH_multijob.json
//! ```

use std::collections::BTreeMap;

use dcflow::prelude::*;
use dcflow::util::bench::bench;
use dcflow::util::cli::Cli;
use dcflow::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn main() {
    let cli = Cli::new(
        "multijob_bench",
        "serial vs wave-batched multi-job swap refinement, JSON output",
    )
    .opt("out", "BENCH_multijob.json", "output path for the JSON report")
    .opt("iters", "3", "measured iterations per configuration")
    .opt("warmup", "1", "unmeasured warmup iterations")
    .flag("smoke", "tiny job set + pinned coarse grid (CI smoke run)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let out_path = args.get("out").to_string();
    let smoke = args.has("smoke");
    // --smoke only lowers the *defaults*; explicitly passed --iters or
    // --warmup always win
    let passed = |name: &str| {
        argv.iter()
            .any(|a| a == &format!("--{name}") || a.starts_with(&format!("--{name}=")))
    };
    let iters: usize = if smoke && !passed("iters") {
        1
    } else {
        args.get_as("iters").expect("--iters")
    };
    let warmup: usize = if smoke && !passed("warmup") {
        0
    } else {
        args.get_as("warmup").expect("--warmup")
    };

    // fixed, versioned workload: the paper's Fig. 6 job plus light
    // tandem/fork-join companions over a heterogeneous pool
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let j4 = Workflow::tandem(2, 3.0);
    let full_jobs = [&j1, &j2, &j3, &j4];
    let smoke_jobs = [&j1, &j2];
    let jobs: &[&Workflow] = if smoke { &smoke_jobs } else { &full_jobs };
    let servers = if smoke {
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0])
    } else {
        Server::pool_exponential(&[
            18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
        ])
    };
    // the smoke run pins a coarse grid so CI measures the engine, not
    // the FFTs; the full run keeps the auto-sized shared grid
    let pinned = if smoke { Some(GridSpec::new(0.05, 256)) } else { None };
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!(
        "multijob_bench: {} jobs, {} servers, {cpus} cpus, iters {iters}, warmup {warmup}{}",
        jobs.len(),
        servers.len(),
        if smoke { " (smoke)" } else { "" }
    );

    // serial reference pass
    let mut serial_planner = Planner::new(&j1, &servers)
        .objective(Objective::Mean)
        .swap_engine(SwapEngine::Serial);
    if let Some(g) = pinned {
        serial_planner = serial_planner.grid(g);
    }
    let reference = serial_planner.plan_jobs(jobs).expect("job set is feasible");
    let t_serial = bench(warmup, iters, || serial_planner.plan_jobs(jobs).unwrap());
    let ref_objective = cluster_objective(&reference, jobs, Objective::Mean);
    println!(
        "  serial      : {:>10.6} s  (objective {:.4})",
        t_serial.mean_s, ref_objective
    );

    let mut results: Vec<Json> = vec![obj(vec![
        ("engine", Json::Str("serial".into())),
        ("shards", Json::Num(1.0)),
        ("mean_s", Json::Num(t_serial.mean_s)),
        ("std_s", Json::Num(t_serial.std_s)),
        ("speedup_vs_serial", Json::Num(1.0)),
        ("cluster_objective", Json::Num(ref_objective)),
    ])];

    // wave engine × shard counts, each checked bit-identical first
    let mut identical = true;
    for shards in [1usize, 2, 8] {
        let backend = ShardedBackend::new(&AnalyticBackend, shards);
        let mut planner = Planner::new(&j1, &servers)
            .objective(Objective::Mean)
            .backend(&backend);
        if let Some(g) = pinned {
            planner = planner.grid(g);
        }
        let got = planner.plan_jobs(jobs).expect("job set is feasible");
        let same = got.len() == reference.len()
            && got.iter().zip(reference.iter()).all(|(g, r)| {
                g.alloc == r.alloc
                    && g.score.mean == r.score.mean
                    && g.score.p99 == r.score.p99
                    && g.grid == r.grid
            });
        identical &= same;
        let t = bench(warmup, iters, || planner.plan_jobs(jobs).unwrap());
        let objective = cluster_objective(&got, jobs, Objective::Mean);
        println!(
            "  wave x{shards:<2}    : {:>10.6} s  (speedup {:.2}x, identical: {same})",
            t.mean_s,
            t_serial.mean_s / t.mean_s
        );
        results.push(obj(vec![
            ("engine", Json::Str("wave".into())),
            ("shards", Json::Num(shards as f64)),
            ("mean_s", Json::Num(t.mean_s)),
            ("std_s", Json::Num(t.std_s)),
            ("speedup_vs_serial", Json::Num(t_serial.mean_s / t.mean_s)),
            ("cluster_objective", Json::Num(objective)),
        ]));
    }

    let grid_json = match pinned {
        Some(g) => obj(vec![("dt", Json::Num(g.dt)), ("n", Json::Num(g.n as f64))]),
        None => Json::Str("auto".into()),
    };
    let report = obj(vec![
        ("bench", Json::Str("multijob_swap".into())),
        ("crate_version", Json::Str(env!("CARGO_PKG_VERSION").into())),
        (
            "config",
            obj(vec![
                ("jobs", Json::Num(jobs.len() as f64)),
                ("servers", Json::Num(servers.len() as f64)),
                ("cpus", Json::Num(cpus as f64)),
                ("swap_rounds", Json::Num(MultiJobConfig::default().swap_rounds as f64)),
                ("max_wave", Json::Num(MultiJobConfig::default().max_wave as f64)),
                ("iters", Json::Num(iters as f64)),
                ("warmup", Json::Num(warmup as f64)),
                ("grid", grid_json),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        ("results", Json::Arr(results)),
        ("identical", Json::Bool(identical)),
    ]);

    std::fs::write(&out_path, report.to_string() + "\n").expect("write BENCH json");
    println!("wrote {out_path} (identical: {identical})");
    if !identical {
        eprintln!("multijob_bench: wave plans diverged from the serial reference");
        std::process::exit(1);
    }
}
