//! Heterogeneous-cluster study: where does stochastic allocation matter?
//!
//! Sweeps (a) load and (b) service-law heterogeneity on the Fig. 6
//! workflow and prints the mean/variance of all four policies, exposing
//! the crossover structure the paper's Table 2 summarizes with three
//! scenarios. Also demonstrates JSON workflow specs end to end — all of
//! it through the `Planner` builder.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use dcflow::prelude::*;

fn fig6_scaled(k: f64) -> Workflow {
    let root = Dcc::serial_with_rates(
        vec![
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::serial(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
        ],
        vec![Some(8.0 * k), Some(4.0 * k), Some(2.0 * k)],
    );
    Workflow::new(root, 8.0 * k).expect("valid")
}

fn sweep(servers: &[Server], model: ResponseModel, label: &str) {
    println!("\n--- {label} ---");
    println!(
        "{:>5} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9}",
        "load", "proposed", "baseline", "fair-base", "optimal", "var:prop", "var:base"
    );
    let fair = BaselinePolicy {
        split: SplitPolicy::Equilibrium,
    };
    for k in [0.6, 0.9, 1.0, 1.1, 1.2, 1.35, 1.5] {
        let wf = fig6_scaled(k);
        // every policy on one common grid, straight off the builder
        let results = Planner::new(&wf, servers).model(model).compare(&[
            &ProposedPolicy::default(),
            &BaselinePolicy::default(),
            &fair,
            &OptimalPolicy,
        ]);
        let mv = |r: &Result<Plan, SchedError>| -> (f64, f64) {
            r.as_ref()
                .map(|p| (p.score.mean, p.score.var))
                .unwrap_or((f64::INFINITY, f64::INFINITY))
        };
        let (pm, pv) = mv(&results[0]);
        let (bm, bv) = mv(&results[1]);
        let (fm, _) = mv(&results[2]);
        let (om, _) = mv(&results[3]);
        println!(
            "{:>5.2} | {:>9.3} {:>9.3} {:>9.3} {:>9.3} | {:>9.3} {:>9.3}",
            k, pm, bm, fm, om, pv, bv
        );
    }
}

fn main() {
    let model = ResponseModel::Mm1;

    // Scenario A: the paper's exact pool (mild heterogeneity 2.25x)
    sweep(
        &Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        model,
        "scenario A: paper pool mu = 9..4 (exponential)",
    );

    // Scenario B: strong heterogeneity (6x speed spread)
    sweep(
        &Server::pool_exponential(&[18.0, 12.0, 9.0, 6.0, 4.0, 3.0]),
        model,
        "scenario B: strong heterogeneity mu = 18..3",
    );

    // Scenario C: mixed Table-1 laws (delayed exp + pareto + straggler)
    let mixed = vec![
        Server::new(0, ServiceDist::delayed_exponential(12.0, 0.02)),
        Server::new(1, ServiceDist::delayed_exponential(9.0, 0.05)),
        Server::new(2, ServiceDist::delayed_pareto(8.0, 0.02)),
        Server::new(3, ServiceDist::delayed_pareto(6.0, 0.05)),
        Server::new(
            4,
            ServiceDist::multimodal(vec![
                (0.9, Mode::continuous(8.0, 0.02, TailKind::Exponential)),
                (0.1, Mode::continuous(1.2, 0.3, TailKind::Exponential)),
            ]),
        ),
        Server::new(5, ServiceDist::straggler(6.0, 0.8, 0.08, 0.02)),
    ];
    sweep(&mixed, ResponseModel::Mg1, "scenario C: mixed Table-1 laws (M/G/1 model)");

    // JSON spec straight into the planner
    let spec = r#"{
        "arrival_rate": 4.0,
        "root": {"type": "serial", "children": [
            {"type": "parallel", "rate": 4.0,
             "children": [{"type": "queue"}, {"type": "queue"}, {"type": "queue"}]},
            {"type": "queue", "rate": 2.0}
        ]}
    }"#;
    let wf = Workflow::from_json(spec).expect("valid spec");
    let pool = Server::pool_exponential(&[10.0, 7.0, 5.0, 4.0]);
    let plan = Planner::new(&wf, &pool)
        .model(model)
        .plan(&ProposedPolicy::default())
        .expect("feasible");
    println!(
        "\nJSON workflow ({} slots): proposed mean={:.4} var={:.4}; slots -> servers {:?}",
        wf.slots(),
        plan.score.mean,
        plan.score.var,
        plan.allocation.slot_server
    );
}
