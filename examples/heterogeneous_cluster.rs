//! Heterogeneous-cluster study: where does stochastic allocation matter?
//!
//! Sweeps (a) load and (b) service-law heterogeneity on the Fig. 6
//! workflow and prints the mean/variance of all four policies, exposing
//! the crossover structure the paper's Table 2 summarizes with three
//! scenarios. Also demonstrates JSON workflow specs end to end.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use dcflow::compose::grid::GridSpec;
use dcflow::compose::score::score_allocation_with;
use dcflow::dist::{Mode, ServiceDist, TailKind};
use dcflow::flow::parse::workflow_from_json;
use dcflow::flow::{Dcc, Workflow};
use dcflow::sched::server::Server;
use dcflow::sched::{
    baseline_allocate, baseline_allocate_split, optimal_allocate, proposed_allocate,
    Allocation, Objective, ResponseModel, SchedError, SplitPolicy,
};

fn fig6_scaled(k: f64) -> Workflow {
    let root = Dcc::serial_with_rates(
        vec![
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::serial(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
        ],
        vec![Some(8.0 * k), Some(4.0 * k), Some(2.0 * k)],
    );
    Workflow::new(root, 8.0 * k).expect("valid")
}

fn score(
    wf: &Workflow,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
    r: Result<Allocation, SchedError>,
) -> (f64, f64) {
    match r {
        Ok(a) => {
            let s = score_allocation_with(wf, &a, servers, grid, model);
            (s.mean, s.var)
        }
        Err(_) => (f64::INFINITY, f64::INFINITY),
    }
}

fn sweep(servers: &[Server], model: ResponseModel, label: &str) {
    println!("\n--- {label} ---");
    println!(
        "{:>5} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9}",
        "load", "proposed", "baseline", "fair-base", "optimal", "var:prop", "var:base"
    );
    for k in [0.6, 0.9, 1.0, 1.1, 1.2, 1.35, 1.5] {
        let wf = fig6_scaled(k);
        let ours = proposed_allocate(&wf, servers, model, Objective::Mean);
        let grid = match &ours {
            Ok((a, _)) => GridSpec::auto_response(a, servers, model),
            Err(_) => GridSpec::auto_pool(&wf, servers),
        };
        let (pm, pv) = match ours {
            Ok((a, _)) => score(&wf, servers, &grid, model, Ok(a)),
            Err(e) => score(&wf, servers, &grid, model, Err(e)),
        };
        let (bm, bv) = score(&wf, servers, &grid, model, baseline_allocate(&wf, servers, model));
        let (fm, _) = score(
            &wf,
            servers,
            &grid,
            model,
            baseline_allocate_split(&wf, servers, model, SplitPolicy::Equilibrium),
        );
        let (om, _) = match optimal_allocate(&wf, servers, &grid, Objective::Mean, model) {
            Ok((_, s)) => (s.mean, s.var),
            Err(_) => (f64::INFINITY, f64::INFINITY),
        };
        println!(
            "{:>5.2} | {:>9.3} {:>9.3} {:>9.3} {:>9.3} | {:>9.3} {:>9.3}",
            k, pm, bm, fm, om, pv, bv
        );
    }
}

fn main() {
    let model = ResponseModel::Mm1;

    // Scenario A: the paper's exact pool (mild heterogeneity 2.25x)
    sweep(
        &Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        model,
        "scenario A: paper pool mu = 9..4 (exponential)",
    );

    // Scenario B: strong heterogeneity (6x speed spread)
    sweep(
        &Server::pool_exponential(&[18.0, 12.0, 9.0, 6.0, 4.0, 3.0]),
        model,
        "scenario B: strong heterogeneity mu = 18..3",
    );

    // Scenario C: mixed Table-1 laws (delayed exp + pareto + straggler)
    let mixed = vec![
        Server::new(0, ServiceDist::delayed_exponential(12.0, 0.02)),
        Server::new(1, ServiceDist::delayed_exponential(9.0, 0.05)),
        Server::new(2, ServiceDist::delayed_pareto(8.0, 0.02)),
        Server::new(3, ServiceDist::delayed_pareto(6.0, 0.05)),
        Server::new(
            4,
            ServiceDist::multimodal(vec![
                (0.9, Mode::continuous(8.0, 0.02, TailKind::Exponential)),
                (0.1, Mode::continuous(1.2, 0.3, TailKind::Exponential)),
            ]),
        ),
        Server::new(5, ServiceDist::straggler(6.0, 0.8, 0.08, 0.02)),
    ];
    sweep(&mixed, ResponseModel::Mg1, "scenario C: mixed Table-1 laws (M/G/1 model)");

    // JSON spec round-trip demo
    let spec = r#"{
        "arrival_rate": 4.0,
        "root": {"type": "serial", "children": [
            {"type": "parallel", "rate": 4.0,
             "children": [{"type": "queue"}, {"type": "queue"}, {"type": "queue"}]},
            {"type": "queue", "rate": 2.0}
        ]}
    }"#;
    let wf = workflow_from_json(spec).expect("valid spec");
    let pool = Server::pool_exponential(&[10.0, 7.0, 5.0, 4.0]);
    let (alloc, s) =
        proposed_allocate(&wf, &pool, model, Objective::Mean).expect("feasible");
    println!(
        "\nJSON workflow ({} slots): proposed mean={:.4} var={:.4}; slots -> servers {:?}",
        wf.slots(),
        s.mean,
        s.var,
        alloc.slot_server
    );
}
