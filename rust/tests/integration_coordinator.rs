//! Coordinator integration: the full Algorithm-3 loop under failures,
//! drift and bursty load.

use dcflow::coordinator::{
    Coordinator, CoordinatorConfig, Policy, WorkerSpec,
};
use dcflow::dist::ServiceDist;
use dcflow::flow::{Dcc, Workflow};
use dcflow::sched::server::Server;
use dcflow::sim::trace::{ArrivalProcess, Trace};
use dcflow::util::rng::Rng;

fn poisson(rate: f64, n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    Trace::generate(ArrivalProcess::Poisson { rate }, n, &mut rng)
}

#[test]
fn adaptive_beats_static_under_degradation() {
    // server degrades mid-run; adaptive coordinator must end up better
    let rates = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0];
    let build = |adaptive: bool| {
        let specs: Vec<WorkerSpec> = rates
            .iter()
            .enumerate()
            .map(|(i, &mu)| {
                if i == 0 {
                    WorkerSpec::drifting(
                        i,
                        ServiceDist::exponential(mu),
                        5_000,
                        ServiceDist::exponential(1.2),
                    )
                } else {
                    WorkerSpec::stable(i, ServiceDist::exponential(mu))
                }
            })
            .collect();
        let cfg = CoordinatorConfig {
            seed: 11,
            policy: Policy::Proposed,
            reopt_every: if adaptive { 800 } else { 0 },
            monitor_window: 1_536,
            min_fit_samples: 256,
            ..Default::default()
        };
        let mut coord = Coordinator::new(specs, Server::pool_exponential(&rates), cfg);
        let job = coord.submit("fig6", Workflow::fig6());
        let trace = poisson(2.0, 30_000, 21);
        let r = coord.run_job(&job, &trace).unwrap();
        coord.shutdown();
        r
    };
    let adaptive = build(true);
    let static_ = build(false);
    assert!(adaptive.metrics.reoptimizations >= 1, "no swap happened");
    // compare tail latency over the whole run: adaptation must help
    assert!(
        adaptive.metrics.latency_quantile(0.99) < static_.metrics.latency_quantile(0.99),
        "adaptive p99 {} vs static p99 {}",
        adaptive.metrics.latency_quantile(0.99),
        static_.metrics.latency_quantile(0.99)
    );
}

#[test]
fn coordinator_handles_bursty_arrivals() {
    let servers = Server::pool_exponential(&[10.0, 9.0, 8.0, 7.0, 6.0, 5.0]);
    let cfg = CoordinatorConfig {
        reopt_every: 0,
        ..Default::default()
    };
    let mut coord = Coordinator::with_truthful_priors(servers, cfg);
    let job = coord.submit("fig6", Workflow::fig6());
    let mut rng = Rng::new(5);
    let trace = Trace::generate(
        ArrivalProcess::Mmpp {
            base_rate: 1.0,
            burst_rate: 6.0,
            base_dwell: 20.0,
            burst_dwell: 5.0,
        },
        15_000,
        &mut rng,
    );
    let r = coord.run_job(&job, &trace).unwrap();
    coord.shutdown();
    assert_eq!(r.metrics.completed, 15_000);
    assert!(r.metrics.throughput() > 0.0);
    // bursty load must show a heavier tail than mean
    assert!(r.metrics.latency_quantile(0.99) > 2.0 * r.metrics.mean_latency());
}

#[test]
fn multi_stage_chain_workflow_runs() {
    // deeper chain than fig6: ingest -> 3-wide map -> shuffle -> reduce
    let root = Dcc::serial_with_rates(
        vec![
            Dcc::queue(),
            Dcc::parallel((0..3).map(|_| Dcc::queue()).collect()),
            Dcc::queue(),
            Dcc::queue(),
        ],
        vec![Some(3.0), Some(3.0), Some(1.5), Some(1.0)],
    );
    let wf = Workflow::new(root, 3.0).unwrap();
    let servers = Server::pool_exponential(&[12.0, 10.0, 8.0, 7.0, 6.0, 5.0]);
    let cfg = CoordinatorConfig {
        reopt_every: 0,
        ..Default::default()
    };
    let mut coord = Coordinator::with_truthful_priors(servers, cfg);
    let job = coord.submit("chain", wf);
    let r = coord.run_job(&job, &poisson(1.5, 8_000, 9)).unwrap();
    let served = coord.shutdown();
    assert_eq!(r.metrics.completed, 8_000);
    // every task touches all 6 slots
    assert_eq!(served.iter().sum::<u64>(), 8_000 * 6);
}

#[test]
fn optimal_policy_works_on_small_pools() {
    let servers = Server::pool_exponential(&[8.0, 6.0, 5.0]);
    let cfg = CoordinatorConfig {
        policy: Policy::Optimal,
        reopt_every: 0,
        ..Default::default()
    };
    let mut coord = Coordinator::with_truthful_priors(servers, cfg);
    let job = coord.submit("tandem", Workflow::tandem(3, 1.0));
    let r = coord.run_job(&job, &poisson(1.0, 5_000, 3)).unwrap();
    coord.shutdown();
    assert_eq!(r.metrics.completed, 5_000);
}

#[test]
fn overload_reported_as_error_not_hang() {
    let servers = Server::pool_exponential(&[2.0, 2.0, 2.0, 2.0, 2.0, 2.0]);
    let cfg = CoordinatorConfig::default();
    let mut coord = Coordinator::with_truthful_priors(servers, cfg);
    let job = coord.submit("fig6-overload", Workflow::fig6()); // λ=8 > capacity
    let err = coord.run_job(&job, &poisson(8.0, 100, 1));
    coord.shutdown();
    assert!(err.is_err(), "overloaded job must be rejected");
}

#[test]
fn monitors_converge_to_hidden_laws() {
    let rates = [9.0, 4.0];
    let specs: Vec<WorkerSpec> = rates
        .iter()
        .enumerate()
        .map(|(i, &mu)| WorkerSpec::stable(i, ServiceDist::exponential(mu)))
        .collect();
    // deliberately WRONG priors
    let priors = Server::pool_exponential(&[1.0, 1.0]);
    let cfg = CoordinatorConfig {
        reopt_every: 500,
        reopt_on_drift_only: false, // refresh aggressively
        min_fit_samples: 256,
        ..Default::default()
    };
    let mut coord = Coordinator::new(specs, priors, cfg);
    let job = coord.submit("fj", Workflow::forkjoin(2, 1.0));
    let _ = coord.run_job(&job, &poisson(1.0, 6_000, 7)).unwrap();
    // the believed pool must now be close to the hidden truth
    for (i, &mu) in rates.iter().enumerate() {
        let believed = coord.pool_view()[i].dist.mean();
        let truth = 1.0 / mu;
        assert!(
            (believed - truth).abs() < 0.15 * truth,
            "server {i}: believed mean {believed} vs truth {truth}"
        );
    }
    coord.shutdown();
}
