//! Golden-corpus integration tests for the scenario subsystem.
//!
//! `golden_corpus_matches_or_blesses` is the CI gate: with committed
//! corpus files present it replays each trace twice and fails on any
//! bit-level divergence from the committed summary; with files absent
//! it captures, verifies and writes them (bless-on-absence — commit the
//! generated `rust/tests/golden/` files to freeze behavior, see the
//! README there).

use dcflow::scenario::{
    check_or_bless, reports_identical, ExecTrace, GoldenStatus, ScenarioClass, ScenarioSpec,
};
use dcflow::util::prop;

#[test]
fn corpus_covers_every_scenario_class() {
    let zoo = ScenarioSpec::zoo();
    for class in ScenarioClass::all() {
        assert!(
            zoo.iter().any(|s| s.class == class),
            "no zoo entry for {class:?}"
        );
    }
}

#[test]
fn golden_corpus_matches_or_blesses() {
    for spec in ScenarioSpec::zoo() {
        match check_or_bless(&spec) {
            Ok(GoldenStatus::Match) => {}
            Ok(GoldenStatus::Blessed) => {
                eprintln!(
                    "blessed new golden corpus entry for '{}' — commit rust/tests/golden/",
                    spec.name
                );
            }
            Ok(GoldenStatus::Divergence(msg)) => panic!("golden divergence: {msg}"),
            Err(e) => panic!("corpus check for '{}' errored: {e}", spec.name),
        }
    }
}

#[test]
fn capture_replay_bit_identity_property() {
    // the acceptance property: for ANY scenario and seed, a captured
    // trace replays to bit-identical plans/metrics, twice, and the
    // re-captured trace closes the loop — including across the JSONL
    // wire format
    prop::run("capture/replay bit-identity", 8, |g| {
        let zoo = ScenarioSpec::zoo();
        let spec = g
            .choose(&zoo)
            .clone()
            .with_seed(g.usize_in(1, 1 << 20) as u64)
            .with_tasks(150);
        let (live, trace) = spec
            .capture()
            .unwrap_or_else(|e| panic!("{}: capture failed: {e}", spec.name));
        let wire = trace.to_jsonl();
        let decoded = ExecTrace::from_jsonl(&wire)
            .unwrap_or_else(|e| panic!("{}: trace parse failed: {e}", spec.name));
        assert_eq!(decoded, trace, "{}: JSONL round-trip", spec.name);

        let (r1, t1) = spec.replay(&decoded).expect("first replay");
        let (r2, t2) = spec.replay(&decoded).expect("second replay");
        assert!(
            reports_identical(&live, &r1),
            "{}: replay differs from live capture",
            spec.name
        );
        assert!(
            reports_identical(&r1, &r2),
            "{}: two replays disagree",
            spec.name
        );
        assert_eq!(t1, t2, "{}: re-captured traces disagree", spec.name);
        assert_eq!(t1, trace, "{}: capture/replay loop not closed", spec.name);
    });
}

#[test]
fn churn_scenario_records_membership_events() {
    let spec = ScenarioSpec::by_name("worker_churn").unwrap().with_tasks(180);
    let (report, trace) = spec.capture().expect("churn capture");
    assert_eq!(trace.churns(), 2, "join + leave must both be recorded");
    // the churn swap shows up in the swap history with its reason
    assert!(
        report.swaps.iter().any(|(_, r)| r == "churn"),
        "membership change must force a re-plan, swaps: {:?}",
        report.swaps
    );
    // the joiner (id 4) served between its join and its leave
    let scripts = trace.service_scripts();
    assert_eq!(scripts.len(), 5);
    assert!(
        !scripts[4].is_empty(),
        "joined worker never drew a single task"
    );
}

#[test]
fn straggler_scenario_detects_drift() {
    let spec = ScenarioSpec::by_name("correlated_stragglers").unwrap();
    let (report, trace) = spec.capture().expect("straggler capture");
    assert!(
        report.swaps.iter().any(|(_, r)| r == "drift"),
        "correlated straggler onset must trigger a drift swap, got {:?}",
        report.swaps
    );
    assert!(trace.reopts() >= 1);
}

#[test]
fn empirical_refit_plan_is_deterministic_and_measured() {
    let spec = ScenarioSpec::by_name("empirical_refit")
        .unwrap()
        .with_tasks(200);
    let (_, trace) = spec.capture().expect("refit capture");
    let p1 = spec.refit_plan(&trace).expect("refit plan feasible");
    let p2 = spec.refit_plan(&trace).expect("refit plan feasible");
    assert_eq!(p1.allocation.slot_server, p2.allocation.slot_server);
    assert_eq!(p1.score.mean.to_bits(), p2.score.mean.to_bits());
    assert_eq!(p1.score.p99.to_bits(), p2.score.p99.to_bits());
    assert!(p1.score.mean.is_finite() && p1.score.mean > 0.0);
}
