//! Golden-corpus integration tests for the scenario subsystem.
//!
//! `golden_corpus_matches_or_blesses` is the CI gate: with committed
//! corpus files present it replays each trace twice and fails on any
//! bit-level divergence from the committed summary; with files absent
//! it captures, verifies and writes them (bless-on-absence — commit the
//! generated `rust/tests/golden/` files to freeze behavior, see the
//! README there).

use dcflow::coordinator::{Coordinator, CoordinatorConfig, RunReport};
use dcflow::prelude::{Objective, ServeConfig, Server, Service, SwapEngine, Workflow};
use dcflow::scenario::{
    check_or_bless, golden, reports_identical, ExecTrace, GoldenStatus, ScenarioClass,
    ScenarioSpec,
};
use dcflow::sim::trace::{ArrivalProcess, Trace};
use dcflow::util::prop;
use dcflow::util::rng::Rng;

#[test]
fn corpus_covers_every_scenario_class() {
    let zoo = ScenarioSpec::zoo();
    for class in ScenarioClass::all() {
        assert!(
            zoo.iter().any(|s| s.class == class),
            "no zoo entry for {class:?}"
        );
    }
}

#[test]
fn golden_corpus_matches_or_blesses() {
    for spec in ScenarioSpec::zoo() {
        match check_or_bless(&spec) {
            Ok(GoldenStatus::Match) => {}
            Ok(GoldenStatus::Blessed) => {
                eprintln!(
                    "blessed new golden corpus entry for '{}' — commit rust/tests/golden/",
                    spec.name
                );
            }
            Ok(GoldenStatus::Divergence(msg)) => panic!("golden divergence: {msg}"),
            Err(e) => panic!("corpus check for '{}' errored: {e}", spec.name),
        }
    }
}

#[test]
fn capture_replay_bit_identity_property() {
    // the acceptance property: for ANY scenario and seed, a captured
    // trace replays to bit-identical plans/metrics, twice, and the
    // re-captured trace closes the loop — including across the JSONL
    // wire format
    prop::run("capture/replay bit-identity", 8, |g| {
        let zoo = ScenarioSpec::zoo();
        let spec = g
            .choose(&zoo)
            .clone()
            .with_seed(g.usize_in(1, 1 << 20) as u64)
            .with_tasks(150);
        let (live, trace) = spec
            .capture()
            .unwrap_or_else(|e| panic!("{}: capture failed: {e}", spec.name));
        let wire = trace.to_jsonl();
        let decoded = ExecTrace::from_jsonl(&wire)
            .unwrap_or_else(|e| panic!("{}: trace parse failed: {e}", spec.name));
        assert_eq!(decoded, trace, "{}: JSONL round-trip", spec.name);

        let (r1, t1) = spec.replay(&decoded).expect("first replay");
        let (r2, t2) = spec.replay(&decoded).expect("second replay");
        assert!(
            reports_identical(&live, &r1),
            "{}: replay differs from live capture",
            spec.name
        );
        assert!(
            reports_identical(&r1, &r2),
            "{}: two replays disagree",
            spec.name
        );
        assert_eq!(t1, t2, "{}: re-captured traces disagree", spec.name);
        assert_eq!(t1, trace, "{}: capture/replay loop not closed", spec.name);
    });
}

#[test]
fn golden_traces_replay_identically_under_every_swap_engine() {
    // the committed corpus is a standing regression gate for the swap
    // engines: every golden trace must replay to the same report and
    // re-captured trace no matter which engine the coordinator's
    // multi-job planner is configured with (capture/replay plan single
    // jobs, so any divergence here means an engine leaks into a path
    // it must not touch)
    for spec in ScenarioSpec::zoo() {
        let path = golden::corpus_dir().join(format!("{}.trace.jsonl", spec.name));
        let Ok(text) = std::fs::read_to_string(&path) else {
            // pre-bless tree: golden_corpus_matches_or_blesses creates
            // the corpus; nothing to cross-check yet
            continue;
        };
        let trace = ExecTrace::from_jsonl(&text)
            .unwrap_or_else(|e| panic!("{}: committed trace unreadable: {e}", spec.name));
        let (base_report, base_trace) = spec
            .replay(&trace)
            .unwrap_or_else(|e| panic!("{}: baseline replay failed: {e}", spec.name));
        for engine in [SwapEngine::Serial, SwapEngine::Incremental] {
            let espec = spec.clone().with_swap_engine(engine);
            let (report, recaptured) = espec
                .replay(&trace)
                .unwrap_or_else(|e| panic!("{}: {engine:?} replay failed: {e}", spec.name));
            assert!(
                reports_identical(&base_report, &report),
                "{}: replay under {engine:?} diverges from the default engine",
                spec.name
            );
            assert_eq!(
                recaptured, base_trace,
                "{}: re-captured trace under {engine:?} diverges",
                spec.name
            );
        }
    }
}

#[test]
fn serve_soak_golden_matches_or_blesses() {
    // the live re-planning service rides the same golden machinery as
    // the zoo: its short soak scenario gets a committed trace + summary
    // under its own corpus file stem
    let spec = ScenarioSpec::serve_soak_short();
    match check_or_bless(&spec) {
        Ok(GoldenStatus::Match) => {}
        Ok(GoldenStatus::Blessed) => {
            eprintln!(
                "blessed new golden corpus entry for '{}' — commit rust/tests/golden/",
                spec.name
            );
        }
        Ok(GoldenStatus::Divergence(msg)) => panic!("golden divergence: {msg}"),
        Err(e) => panic!("corpus check for '{}' errored: {e}", spec.name),
    }
}

#[test]
fn serve_soak_trace_replays_under_every_swap_engine_and_matches_the_service() {
    // the committed soak trace is engine-invariant like every other
    // golden trace, AND the live service itself (transparent admission)
    // reproduces it bit for bit — closing the loop serve is built on:
    // service run == capture/replay driver == committed corpus
    let spec = ScenarioSpec::serve_soak_short();
    let path = golden::corpus_dir().join(format!("{}.trace.jsonl", spec.name));
    let Ok(text) = std::fs::read_to_string(&path) else {
        // pre-bless tree: serve_soak_golden_matches_or_blesses creates
        // the corpus; nothing to cross-check yet
        return;
    };
    let trace = ExecTrace::from_jsonl(&text)
        .unwrap_or_else(|e| panic!("{}: committed trace unreadable: {e}", spec.name));
    let (base_report, base_trace) = spec
        .replay(&trace)
        .unwrap_or_else(|e| panic!("{}: baseline replay failed: {e}", spec.name));
    for engine in [SwapEngine::Serial, SwapEngine::Incremental] {
        let espec = spec.clone().with_swap_engine(engine);
        let (report, recaptured) = espec
            .replay(&trace)
            .unwrap_or_else(|e| panic!("{}: {engine:?} replay failed: {e}", spec.name));
        assert!(
            reports_identical(&base_report, &report),
            "{}: replay under {engine:?} diverges from the default engine",
            spec.name
        );
        assert_eq!(
            recaptured, base_trace,
            "{}: re-captured trace under {engine:?} diverges",
            spec.name
        );
    }
    let (served, served_trace) =
        Service::run_spec(&spec, ServeConfig::default()).expect("service runs");
    assert_eq!(
        served_trace, trace,
        "{}: the live service no longer reproduces the committed soak trace",
        spec.name
    );
    assert!(
        reports_identical(&served.run, &base_report),
        "{}: service run report diverges from the replayed corpus",
        spec.name
    );
}

#[test]
fn run_multi_plans_are_engine_invariant() {
    // the one coordinator path that exercises the multi-job planner:
    // identical job sets + identical arrival streams must produce
    // bit-identical run reports under all three swap engines
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.5, 4.0,
    ]);
    let mut rng = Rng::new(0x5EED_CAFE);
    let arrivals: Vec<Trace> = [2.0, 1.0, 0.8]
        .iter()
        .map(|&rate| Trace::generate(ArrivalProcess::Poisson { rate }, 60, &mut rng))
        .collect();

    let mut reference: Option<Vec<RunReport>> = None;
    for engine in [SwapEngine::Wave, SwapEngine::Serial, SwapEngine::Incremental] {
        let cfg = CoordinatorConfig {
            swap_engine: engine,
            reopt_every: 0,
            ..Default::default()
        };
        let mut coord = Coordinator::with_truthful_priors(pool.clone(), cfg);
        let workflows = [
            Workflow::fig6(),
            Workflow::tandem(3, 1.0),
            Workflow::forkjoin(2, 2.0),
        ];
        let jobs: Vec<_> = workflows
            .into_iter()
            .enumerate()
            .map(|(i, wf)| {
                let job = coord.submit(&format!("job-{i}"), wf);
                (job, arrivals[i].clone())
            })
            .collect();
        let reports = coord
            .run_multi(&jobs, Objective::Mean)
            .unwrap_or_else(|e| panic!("{engine:?}: run_multi failed: {e}"));
        coord.shutdown();
        assert_eq!(reports.len(), 3, "{engine:?}");
        match &reference {
            None => reference = Some(reports),
            Some(base) => {
                for (b, r) in base.iter().zip(reports.iter()) {
                    assert!(
                        reports_identical(b, r),
                        "{engine:?}: run_multi report diverges from the wave engine"
                    );
                }
            }
        }
    }
}

#[test]
fn churn_scenario_records_membership_events() {
    let spec = ScenarioSpec::by_name("worker_churn").unwrap().with_tasks(180);
    let (report, trace) = spec.capture().expect("churn capture");
    assert_eq!(trace.churns(), 2, "join + leave must both be recorded");
    // the churn swap shows up in the swap history with its reason
    assert!(
        report.swaps.iter().any(|(_, r)| r == "churn"),
        "membership change must force a re-plan, swaps: {:?}",
        report.swaps
    );
    // the joiner (id 4) served between its join and its leave
    let scripts = trace.service_scripts();
    assert_eq!(scripts.len(), 5);
    assert!(
        !scripts[4].is_empty(),
        "joined worker never drew a single task"
    );
}

#[test]
fn straggler_scenario_detects_drift() {
    let spec = ScenarioSpec::by_name("correlated_stragglers").unwrap();
    let (report, trace) = spec.capture().expect("straggler capture");
    assert!(
        report.swaps.iter().any(|(_, r)| r == "drift"),
        "correlated straggler onset must trigger a drift swap, got {:?}",
        report.swaps
    );
    assert!(trace.reopts() >= 1);
}

#[test]
fn empirical_refit_plan_is_deterministic_and_measured() {
    let spec = ScenarioSpec::by_name("empirical_refit")
        .unwrap()
        .with_tasks(200);
    let (_, trace) = spec.capture().expect("refit capture");
    let p1 = spec.refit_plan(&trace).expect("refit plan feasible");
    let p2 = spec.refit_plan(&trace).expect("refit plan feasible");
    assert_eq!(p1.allocation.slot_server, p2.allocation.slot_server);
    assert_eq!(p1.score.mean.to_bits(), p2.score.mean.to_bits());
    assert_eq!(p1.score.p99.to_bits(), p2.score.p99.to_bits());
    assert!(p1.score.mean.is_finite() && p1.score.mean > 0.0);
}
