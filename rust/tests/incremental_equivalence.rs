//! Incremental swap-engine equivalence: [`SwapEngine::Incremental`]
//! must be bit-identical to the full-wave engine and to the serial
//! oracle on every job set, across shard counts, chunking policies and
//! wave caps — and its memo counters must reconcile exactly with the
//! backend traffic it saves. Mock-backend tests pin the exact per-round
//! hit/miss/invalidation trajectory on hand-computable job sets,
//! including the `select_swaps` conflict path.
//!
//! Property cases replay deterministically: a failure prints the seed
//! and the `DCFLOW_PROP_SEED=<seed>` incantation that reruns it alone
//! (`DCFLOW_PROP_CASES` overrides the sweep width).

use dcflow::prelude::*;
use dcflow::util::prop;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A random small workflow: tandem, fork-join, or fork-join-then-queue
/// (the same shapes `backend_equivalence.rs` sweeps).
fn random_workflow(g: &mut prop::Gen) -> Workflow {
    let n_slots = g.usize_in(2, 5);
    match g.usize_in(0, 2) {
        0 => Workflow::tandem(n_slots, g.f64_in(0.3, 1.2)),
        1 => Workflow::forkjoin(n_slots, g.f64_in(0.3, 1.2)),
        _ => Workflow::new(
            Dcc::serial(vec![
                Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                Dcc::queue(),
            ]),
            g.f64_in(0.3, 1.2),
        )
        .unwrap(),
    }
}

/// Bit-level plan-set equality: allocations, shared grid, and every
/// score component compared through `to_bits` (so two NaNs of the same
/// payload agree and `-0.0 != 0.0`).
fn assert_plans_bit_identical(a: &[JobPlan], b: &[JobPlan], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: plan count");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.job, y.job, "{ctx}: job order");
        assert_eq!(x.alloc, y.alloc, "{ctx}: allocation of job {}", x.job);
        assert_eq!(x.grid, y.grid, "{ctx}: grid of job {}", x.job);
        for (name, xa, ya) in [
            ("mean", x.score.mean, y.score.mean),
            ("var", x.score.var, y.score.var),
            ("p99", x.score.p99, y.score.p99),
            ("mass", x.score.mass, y.score.mass),
        ] {
            assert_eq!(
                xa.to_bits(),
                ya.to_bits(),
                "{ctx}: {name} of job {} ({xa} vs {ya})",
                x.job
            );
        }
    }
}

#[test]
fn incremental_is_bit_identical_across_shards_chunking_and_wave_caps() {
    // the tentpole property on a fixed 3-job set: serial oracle == wave
    // == incremental, for every shard count × chunking policy × wave
    // cap combination (all through the public Planner surface)
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
    ]);
    let serial = Planner::new(&j1, &pool)
        .swap_engine(SwapEngine::Serial)
        .plan_jobs(&jobs)
        .unwrap();
    let wave = Planner::new(&j1, &pool)
        .swap_engine(SwapEngine::Wave)
        .plan_jobs(&jobs)
        .unwrap();
    assert_plans_bit_identical(&serial, &wave, "wave vs serial");
    for shards in [1usize, 2, 8] {
        for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(3)] {
            for max_wave in [1usize, 5, 4096] {
                let backend = ShardedBackend::new(&AnalyticBackend, shards).chunking(chunking);
                let incremental = Planner::new(&j1, &pool)
                    .backend(&backend)
                    .swap_engine(SwapEngine::Incremental)
                    .max_wave(max_wave)
                    .plan_jobs(&jobs)
                    .unwrap();
                assert_plans_bit_identical(
                    &serial,
                    &incremental,
                    &format!("incremental x{shards} / {chunking:?} / max_wave {max_wave}"),
                );
            }
        }
    }
}

#[test]
fn incremental_matches_oracles_on_random_job_sets() {
    // property form over random 3-job sets and pools, multi-round
    // trajectories included: the incremental engine through a sharded
    // backend equals the serial oracle bit for bit, or both fail with
    // the same error
    prop::run("multijob incremental == serial oracle", 6, |g| {
        let a = random_workflow(g);
        let b = random_workflow(g);
        let c = random_workflow(g);
        let total = a.slots() + b.slots() + c.slots();
        let rates: Vec<f64> = (0..total + g.usize_in(0, 2))
            .map(|_| g.f64_in(4.0, 20.0))
            .collect();
        let pool = Server::pool_exponential(&rates);
        let jobs = [&a, &b, &c];
        let serial = Planner::new(&a, &pool)
            .swap_engine(SwapEngine::Serial)
            .swap_rounds(3)
            .plan_jobs(&jobs);
        let backend = ShardedBackend::new(&AnalyticBackend, 2);
        let incremental = Planner::new(&a, &pool)
            .backend(&backend)
            .swap_engine(SwapEngine::Incremental)
            .swap_rounds(3)
            .plan_jobs(&jobs);
        match (serial, incremental) {
            (Ok(s), Ok(i)) => assert_plans_bit_identical(&s, &i, "random job set"),
            (Err(x), Err(y)) => assert_eq!(x, y),
            (s, i) => panic!("feasibility mismatch: {s:?} vs {i:?}"),
        }
    });
}

/// Analytic scoring with a side-count: every `score` call counts one,
/// every `score_batch` call counts its batch length — exactly the unit
/// the memo's hit/miss counters use.
#[derive(Default)]
struct CountingBackend {
    scored: AtomicUsize,
}

impl ScoreBackend for CountingBackend {
    fn name(&self) -> &str {
        "counting-analytic"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        self.scored.fetch_add(1, Ordering::Relaxed);
        AnalyticBackend.score(wf, alloc, servers, grid, model)
    }

    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        self.scored.fetch_add(allocs.len(), Ordering::Relaxed);
        AnalyticBackend.score_batch(wf, allocs, servers, grid, model)
    }
}

#[test]
fn memo_hits_are_exactly_the_backend_calls_saved() {
    // identical plans ⇒ identical refine traffic, so the only backend
    // traffic the incremental engine removes is memo-served swap sides:
    // wave_calls == incremental_calls + memo_hits, exactly
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let j4 = Workflow::tandem(2, 3.0);
    let jobs = [&j1, &j2, &j3, &j4];
    let pool = Server::pool_exponential(&[
        18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
    ]);
    let wave_backend = CountingBackend::default();
    let (wave_plans, wave_stats) = Planner::new(&j1, &pool)
        .backend(&wave_backend)
        .swap_engine(SwapEngine::Wave)
        .plan_jobs_report(&jobs)
        .unwrap();
    let inc_backend = CountingBackend::default();
    let (inc_plans, inc_stats) = Planner::new(&j1, &pool)
        .backend(&inc_backend)
        .swap_engine(SwapEngine::Incremental)
        .plan_jobs_report(&jobs)
        .unwrap();
    assert_plans_bit_identical(&wave_plans, &inc_plans, "counting backend");

    let wave_calls = wave_backend.scored.load(Ordering::Relaxed);
    let inc_calls = inc_backend.scored.load(Ordering::Relaxed);
    assert_eq!(
        wave_calls,
        inc_calls + inc_stats.memo_hits,
        "saved backend calls must equal memo hits \
         (wave {wave_calls}, incremental {inc_calls}, hits {})",
        inc_stats.memo_hits
    );
    assert_eq!(wave_stats.memo_hits, 0);
    assert_eq!(wave_stats.memo_misses, 0);

    // identical trajectories ⇒ identical round structure
    assert_eq!(wave_stats.rounds.len(), inc_stats.rounds.len());
    for (w, i) in wave_stats.rounds.iter().zip(&inc_stats.rounds) {
        assert_eq!(w.candidates, i.candidates, "same candidates per round");
        assert_eq!(w.applied, i.applied, "same applied swaps per round");
        assert_eq!(w.scored, 2 * w.candidates, "wave scores every side");
        assert_eq!(i.scored + i.memo_hits, 2 * i.candidates, "sides invariant");
    }
    assert_eq!(inc_stats.scored_total(), inc_stats.memo_misses);

    // with at least two jobs untouched by round 1's swaps, round 2 must
    // replay at least one cached pair (4-job sets make this reachable;
    // 2–3-job sets structurally cannot hit)
    if inc_stats.rounds.len() >= 2 && jobs.len() >= 2 * inc_stats.rounds[0].applied + 2 {
        assert!(
            inc_stats.rounds[1].memo_hits > 0,
            "untouched pair must hit in round 2: {:?}",
            inc_stats.rounds
        );
        assert!(inc_stats.hit_rate() > 0.0);
    }
}

/// One-slot-job mock: the score of a (job, server) placement is read
/// straight from a cost matrix, making every swap decision — and
/// therefore the full memo trajectory — hand-computable. Jobs are
/// identified by their (distinct, integral) arrival rates, servers by
/// global id (`servers[slot].id`, which multijob keeps global in every
/// pool view it passes to a backend).
struct MatrixBackend<const N: usize> {
    /// `cost[job][server]`; job index is `top_rate - arrival_rate`.
    cost: [[f64; N]; N],
    top_rate: usize,
}

impl<const N: usize> ScoreBackend for MatrixBackend<N> {
    fn name(&self) -> &str {
        "matrix-mock"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        _grid: &GridSpec,
        _model: ResponseModel,
    ) -> Score {
        let j = self.top_rate - wf.arrival_rate.round() as usize;
        let s = servers[alloc.slot_server[0]].id;
        Score::point(self.cost[j][s], 0.0, self.cost[j][s])
    }
}

#[test]
fn memo_trajectory_is_exact_on_a_hand_computable_job_set() {
    // four 1-slot jobs (invariant under §3 refine) with rates 4..1 seed
    // greedily onto servers 0..3; the cost matrix makes exactly one
    // swap improving — jobs 0 and 1 trade servers in round 1 — so the
    // full round/memo trajectory is known in closed form:
    //   round 1: 6 pairs × 1 exchange, all fresh (12 sides), 1 applied
    //   round 2: 5 pairs rebuilt (10 sides), pair (2,3) replays (2
    //            sides), nothing improves
    let backend = MatrixBackend::<4> {
        cost: [
            [1.0, 0.0, 10.0, 10.0],
            [0.0, 1.0, 10.0, 10.0],
            [1.0, 1.0, 1.0, 1.0],
            [1.0, 1.0, 1.0, 1.0],
        ],
        top_rate: 4,
    };
    let j0 = Workflow::tandem(1, 4.0);
    let j1 = Workflow::tandem(1, 3.0);
    let j2 = Workflow::tandem(1, 2.0);
    let j3 = Workflow::tandem(1, 1.0);
    let jobs = [&j0, &j1, &j2, &j3];
    let pool = Server::pool_exponential(&[10.0, 9.0, 8.0, 7.0]);

    let (plans, stats) = Planner::new(&j0, &pool)
        .backend(&backend)
        .swap_engine(SwapEngine::Incremental)
        .plan_jobs_report(&jobs)
        .unwrap();
    assert_eq!(
        stats.rounds,
        vec![
            RoundStats {
                candidates: 6,
                scored: 12,
                memo_hits: 0,
                applied: 1,
            },
            RoundStats {
                candidates: 6,
                scored: 10,
                memo_hits: 2,
                applied: 0,
            },
        ]
    );
    assert_eq!(stats.memo_hits, 2);
    assert_eq!(stats.memo_misses, 22);
    assert_eq!(stats.memo_invalidated, 10, "5 of 6 cached pairs touch a swapped job");
    assert!((stats.hit_rate() - 2.0 / 24.0).abs() < 1e-15);

    // the one improving swap: jobs 0 and 1 trade servers 0 and 1
    let placed: Vec<usize> = plans.iter().map(|p| p.alloc.slot_server[0]).collect();
    assert_eq!(placed, vec![1, 0, 2, 3]);

    // and all three engines land on the same plans, bit for bit
    for engine in [SwapEngine::Wave, SwapEngine::Serial] {
        let other = Planner::new(&j0, &pool)
            .backend(&backend)
            .swap_engine(engine)
            .plan_jobs(&jobs)
            .unwrap();
        assert_plans_bit_identical(&plans, &other, &format!("{engine:?} vs incremental"));
    }
}

#[test]
fn conflicting_improving_swaps_resolve_identically_under_every_engine() {
    // an engineered select_swaps conflict: swaps (0,1) at delta −5 and
    // (1,2) at delta −3 both improve in round 1 but share job 1, so
    // exactly the better one applies — under every engine, with the
    // same resulting plans and the same recorded trajectory
    let backend = MatrixBackend::<3> {
        cost: [
            [1.0, 0.0, 5.0],
            [0.0, 1.0, 0.0],
            [5.0, 0.0, 1.0],
        ],
        top_rate: 3,
    };
    let j0 = Workflow::tandem(1, 3.0);
    let j1 = Workflow::tandem(1, 2.0);
    let j2 = Workflow::tandem(1, 1.0);
    let jobs = [&j0, &j1, &j2];
    let pool = Server::pool_exponential(&[10.0, 9.0, 8.0]);

    let mut reference: Option<Vec<JobPlan>> = None;
    for engine in [SwapEngine::Serial, SwapEngine::Wave, SwapEngine::Incremental] {
        let (plans, stats) = Planner::new(&j0, &pool)
            .backend(&backend)
            .swap_engine(engine)
            .plan_jobs_report(&jobs)
            .unwrap();
        assert_eq!(stats.rounds.len(), 2, "{engine:?}");
        assert_eq!(
            stats.rounds[0].applied, 1,
            "{engine:?}: of two improving-but-conflicting swaps exactly one applies"
        );
        assert_eq!(stats.rounds[1].applied, 0, "{engine:?}: round 2 improves nothing");
        // the −5 swap won: jobs 0 and 1 traded; job 2 kept server 2
        let placed: Vec<usize> = plans.iter().map(|p| p.alloc.slot_server[0]).collect();
        assert_eq!(placed, vec![1, 0, 2], "{engine:?}");
        if engine == SwapEngine::Incremental {
            // every cached pair touches job 0 or 1 ⇒ full invalidation,
            // zero hits, both rounds fully fresh
            assert_eq!(stats.memo_hits, 0);
            assert_eq!(stats.memo_misses, 12);
            assert_eq!(stats.memo_invalidated, 6);
        }
        match &reference {
            None => reference = Some(plans),
            Some(r) => assert_plans_bit_identical(r, &plans, &format!("{engine:?}")),
        }
    }
}

/// Mock in which one job (picked by arrival rate) scores unstable on
/// every placement, exercising the non-finite-base skip path.
struct OneUnstableBackend;

impl ScoreBackend for OneUnstableBackend {
    fn name(&self) -> &str {
        "one-unstable-mock"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        _grid: &GridSpec,
        _model: ResponseModel,
    ) -> Score {
        if wf.arrival_rate.round() as usize == 2 {
            return Score::unstable_point();
        }
        // a mild cost spread for the two stable jobs (rates 3 and 1)
        let s = servers[alloc.slot_server[0]].id as f64;
        Score::point(1.0 + s, 0.0, 1.0 + s)
    }
}

#[test]
fn unstable_incumbents_are_skipped_and_never_cached() {
    // job 1 is unstable everywhere ⇒ its two pairs have a non-finite
    // base and are skipped by every engine; only pair (0,2) is
    // enumerated, and only its sides ever enter the memo
    let j0 = Workflow::tandem(1, 3.0);
    let j1 = Workflow::tandem(1, 2.0);
    let j2 = Workflow::tandem(1, 1.0);
    let jobs = [&j0, &j1, &j2];
    let pool = Server::pool_exponential(&[10.0, 9.0, 8.0]);
    let backend = OneUnstableBackend;

    let (inc_plans, stats) = Planner::new(&j0, &pool)
        .backend(&backend)
        .swap_engine(SwapEngine::Incremental)
        .plan_jobs_report(&jobs)
        .unwrap();
    assert_eq!(
        stats.rounds,
        vec![RoundStats {
            candidates: 1,
            scored: 2,
            memo_hits: 0,
            applied: 0,
        }],
        "only the stable pair (0,2) is enumerated; moving job 0 to a \
         slower server never improves"
    );
    assert_eq!(stats.memo_misses, 2, "skipped pairs must not be cached");
    assert_eq!(stats.memo_hits, 0);
    assert_eq!(stats.memo_invalidated, 0);

    for engine in [SwapEngine::Wave, SwapEngine::Serial] {
        let other = Planner::new(&j0, &pool)
            .backend(&backend)
            .swap_engine(engine)
            .plan_jobs(&jobs)
            .unwrap();
        assert_plans_bit_identical(&inc_plans, &other, &format!("{engine:?} vs incremental"));
    }
}

#[test]
fn heavy_tail_laws_stay_engine_invariant() {
    // Table-1 families at their committed extremes under M/G/1 — the
    // degenerate-law pressure corner: near-infinite-variance pareto,
    // sub-exponential weibull, a straggler mixture
    let j1 = Workflow::chain(2, 2, 0.5);
    let j2 = Workflow::tandem(2, 0.4);
    let jobs = [&j1, &j2];
    let pool = vec![
        Server::new(0, ServiceDist::delayed_pareto(2.4, 0.05)),
        Server::new(1, ServiceDist::delayed_pareto(3.5, 0.0)),
        Server::new(2, ServiceDist::delayed_weibull(1.4, 0.65, 0.1)),
        Server::new(3, ServiceDist::delayed_weibull(2.2, 0.8, 0.0)),
        Server::new(4, ServiceDist::straggler(9.0, 0.35, 0.2, 0.05)),
        Server::new(5, ServiceDist::exponential(5.0)),
        Server::new(6, ServiceDist::exponential(4.0)),
    ];
    let serial = Planner::new(&j1, &pool)
        .model(ResponseModel::Mg1)
        .swap_engine(SwapEngine::Serial)
        .plan_jobs(&jobs);
    let incremental = Planner::new(&j1, &pool)
        .model(ResponseModel::Mg1)
        .swap_engine(SwapEngine::Incremental)
        .plan_jobs(&jobs);
    match (serial, incremental) {
        (Ok(s), Ok(i)) => assert_plans_bit_identical(&s, &i, "heavy-tail pool"),
        (Err(x), Err(y)) => assert_eq!(x, y),
        (s, i) => panic!("feasibility mismatch: {s:?} vs {i:?}"),
    }
}

#[test]
fn nan_pressure_is_rejected_under_every_engine() {
    // a poisoned job (NaN arrival rate) surfaces as Infeasible — never
    // a panic, never a partially built memo — under all three engines
    let mut poisoned = Workflow::tandem(2, 1.0);
    poisoned.arrival_rate = f64::NAN;
    let healthy = Workflow::fig6();
    let pool =
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    for engine in [SwapEngine::Wave, SwapEngine::Serial, SwapEngine::Incremental] {
        let result = Planner::new(&healthy, &pool)
            .swap_engine(engine)
            .plan_jobs(&[&healthy, &poisoned]);
        assert!(
            matches!(result, Err(SchedError::Infeasible(_))),
            "{engine:?}: expected Infeasible, got {result:?}"
        );
    }
}
