//! ScoreBackend equivalence: every backend injected through `Planner`
//! must agree with the default analytic path (exactly where the math is
//! shared, approximately where laws are re-fitted), and `plan_jobs`
//! must evaluate every job on one shared grid. Only the public builder
//! surface is used — no deep imports of the raw scoring free function.

use dcflow::prelude::*;
use dcflow::util::prop;
use dcflow::util::rng::Rng;

/// A random small workflow: tandem, fork-join, or fork-join-then-queue.
fn random_workflow(g: &mut prop::Gen) -> Workflow {
    let n_slots = g.usize_in(2, 5);
    match g.usize_in(0, 2) {
        0 => Workflow::tandem(n_slots, g.f64_in(0.3, 1.2)),
        1 => Workflow::forkjoin(n_slots, g.f64_in(0.3, 1.2)),
        _ => Workflow::new(
            Dcc::serial(vec![
                Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                Dcc::queue(),
            ]),
            g.f64_in(0.3, 1.2),
        )
        .unwrap(),
    }
}

fn random_pool(g: &mut prop::Gen, slots: usize) -> Vec<Server> {
    let extra = g.usize_in(0, 2);
    let rates: Vec<f64> = (0..slots + extra).map(|_| g.f64_in(2.0, 20.0)).collect();
    Server::pool_exponential(&rates)
}

#[test]
fn explicit_analytic_backend_is_the_default_bit_for_bit() {
    // injecting AnalyticBackend must be indistinguishable from not
    // injecting anything, for every built-in policy
    prop::run("Planner.backend(Analytic) == Planner default", 20, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let default_planner = Planner::new(&wf, &servers);
        let injected = Planner::new(&wf, &servers).backend(&AnalyticBackend);
        for policy in [
            &SdccPolicy as &dyn AllocationPolicy,
            &BaselinePolicy::default(),
            &ProposedPolicy::default(),
        ] {
            match (default_planner.plan(policy), injected.plan(policy)) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.allocation, b.allocation);
                    assert_eq!(a.score.mean, b.score.mean);
                    assert_eq!(a.score.var, b.score.var);
                    assert_eq!(a.score.p99, b.score.p99);
                    assert_eq!(a.diagnostics.grid, b.diagnostics.grid);
                }
                (Err(a), Err(b)) => assert_eq!(a, b),
                (a, b) => panic!("feasibility mismatch: {a:?} vs {b:?}"),
            }
        }
    });
}

#[test]
fn planner_score_is_plan_score_on_the_same_grid() {
    // Planner::score (the builder replacement for the raw free
    // function) re-produces a Plan's score bit for bit
    prop::run("Planner::score == Plan.score", 20, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let planner = Planner::new(&wf, &servers);
        let Ok(plan) = planner.plan(&ProposedPolicy::default()) else {
            return; // infeasible draw: fine
        };
        let rescored = planner.grid(plan.diagnostics.grid).score(&plan.allocation);
        assert_eq!(rescored.mean, plan.score.mean);
        assert_eq!(rescored.var, plan.score.var);
        assert_eq!(rescored.p99, plan.score.p99);
    });
}

#[test]
fn runtime_backend_native_matches_analytic_through_planner() {
    // runtime::scorer as a ScoreBackend: the native fallback engine
    // routes through the same composition math and returns the full
    // analytic Score, so planning through it is exact
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let backend = RuntimeBackend::native();
    let via_runtime = Planner::new(&wf, &servers)
        .backend(&backend)
        .plan(&ProposedPolicy::default())
        .unwrap();
    let via_analytic = Planner::new(&wf, &servers)
        .plan(&ProposedPolicy::default())
        .unwrap();
    assert_eq!(via_runtime.diagnostics.backend, "runtime-native");
    assert_eq!(via_analytic.diagnostics.backend, "analytic");
    assert_eq!(via_runtime.allocation, via_analytic.allocation);
    assert_eq!(via_runtime.score.mean, via_analytic.score.mean);
    assert_eq!(via_runtime.score.var, via_analytic.score.var);
    assert_eq!(via_runtime.score.p99, via_analytic.score.p99);
}

#[test]
fn empirical_backend_recovers_the_true_pool() {
    // believed pool is wrong; measurements of the true laws flow in
    // through EmpiricalBackend; scores must land near the truth-pool
    // analytic scores
    let wf = Workflow::fig6();
    let truth = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let believed = Server::pool_exponential(&[6.0; 6]);
    let mut rng = Rng::new(2024);
    let mut backend = EmpiricalBackend::new();
    for (sid, s) in truth.iter().enumerate() {
        let samples: Vec<f64> = (0..5000).map(|_| s.dist.sample(&mut rng)).collect();
        backend = backend.with_samples(sid, &samples);
    }
    let truth_plan = Planner::new(&wf, &truth).plan(&SdccPolicy).unwrap();
    // same grid + same allocation, scored through the measured laws
    let measured = Planner::new(&wf, &believed)
        .grid(truth_plan.diagnostics.grid)
        .backend(&backend)
        .score(&truth_plan.allocation);
    assert!(measured.is_stable());
    assert!(
        (measured.mean - truth_plan.score.mean).abs() < 0.10 * truth_plan.score.mean,
        "measured {} vs truth {}",
        measured.mean,
        truth_plan.score.mean
    );
}

#[test]
fn plan_jobs_shares_one_grid_across_jobs() {
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
    ]);
    let plans = Planner::new(&j1, &pool).plan_jobs(&jobs).unwrap();
    assert_eq!(plans.len(), 3);
    for p in &plans {
        assert_eq!(p.grid, plans[0].grid, "job {} has a different grid", p.job);
        assert!(p.score.is_stable(), "job {} unstable", p.job);
    }
    // pinned grids flow through to every job
    let pinned = GridSpec::new(0.02, 2048);
    let pinned_plans = Planner::new(&j1, &pool)
        .grid(pinned)
        .plan_jobs(&jobs)
        .unwrap();
    for p in &pinned_plans {
        assert_eq!(p.grid, pinned);
    }
}

#[test]
fn sharded_backend_is_bit_identical_across_shard_counts() {
    // ShardedBackend(Analytic) through the full Planner surface must be
    // indistinguishable from serial AnalyticBackend for every built-in
    // scoring policy, across shard counts and random workflows
    prop::run("ShardedBackend(Analytic) == AnalyticBackend", 15, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let serial = Planner::new(&wf, &servers).backend(&AnalyticBackend);
        for shards in [1usize, 2, 8] {
            let backend = ShardedBackend::new(&AnalyticBackend, shards);
            let sharded = Planner::new(&wf, &servers).backend(&backend);
            for policy in [
                &ProposedPolicy::default() as &dyn AllocationPolicy,
                &OptimalPolicy,
            ] {
                match (serial.plan(policy), sharded.plan(policy)) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.allocation, b.allocation, "{shards} shards");
                        assert_eq!(a.score.mean, b.score.mean);
                        assert_eq!(a.score.var, b.score.var);
                        assert_eq!(a.score.p99, b.score.p99);
                        assert_eq!(a.score.mass, b.score.mass);
                        assert_eq!(a.diagnostics.grid, b.diagnostics.grid);
                    }
                    (Err(a), Err(b)) => assert_eq!(a, b),
                    (a, b) => panic!("feasibility mismatch at {shards} shards: {a:?} vs {b:?}"),
                }
            }
        }
    });
}

#[test]
fn sharded_backend_plan_jobs_matches_serial() {
    // the multi-job engine (greedy seed + shared grid + cross-job swap
    // refinement) scores many waves; sharding must not change any plan
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
    ]);
    let serial = Planner::new(&j1, &pool).plan_jobs(&jobs).unwrap();
    for shards in [1usize, 2, 8] {
        let backend = ShardedBackend::new(&AnalyticBackend, shards);
        let sharded = Planner::new(&j1, &pool)
            .backend(&backend)
            .plan_jobs(&jobs)
            .unwrap();
        assert_eq!(serial.len(), sharded.len());
        for (s, p) in serial.iter().zip(sharded.iter()) {
            assert_eq!(s.job, p.job, "{shards} shards");
            assert_eq!(s.alloc, p.alloc);
            assert_eq!(s.grid, p.grid);
            assert_eq!(s.score.mean, p.score.mean);
            assert_eq!(s.score.var, p.score.var);
            assert_eq!(s.score.p99, p.score.p99);
        }
    }
}

#[test]
fn sharded_chunking_policies_do_not_change_results() {
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let serial = Planner::new(&wf, &servers)
        .plan(&OptimalPolicy)
        .unwrap();
    for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(1), ChunkPolicy::Fixed(5)] {
        let backend = ShardedBackend::new(&AnalyticBackend, 4).chunking(chunking);
        let plan = Planner::new(&wf, &servers)
            .backend(&backend)
            .plan(&OptimalPolicy)
            .unwrap();
        assert_eq!(plan.allocation, serial.allocation, "{chunking:?}");
        assert_eq!(plan.score.mean, serial.score.mean);
    }
}

#[test]
fn sharding_composes_with_empirical_backend() {
    // a sharded empirical backend must substitute the same measured
    // pool (scoring_pool delegation) and produce the same scores
    let wf = Workflow::fig6();
    let truth = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let believed = Server::pool_exponential(&[6.0; 6]);
    let mut rng = Rng::new(99);
    let mut inner = EmpiricalBackend::new();
    for (sid, s) in truth.iter().enumerate() {
        let samples: Vec<f64> = (0..3000).map(|_| s.dist.sample(&mut rng)).collect();
        inner = inner.with_samples(sid, &samples);
    }
    let serial = Planner::new(&wf, &believed)
        .backend(&inner)
        .plan(&ProposedPolicy::default())
        .unwrap();
    let sharded_backend = ShardedBackend::new(&inner, 4);
    let sharded = Planner::new(&wf, &believed)
        .backend(&sharded_backend)
        .plan(&ProposedPolicy::default())
        .unwrap();
    assert_eq!(serial.allocation, sharded.allocation);
    assert_eq!(serial.score.mean, sharded.score.mean);
    // the sharded wrapper reports the inner backend's measured grid
    assert_eq!(serial.diagnostics.grid, sharded.diagnostics.grid);
}

#[test]
fn swap_wave_is_bit_identical_to_serial_reference() {
    // the multi-job tentpole property: the wave-batched cross-job swap
    // engine, through ShardedBackend at every shard count and chunking
    // policy, reproduces the serial reference pass bit for bit
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
    ]);
    let reference = Planner::new(&j1, &pool)
        .swap_engine(SwapEngine::Serial)
        .plan_jobs(&jobs)
        .unwrap();
    for shards in [1usize, 2, 8] {
        for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(3)] {
            let backend = ShardedBackend::new(&AnalyticBackend, shards).chunking(chunking);
            let wave = Planner::new(&j1, &pool)
                .backend(&backend)
                .plan_jobs(&jobs)
                .unwrap();
            assert_eq!(reference.len(), wave.len());
            for (r, w) in reference.iter().zip(wave.iter()) {
                assert_eq!(r.job, w.job, "{shards} shards / {chunking:?}");
                assert_eq!(r.alloc, w.alloc, "{shards} shards / {chunking:?}");
                assert_eq!(r.grid, w.grid);
                assert_eq!(r.score.mean, w.score.mean);
                assert_eq!(r.score.var, w.score.var);
                assert_eq!(r.score.p99, w.score.p99);
                assert_eq!(r.score.mass, w.score.mass);
            }
        }
    }
    // and the wave cap only changes scheduling granularity, never plans
    for max_wave in [1usize, 5] {
        let cramped = Planner::new(&j1, &pool)
            .max_wave(max_wave)
            .plan_jobs(&jobs)
            .unwrap();
        for (r, c) in reference.iter().zip(cramped.iter()) {
            assert_eq!(r.alloc, c.alloc, "max_wave {max_wave}");
            assert_eq!(r.score.mean, c.score.mean);
        }
    }
}

#[test]
fn swap_wave_matches_serial_on_random_job_sets() {
    // property form over random 2-job sets: serial reference == wave
    // engine through a sharded backend, or both infeasible identically
    prop::run("multijob wave == serial reference", 6, |g| {
        let a = random_workflow(g);
        let b = random_workflow(g);
        let total = a.slots() + b.slots();
        let rates: Vec<f64> = (0..total + g.usize_in(0, 2))
            .map(|_| g.f64_in(4.0, 20.0))
            .collect();
        let pool = Server::pool_exponential(&rates);
        let serial = Planner::new(&a, &pool)
            .swap_engine(SwapEngine::Serial)
            .plan_jobs(&[&a, &b]);
        let backend = ShardedBackend::new(&AnalyticBackend, 2);
        let wave = Planner::new(&a, &pool)
            .backend(&backend)
            .plan_jobs(&[&a, &b]);
        match (serial, wave) {
            (Ok(s), Ok(w)) => {
                assert_eq!(s.len(), w.len());
                for (x, y) in s.iter().zip(w.iter()) {
                    assert_eq!(x.alloc, y.alloc);
                    assert_eq!(x.score.mean, y.score.mean);
                    assert_eq!(x.score.p99, y.score.p99);
                }
            }
            (Err(x), Err(y)) => assert_eq!(x, y),
            (s, w) => panic!("feasibility mismatch: {s:?} vs {w:?}"),
        }
    });
}

#[test]
fn conflicting_swaps_resolve_to_the_best_one() {
    // regression for per-round conflict resolution: of two improving
    // swaps touching the same job, only the more-improving one applies
    // (total_cmp ordering, stable tie-break on enumeration order)
    use dcflow::sched::multijob::{select_swaps, RankedSwap};
    let ranked = [
        RankedSwap { a: 0, b: 1, delta: -0.3 },
        RankedSwap { a: 1, b: 2, delta: -0.8 },
    ];
    // (1,2) wins; (0,1) shares job 1 and is deferred to the next round
    assert_eq!(select_swaps(&ranked, 3), vec![1]);
    // swaps over disjoint job pairs all apply, best first
    let disjoint = [
        RankedSwap { a: 0, b: 1, delta: -0.3 },
        RankedSwap { a: 2, b: 3, delta: -0.8 },
        RankedSwap { a: 4, b: 5, delta: -0.5 },
    ];
    assert_eq!(select_swaps(&disjoint, 6), vec![1, 2, 0]);
    // an exact tie keeps enumeration order deterministically
    let tied = [
        RankedSwap { a: 0, b: 1, delta: -0.4 },
        RankedSwap { a: 1, b: 2, delta: -0.4 },
    ];
    assert_eq!(select_swaps(&tied, 3), vec![0]);
    // empty in, empty out
    assert!(select_swaps(&[], 3).is_empty());
}

#[test]
fn nan_pressure_job_is_rejected_not_a_panic() {
    // regression for the multijob partial_cmp().unwrap() panic: a
    // degenerate job must surface as SchedError::Infeasible
    let mut poisoned = Workflow::tandem(2, 1.0);
    poisoned.arrival_rate = f64::NAN;
    let healthy = Workflow::fig6();
    let pool =
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let result = Planner::new(&healthy, &pool).plan_jobs(&[&healthy, &poisoned]);
    assert!(
        matches!(result, Err(SchedError::Infeasible(_))),
        "expected Infeasible, got {result:?}"
    );
}

#[test]
fn heavy_tail_horizon_yields_finite_grids_end_to_end() {
    // regression for the infinite-horizon grids: a pool containing a
    // near-degenerate pareto law (astronomical 99.99% quantile) must
    // still produce a finite evaluation grid rather than dt = inf
    let heavy = ServiceDist::delayed_pareto(0.05, 0.0);
    assert!(heavy.quantile(0.9999) > GridSpec::MAX_HORIZON);
    let tame = ServiceDist::exponential(5.0);
    let grid = GridSpec::auto_for(&[&heavy, &tame]);
    assert!(grid.dt.is_finite() && grid.dt > 0.0);
    assert!(grid.t_max() <= GridSpec::MAX_HORIZON);
}

#[test]
fn backends_flow_through_plan_jobs() {
    // the injected backend scores multi-job plans too (native runtime
    // backend == analytic math)
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let jobs = [&j1, &j2];
    let pool =
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let backend = RuntimeBackend::native();
    let via_runtime = Planner::new(&j1, &pool)
        .backend(&backend)
        .plan_jobs(&jobs)
        .unwrap();
    let via_analytic = Planner::new(&j1, &pool).plan_jobs(&jobs).unwrap();
    assert_eq!(via_runtime.len(), via_analytic.len());
    for (r, a) in via_runtime.iter().zip(via_analytic.iter()) {
        assert_eq!(r.alloc, a.alloc);
        assert_eq!(r.score.mean, a.score.mean);
        assert_eq!(r.grid, a.grid);
    }
}
