//! Old-vs-new equivalence: the `Planner` surface must return
//! bit-identical allocations to the engine pipelines it wraps, on
//! random tandem / fork-join / mixed workflows. This is the
//! migration's safety net — if a policy ever drifts from the algorithm
//! it wraps, these properties fail. (The deprecated free-function
//! shims this suite also used to pin were removed in 0.4.0; see
//! docs/MIGRATION.md.)

use dcflow::prelude::*;
use dcflow::sched::optimal::exhaustive;
use dcflow::sched::refine::propose;
use dcflow::sched::{allocate_with, baseline_allocate_split};
use dcflow::util::prop;

/// A random small workflow: tandem, fork-join, or fork-join-then-queue.
fn random_workflow(g: &mut prop::Gen) -> Workflow {
    let n_slots = g.usize_in(2, 5);
    match g.usize_in(0, 2) {
        0 => Workflow::tandem(n_slots, g.f64_in(0.3, 1.2)),
        1 => Workflow::forkjoin(n_slots, g.f64_in(0.3, 1.2)),
        _ => Workflow::new(
            Dcc::serial(vec![
                Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                Dcc::queue(),
            ]),
            g.f64_in(0.3, 1.2),
        )
        .unwrap(),
    }
}

fn random_pool(g: &mut prop::Gen, slots: usize) -> Vec<Server> {
    let extra = g.usize_in(0, 2);
    let rates: Vec<f64> = (0..slots + extra).map(|_| g.f64_in(2.0, 20.0)).collect();
    Server::pool_exponential(&rates)
}

#[test]
fn sdcc_policy_matches_engine_bit_for_bit() {
    prop::run("Planner(SdccPolicy) == allocate_with", 40, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let planner = Planner::new(&wf, &servers);
        let via_planner = planner.allocate(&SdccPolicy);
        let via_engine = allocate_with(&wf, &servers, ResponseModel::Mm1);
        assert_eq!(via_planner, via_engine);
    });
}

#[test]
fn baseline_policy_matches_engine_bit_for_bit() {
    prop::run("Planner(BaselinePolicy) == baseline pipelines", 40, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let model = ResponseModel::Mm1;
        let planner = Planner::new(&wf, &servers).model(model);
        for split in [SplitPolicy::Uniform, SplitPolicy::Equilibrium] {
            let via_planner = planner.allocate(&BaselinePolicy { split });
            let via_engine = baseline_allocate_split(&wf, &servers, model, split);
            assert_eq!(via_planner, via_engine);
        }
    });
}

#[test]
fn proposed_policy_matches_engine_bit_for_bit() {
    prop::run("Planner(ProposedPolicy) == propose", 25, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let model = ResponseModel::Mm1;
        let planner = Planner::new(&wf, &servers).model(model);
        let via_planner = planner.allocate(&ProposedPolicy::default());
        let via_engine = propose(&wf, &servers, model, Objective::Mean).map(|(a, _)| a);
        assert_eq!(via_planner, via_engine);
    });
}

#[test]
fn optimal_policy_matches_engine_bit_for_bit() {
    prop::run("Planner(OptimalPolicy) == exhaustive", 15, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let model = ResponseModel::Mm1;
        let grid = GridSpec::auto_pool(&wf, &servers);
        let planner = Planner::new(&wf, &servers).model(model).grid(grid);
        let via_planner = planner.allocate(&OptimalPolicy);
        let via_engine =
            exhaustive(&wf, &servers, &grid, Objective::Mean, model).map(|(a, _)| a);
        assert_eq!(via_planner, via_engine);
        // and the engine's score is the planner's score (same grid)
        if let (Ok(plan), Ok((_, s))) = (
            planner.plan(&OptimalPolicy),
            exhaustive(&wf, &servers, &grid, Objective::Mean, model),
        ) {
            assert_eq!(plan.score.mean, s.mean);
            assert_eq!(plan.score.p99, s.p99);
        }
    });
}

#[test]
fn planner_errors_match_engine_errors() {
    // shim removal must not change error behavior: the planner reports
    // exactly what the engine reports
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[5.0, 5.5]);
    let via_planner = Planner::new(&wf, &servers).allocate(&SdccPolicy);
    let via_engine = allocate_with(&wf, &servers, ResponseModel::Mm1);
    assert_eq!(via_planner, via_engine);
    assert!(matches!(
        via_planner,
        Err(SchedError::NotEnoughServers { need: 6, have: 2 })
    ));
}

#[test]
fn objective_equivalence_for_proposed() {
    // the objective knob flows identically through both surfaces
    prop::run("objective passthrough", 10, |g| {
        let wf = random_workflow(g);
        let servers = random_pool(g, wf.slots());
        let model = ResponseModel::Mm1;
        for objective in [Objective::Mean, Objective::Variance, Objective::P99] {
            let via_planner = Planner::new(&wf, &servers)
                .model(model)
                .objective(objective)
                .allocate(&ProposedPolicy::default());
            let via_engine = propose(&wf, &servers, model, objective).map(|(a, _)| a);
            assert_eq!(via_planner, via_engine);
        }
    });
}
