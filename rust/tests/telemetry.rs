//! Telemetry subsystem integration tests: disabled-recorder
//! bit-identity of planning, span parentage across the scoring pool's
//! threads, histogram quantile accuracy against the exact reference,
//! JSONL round-tripping with version rejection, warn routing, and the
//! `Planner::recorder` scope guard. The obs pipeline is process-global,
//! so every test touching it serializes on one local lock (CI
//! additionally runs this binary under `RUST_TEST_THREADS=1`).

use std::collections::BTreeMap;
use std::sync::Mutex;

use dcflow::obs::{self, AttrValue, Event, Level};
use dcflow::prelude::*;
use dcflow::sched::schedule_rates;
use dcflow::util::rng::Rng;
use dcflow::util::stats;
use dcflow::util::warn;

/// Serialize tests that flip the global capture mode or drain the sink.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The bench's heterogeneous-pool scenario: four jobs on 14 servers,
/// enough pairs that swap rounds always have candidates to score.
fn job_set() -> (Vec<Workflow>, Vec<Server>) {
    (
        vec![
            Workflow::fig6(),
            Workflow::tandem(3, 1.0),
            Workflow::forkjoin(2, 2.0),
            Workflow::tandem(2, 3.0),
        ],
        Server::pool_exponential(&[
            18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
        ]),
    )
}

/// Index the span events of a trace: id → (name, parent).
fn span_index(events: &[Event]) -> BTreeMap<u64, (String, Option<u64>)> {
    let mut by_id = BTreeMap::new();
    for ev in events {
        if let Event::Span {
            id, parent, name, ..
        } = ev
        {
            by_id.insert(*id, (name.clone(), *parent));
        }
    }
    by_id
}

/// Ancestor names of a span, nearest first.
fn ancestors(by_id: &BTreeMap<u64, (String, Option<u64>)>, mut id: u64) -> Vec<String> {
    let mut chain = Vec::new();
    while let Some(p) = by_id.get(&id).and_then(|(_, parent)| *parent) {
        chain.push(by_id[&p].0.clone());
        id = p;
    }
    chain
}

#[test]
fn disabled_recorder_keeps_plan_jobs_bit_identical() {
    let _g = lock();
    obs::set_enabled(false);
    let (jobs_owned, servers) = job_set();
    let jobs: Vec<&Workflow> = jobs_owned.iter().collect();
    let backend = ShardedBackend::new(&AnalyticBackend, 2).min_parallel_wave(2);
    let planner = Planner::new(jobs[0], &servers)
        .objective(Objective::Mean)
        .backend(&backend)
        .swap_engine(SwapEngine::Incremental)
        .grid(GridSpec::new(0.05, 256));
    let reference = planner.plan_jobs(&jobs).expect("job set is feasible");

    obs::set_enabled(true);
    let traced = planner.plan_jobs(&jobs).expect("job set is feasible");
    obs::set_enabled(false);
    let _ = obs::drain();
    let replay = planner.plan_jobs(&jobs).expect("job set is feasible");

    for (label, plans) in [("traced", &traced), ("replay", &replay)] {
        assert_eq!(plans.len(), reference.len(), "{label}");
        for (g, r) in plans.iter().zip(reference.iter()) {
            assert_eq!(g.job, r.job, "{label} job index");
            assert_eq!(g.alloc, r.alloc, "{label} allocation");
            assert_eq!(g.score.mean.to_bits(), r.score.mean.to_bits(), "{label} mean");
            assert_eq!(g.score.p99.to_bits(), r.score.p99.to_bits(), "{label} p99");
            assert_eq!(g.grid, r.grid, "{label} grid");
        }
    }
}

#[test]
fn plan_jobs_emits_a_nested_span_tree() {
    let _g = lock();
    let _ = obs::drain();
    let (jobs_owned, servers) = job_set();
    let jobs: Vec<&Workflow> = jobs_owned.iter().collect();
    let backend = ShardedBackend::new(&AnalyticBackend, 4).min_parallel_wave(2);
    let planner = Planner::new(jobs[0], &servers)
        .objective(Objective::Mean)
        .backend(&backend)
        .swap_engine(SwapEngine::Incremental)
        .grid(GridSpec::new(0.05, 256));
    obs::set_enabled(true);
    planner.plan_jobs(&jobs).expect("job set is feasible");
    obs::set_enabled(false);
    let events = obs::drain();

    let summary = obs::validate(&events).expect("well-formed trace");
    assert!(summary.spans >= 4, "expected a real span tree: {summary:?}");
    assert!(summary.max_depth >= 3, "plan_jobs → multijob → phase: {summary:?}");

    let by_id = span_index(&events);
    let named = |want: &str| -> Vec<u64> {
        by_id
            .iter()
            .filter(|(_, (n, _))| n == want)
            .map(|(id, _)| *id)
            .collect()
    };
    // the pipeline root is the planner entry point
    let roots = named("plan_jobs");
    assert_eq!(roots.len(), 1);
    assert_eq!(by_id[&roots[0]].1, None, "plan_jobs is a root span");
    // multijob nests directly under it
    for id in named("multijob") {
        let parent = by_id[&id].1.expect("multijob has a parent");
        assert_eq!(by_id[&parent].0, "plan_jobs");
    }
    assert!(!named("multijob").is_empty());
    // every swap round is a direct child of multijob
    let rounds = named("multijob.swap_round");
    assert!(!rounds.is_empty(), "swap rounds were traced");
    for id in rounds {
        let parent = by_id[&id].1.expect("round has a parent");
        assert_eq!(by_id[&parent].0, "multijob");
    }
    // every scoring wave sits somewhere under multijob, and every chunk
    // directly under its wave
    let waves = named("backend.wave");
    assert!(!waves.is_empty(), "scoring waves were traced");
    for id in waves {
        assert!(
            ancestors(&by_id, id).iter().any(|n| n == "multijob"),
            "wave {id} escaped the multijob subtree"
        );
    }
    for id in named("backend.chunk") {
        let parent = by_id[&id].1.expect("chunk has a parent");
        assert_eq!(by_id[&parent].0, "backend.wave");
    }
}

#[test]
fn chunk_spans_nest_under_their_wave_across_pool_threads() {
    let _g = lock();
    let _ = obs::drain();
    // a 32-candidate wave over fig6 (rotations + adjacent
    // transpositions of the identity assignment)
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let mut wave = Vec::new();
    let mut assign: Vec<usize> = (0..servers.len()).collect();
    while wave.len() < 32 {
        assign.rotate_left(1);
        if let Ok(a) = schedule_rates(&wf, assign.clone(), &servers, model) {
            wave.push(a);
        }
        for i in 0..servers.len() - 1 {
            if wave.len() >= 32 {
                break;
            }
            let mut swapped = assign.clone();
            swapped.swap(i, i + 1);
            if let Ok(a) = schedule_rates(&wf, swapped, &servers, model) {
                wave.push(a);
            }
        }
    }
    let grid = GridSpec::auto_response(&wave[0], &servers, model);
    let pooled = ShardedBackend::new(&AnalyticBackend, 4).min_parallel_wave(2);

    obs::set_enabled(true);
    let outer = obs::span("telemetry.test.outer");
    let outer_id = outer.id();
    let _scores = pooled.score_batch(&wf, &wave, &servers, &grid, model);
    drop(outer);
    obs::set_enabled(false);
    let events = obs::drain();

    obs::validate(&events).expect("well-formed trace");
    let by_id = span_index(&events);
    let wave_ids: Vec<u64> = by_id
        .iter()
        .filter(|(_, (n, _))| n == "backend.wave")
        .map(|(id, _)| *id)
        .collect();
    assert_eq!(wave_ids.len(), 1, "one wave span for one score_batch call");
    assert_eq!(
        by_id[&wave_ids[0]].1,
        Some(outer_id),
        "the wave nests under the caller's open span"
    );
    // 32 candidates over 4 shards: dispatched, so chunk spans exist and
    // each links across its worker thread back to this wave
    let chunks: Vec<u64> = by_id
        .iter()
        .filter(|(_, (n, _))| n == "backend.chunk")
        .map(|(id, _)| *id)
        .collect();
    assert!(!chunks.is_empty(), "a 32-wide wave on 4 shards dispatches");
    for id in chunks {
        assert_eq!(by_id[&id].1, Some(wave_ids[0]), "chunk {id} parent");
    }
}

#[test]
fn registry_histogram_quantiles_track_the_exact_reference() {
    // local registry: no global state, no lock needed
    let reg = obs::Registry::default();
    let hist = reg.histogram("test.latency", 0.0, 8.0, 64);
    let mut rng = Rng::new(42);
    let mut samples: Vec<f64> = (0..2000).map(|_| rng.exponential(1.0)).collect();
    for &s in &samples {
        hist.record(s);
    }
    samples.sort_by(f64::total_cmp);
    let snap = hist.snapshot();
    assert_eq!(snap.count, 2000);
    for q in [0.1, 0.5, 0.9, 0.99] {
        let exact = stats::quantile(&samples, q);
        let approx = snap.quantile(q);
        assert!(
            (approx - exact).abs() <= 2.0 * snap.width,
            "q={q}: bucket-CDF {approx} vs exact {exact} (width {})",
            snap.width
        );
    }
}

#[test]
fn jsonl_round_trips_and_rejects_foreign_versions() {
    let _g = lock();
    let _ = obs::drain();
    obs::set_enabled(true);
    {
        let mut root = obs::span("telemetry.test.root");
        root.attr("jobs", 3usize);
        root.attr("engine", "incremental");
        let _child = obs::span("telemetry.test.child");
        obs::event(
            "telemetry.test.instant",
            vec![("k".to_string(), 1.5f64.into())],
        );
    }
    obs::set_enabled(false);
    let events = obs::drain();
    let text = obs::to_jsonl(&events);

    // serialize → parse → serialize is byte-stable
    let parsed = obs::parse_jsonl(&text).expect("round-trip parse");
    assert_eq!(obs::to_jsonl(&parsed), text);

    // a trace from a future format version is rejected, not misread
    let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
    lines[0] = lines[0].replace('1', "999");
    let err = obs::parse_jsonl(&lines.join("\n")).unwrap_err();
    assert!(err.contains("unsupported"), "got: {err}");
    // headerless and empty inputs are rejected too
    assert!(obs::parse_jsonl(&lines[1..].join("\n")).is_err());
    assert!(obs::parse_jsonl("").is_err());

    // the Chrome export carries the slices and the instant
    let chrome = obs::to_chrome_trace(&events);
    assert!(chrome.contains("traceEvents"));
    assert!(chrome.contains("telemetry.test.root"));
    assert!(chrome.contains("telemetry.test.child"));
    assert!(chrome.contains("telemetry.test.instant"));
}

#[test]
fn warn_reaches_the_trace_even_when_stderr_is_quiet() {
    let _g = lock();
    let _ = obs::drain();
    warn::set_quiet(true);
    obs::set_enabled(true);
    warn::warn("telemetry-test diagnostic (not visible in test output)");
    obs::set_enabled(false);
    warn::set_quiet(false);
    let events = obs::drain();
    let w = events
        .iter()
        .find(|e| {
            matches!(
                e,
                Event::Instant { name, level: Level::Warn, .. } if name == "warn"
            )
        })
        .expect("warn captured as a level=warn instant");
    if let Event::Instant { attrs, .. } = w {
        assert!(
            matches!(&attrs[0].1, AttrValue::Str(s) if s.contains("telemetry-test")),
            "warn event carries the message"
        );
    }
}

#[test]
fn planner_recorder_scopes_capture_and_restores_mode() {
    let _g = lock();
    obs::set_enabled(false);
    let _ = obs::drain();
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let planner = Planner::new(&wf, &servers).recorder(Recorder::global());
    let plan = planner.plan(&SdccPolicy).expect("fig6 is feasible");
    assert!(plan.score.mean > 0.0);
    // the guard restored the pre-call (disabled) mode...
    assert!(!obs::enabled(), "recorder scope leaked past the call");
    obs::event("telemetry.test.after", Vec::new());
    // ...yet the traced call itself was captured
    let events = obs::drain();
    obs::validate(&events).expect("well-formed trace");
    assert!(
        events
            .iter()
            .any(|e| matches!(e, Event::Span { name, .. } if name == "plan")),
        "the plan call was traced"
    );
    assert!(
        !events
            .iter()
            .any(|e| matches!(e, Event::Instant { name, .. } if name == "telemetry.test.after")),
        "post-call events are not captured"
    );
}
