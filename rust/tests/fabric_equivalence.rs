//! Scoring-fabric equivalence: the pooled dispatch path (persistent
//! `ScoringPool` workers scoring through the allocation-free scratch
//! kernels) must be bit-identical to the spawn-per-wave scoped path and
//! to the serial reference, across shard counts, chunk policies and
//! wave widths — including waves below the inline threshold. Run in CI
//! as its own job under `RUST_TEST_THREADS=1` so pool counters are
//! deterministic per test.

use dcflow::compose::score::{score_allocation_scratch, score_allocation_with};
use dcflow::prelude::*;
use dcflow::sched::schedule_rates;
use dcflow::util::prop;

fn fig6() -> (Workflow, Vec<Server>) {
    (
        Workflow::fig6(),
        Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
    )
}

/// A wave of `n` distinct feasible candidates over the fig6 pool
/// (rotations + adjacent transpositions of the identity assignment,
/// cycled to length).
fn candidate_wave(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    n: usize,
) -> Vec<Allocation> {
    let mut wave = Vec::new();
    let mut assign: Vec<usize> = (0..servers.len()).collect();
    while wave.len() < n {
        assign.rotate_left(1);
        if let Ok(a) = schedule_rates(wf, assign.clone(), servers, model) {
            wave.push(a);
        }
        for i in 0..servers.len() - 1 {
            if wave.len() >= n {
                break;
            }
            let mut swapped = assign.clone();
            swapped.swap(i, i + 1);
            if let Ok(a) = schedule_rates(wf, swapped, servers, model) {
                wave.push(a);
            }
        }
    }
    wave.truncate(n);
    wave
}

fn assert_scores_bit_identical(got: &[Score], want: &[Score], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (k, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.mean.to_bits(), w.mean.to_bits(), "{ctx} row {k} mean");
        assert_eq!(g.var.to_bits(), w.var.to_bits(), "{ctx} row {k} var");
        assert_eq!(g.p99.to_bits(), w.p99.to_bits(), "{ctx} row {k} p99");
        assert_eq!(g.mass.to_bits(), w.mass.to_bits(), "{ctx} row {k} mass");
        assert_eq!(g.pdf.len(), w.pdf.len(), "{ctx} row {k} pdf len");
        for (x, y) in g.pdf.iter().zip(w.pdf.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx} row {k} pdf");
        }
    }
}

#[test]
fn pooled_equals_scoped_equals_serial_across_the_matrix() {
    // the tentpole property: shards x chunkings x wave widths (spanning
    // the inline threshold on both sides), pooled == scoped == serial
    let (wf, servers) = fig6();
    let model = ResponseModel::Mm1;
    let wave = candidate_wave(&wf, &servers, model, 64);
    let grid = GridSpec::auto_response(&wave[0], &servers, model);
    for width in [1usize, 3, 7, 8, 24, 64] {
        let wave = &wave[..width];
        let serial = AnalyticBackend.score_batch(&wf, wave, &servers, &grid, model);
        for shards in [1usize, 2, 8] {
            for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(1), ChunkPolicy::Fixed(3)] {
                let ctx = format!("width={width} shards={shards} {chunking:?}");
                let pooled = ShardedBackend::new(&AnalyticBackend, shards).chunking(chunking);
                let got = pooled.score_batch(&wf, wave, &servers, &grid, model);
                assert_scores_bit_identical(&got, &serial, &format!("pooled {ctx}"));
                let scoped = ShardedBackend::new(&AnalyticBackend, shards)
                    .chunking(chunking)
                    .dispatch(Dispatch::SpawnPerWave);
                let got = scoped.score_batch(&wf, wave, &servers, &grid, model);
                assert_scores_bit_identical(&got, &serial, &format!("scoped {ctx}"));
            }
        }
    }
}

#[test]
fn one_pool_scores_many_waves_bit_identically() {
    // a single long-lived backend (one fabric) across many waves of
    // varying width: warm workers and recycled scratch must never
    // perturb a bit, and sub-threshold waves stay inline
    let (wf, servers) = fig6();
    let model = ResponseModel::Mm1;
    let all = candidate_wave(&wf, &servers, model, 48);
    let grid = GridSpec::auto_response(&all[0], &servers, model);
    let pooled = ShardedBackend::new(&AnalyticBackend, 4);
    let mut inline_expected = 0usize;
    let mut dispatched_expected = 0usize;
    for width in [2usize, 48, 5, 16, 48, 7, 31] {
        let wave = &all[..width];
        let serial = AnalyticBackend.score_batch(&wf, wave, &servers, &grid, model);
        let got = pooled.score_batch(&wf, wave, &servers, &grid, model);
        assert_scores_bit_identical(&got, &serial, &format!("wave width {width}"));
        if width < pooled.min_wave() {
            inline_expected += 1;
        } else {
            dispatched_expected += 1;
        }
    }
    let st = pooled.fabric_stats().expect("sharded reports stats");
    assert_eq!(st.workers, 4);
    assert_eq!(st.waves_inline, inline_expected);
    assert_eq!(st.waves_dispatched, dispatched_expected);
    assert!(st.chunks_dispatched >= dispatched_expected);
}

#[test]
fn scratch_scorer_matches_allocating_scorer_on_random_flows() {
    // property form of the kernel-layer refactor: one shared Scratch
    // across every draw (stale buffer contents must never leak into a
    // score), random topologies, both response models
    let mut scratch = Scratch::new();
    prop::run("score_allocation_scratch == score_allocation_with", 20, |g| {
        let n_slots = g.usize_in(2, 5);
        let wf = match g.usize_in(0, 2) {
            0 => Workflow::tandem(n_slots, g.f64_in(0.3, 1.2)),
            1 => Workflow::forkjoin(n_slots, g.f64_in(0.3, 1.2)),
            _ => Workflow::new(
                Dcc::serial(vec![
                    Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                    Dcc::queue(),
                ]),
                g.f64_in(0.3, 1.2),
            )
            .unwrap(),
        };
        let rates: Vec<f64> = (0..wf.slots()).map(|_| g.f64_in(2.0, 20.0)).collect();
        let servers = Server::pool_exponential(&rates);
        let assign: Vec<usize> = (0..wf.slots()).collect();
        let model = if g.bool(0.5) {
            ResponseModel::Mm1
        } else {
            ResponseModel::ServiceOnly
        };
        // schedule_rates may reject the draw as infeasible; an unstable
        // allocation that *schedules* must still score identically
        let Ok(alloc) = schedule_rates(&wf, assign, &servers, model) else {
            return;
        };
        let grid = GridSpec::auto_response(&alloc, &servers, model);
        let want = score_allocation_with(&wf, &alloc, &servers, &grid, model);
        let got = score_allocation_scratch(&wf, &alloc, &servers, &grid, model, &mut scratch);
        assert_eq!(got.mean.to_bits(), want.mean.to_bits());
        assert_eq!(got.var.to_bits(), want.var.to_bits());
        assert_eq!(got.p99.to_bits(), want.p99.to_bits());
        assert_eq!(got.mass.to_bits(), want.mass.to_bits());
        assert_eq!(got.pdf.len(), want.pdf.len());
        for (x, y) in got.pdf.iter().zip(want.pdf.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    });
}

#[test]
fn warm_scratch_scoring_allocates_no_kernel_buffers() {
    // the allocation-discipline contract, directly on one Scratch: after
    // a one-candidate warm-up, scoring candidates of the same shape
    // creates or grows zero scratch buffers — on a grid big enough that
    // serial convolution takes the FFT path, so the complex buffers are
    // exercised too
    let wf = Workflow::tandem(3, 1.0);
    let servers = Server::pool_exponential(&[9.0, 7.0, 5.0]);
    let alloc = schedule_rates(&wf, vec![0, 1, 2], &servers, ResponseModel::Mm1).unwrap();
    let grid = GridSpec::new(0.01, 2048); // > DIRECT_FFT_CROSSOVER
    let mut scratch = Scratch::new();
    // warm-up: one candidate creates every buffer shape the loop needs
    score_allocation_scratch(&wf, &alloc, &servers, &grid, ResponseModel::Mm1, &mut scratch);
    let warm = scratch.buffer_allocs();
    assert!(warm > 0, "warm-up must have populated the stash");
    for _ in 0..32 {
        let s = score_allocation_scratch(
            &wf,
            &alloc,
            &servers,
            &grid,
            ResponseModel::Mm1,
            &mut scratch,
        );
        assert!(s.is_stable());
    }
    assert_eq!(
        scratch.buffer_allocs(),
        warm,
        "zero scratch-buffer allocations per candidate after warm-up"
    );
}

#[test]
fn pooled_backend_scratch_allocs_are_bounded_by_warmup() {
    // fabric-level allocation discipline: across many dispatched waves,
    // total scratch heap events stay bounded by the per-worker warm-up
    // cost — they do not scale with waves or candidates
    let (wf, servers) = fig6();
    let model = ResponseModel::Mm1;
    let wave = candidate_wave(&wf, &servers, model, 24);
    let grid = GridSpec::auto_response(&wave[0], &servers, model);
    let shards = 2usize;
    let pooled = ShardedBackend::new(&AnalyticBackend, shards);
    // measure one worker's warm-up cost on an identical workload
    let mut probe = Scratch::new();
    score_allocation_scratch(&wf, &wave[0], &servers, &grid, model, &mut probe);
    let per_worker_warm = probe.buffer_allocs();
    for _ in 0..10 {
        pooled.score_batch(&wf, &wave, &servers, &grid, model);
    }
    let st = pooled.fabric_stats().expect("stats");
    assert_eq!(st.waves_dispatched, 10);
    assert!(
        st.scratch_allocs <= shards * per_worker_warm,
        "scratch allocs {} exceed warm-up bound {} x {per_worker_warm} \
         (10 waves x 24 candidates would churn ~{} buffers unpooled)",
        st.scratch_allocs,
        shards,
        10 * 24 * per_worker_warm
    );
}

#[test]
fn plan_jobs_on_the_pool_matches_serial_and_reports_fabric() {
    // the planner surface: multi-job planning through the pooled fabric
    // returns identical plans and surfaces fabric + memo telemetry
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let pool = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
    ]);
    let (serial_plans, serial_stats) = Planner::new(&j1, &pool).plan_jobs_report(&jobs).unwrap();
    // a plain backend has no fabric to report
    assert_eq!(serial_stats.fabric, None);
    for engine in [SwapEngine::Wave, SwapEngine::Incremental] {
        let backend = ShardedBackend::new(&AnalyticBackend, 4);
        let (plans, stats) = Planner::new(&j1, &pool)
            .swap_engine(engine)
            .backend(&backend)
            .plan_jobs_report(&jobs)
            .unwrap();
        assert_eq!(plans.len(), serial_plans.len());
        for (s, p) in serial_plans.iter().zip(plans.iter()) {
            assert_eq!(s.job, p.job, "{engine:?}");
            assert_eq!(s.alloc, p.alloc, "{engine:?}");
            assert_eq!(s.grid, p.grid);
            assert_eq!(s.score.mean.to_bits(), p.score.mean.to_bits());
            assert_eq!(s.score.var.to_bits(), p.score.var.to_bits());
            assert_eq!(s.score.p99.to_bits(), p.score.p99.to_bits());
        }
        let fabric = stats.fabric.expect("sharded backend reports fabric");
        assert_eq!(fabric.workers, 4);
        assert!(
            fabric.waves_inline + fabric.waves_dispatched > 0,
            "{engine:?}: the swap phase scored at least one wave"
        );
        // memo hit-rate telemetry rides along next to the fabric
        // counters
        if engine == SwapEngine::Incremental {
            assert_eq!(stats.memo_misses, stats.scored_total());
            assert!((0.0..=1.0).contains(&stats.hit_rate()));
        }
    }
}

#[test]
fn unstable_candidates_are_bit_identical_on_the_pool() {
    // the unstable sentinel path recycles scratch buffers mid-fold;
    // interleaved stable/unstable candidates must round-trip the pool
    // with positions and sentinels intact
    let wf = Workflow::tandem(1, 5.0);
    let servers = Server::pool_exponential(&[20.0, 2.0]); // server 1 overloads
    let grid = GridSpec::new(0.01, 1024);
    let ok = Allocation::new(vec![0], vec![5.0], &wf, 2).unwrap();
    let bad = Allocation::new(vec![1], vec![5.0], &wf, 2).unwrap();
    let wave: Vec<Allocation> = (0..16)
        .map(|i| if i % 3 == 0 { ok.clone() } else { bad.clone() })
        .collect();
    let serial = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
    let pooled = ShardedBackend::new(&AnalyticBackend, 3).chunking(ChunkPolicy::Fixed(2));
    let got = pooled.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
    assert_scores_bit_identical(&got, &serial, "unstable mix");
    for (i, s) in got.iter().enumerate() {
        assert_eq!(s.is_stable(), i % 3 == 0, "row {i}");
    }
}
