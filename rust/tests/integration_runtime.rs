//! Runtime integration: AOT artifacts through PJRT vs the native engine.
//!
//! These tests exercise the real request path (rust → PJRT compiled
//! executables, python nowhere in sight). They self-skip when
//! `artifacts/` has not been built (`make artifacts`).

use dcflow::prelude::*;
use dcflow::runtime::executable::ArtifactRegistry;
use dcflow::runtime::scorer::{is_fig6_shape, BatchScorer};
use dcflow::runtime::ScorerEngine;
use dcflow::sched::schedule_rates;
use dcflow::util::rng::Rng;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn registry_enumerates_manifest() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let reg = ArtifactRegistry::open(&dir).unwrap();
    let names = reg.names();
    for want in ["score_fig6", "conv_pair", "max_pair", "score_batch"] {
        assert!(
            names.iter().any(|n| n.starts_with(want)),
            "missing artifact family {want}: {names:?}"
        );
    }
}

#[test]
fn max_pair_artifact_matches_native() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let name = "max_pair_b8_g1024";
    let Some(meta) = reg.meta(name).cloned() else {
        eprintln!("SKIP: {name} absent");
        return;
    };
    let (b, g) = (meta.inputs[0][0], meta.inputs[0][1]);
    let dt = 0.01f32;
    // cdfs of Exp(2+i), Exp(4+i)
    let mut cf = vec![0f32; b * g];
    let mut cg = vec![0f32; b * g];
    for row in 0..b {
        for k in 0..g {
            let t = k as f32 * dt;
            cf[row * g + k] = 1.0 - (-(2.0 + row as f32) * t).exp();
            cg[row * g + k] = 1.0 - (-(4.0 + row as f32) * t).exp();
        }
    }
    let outs = reg
        .execute_f32(name, &[(&cf, &[b, g]), (&cg, &[b, g]), (&[dt], &[])])
        .unwrap();
    assert_eq!(outs.len(), 2); // (cdf, pdf)
    for row in 0..b {
        for k in (0..g).step_by(97) {
            let want = cf[row * g + k] * cg[row * g + k];
            let got = outs[0][row * g + k];
            assert!((got - want).abs() < 1e-5, "row={row} k={k}");
        }
    }
}

#[test]
fn batched_scorer_agrees_with_native_on_permutation_wave() {
    let Some(_) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let wf = Workflow::fig6();
    assert!(is_fig6_shape(&wf));
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;

    // a wave of 100 random rate-scheduled candidates (crosses one PJRT
    // batch boundary: B=64)
    let mut rng = Rng::new(99);
    let mut waves: Vec<Allocation> = Vec::new();
    while waves.len() < 100 {
        let mut p: Vec<usize> = (0..6).collect();
        rng.shuffle(&mut p);
        if let Ok(a) = schedule_rates(&wf, p, &servers, model) {
            waves.push(a);
        }
    }
    let grid_probe = GridSpec::auto_response(&waves[0], &servers, model);

    let mut xla = BatchScorer::open_auto();
    if xla.backend() != ScorerEngine::Xla {
        eprintln!("SKIP: xla backend unavailable");
        return;
    }
    let grid = GridSpec {
        dt: grid_probe.dt,
        n: xla.grid_n,
    };
    let fast = xla.score_batch(&wf, &waves, &servers, &grid, model);
    let mut native = BatchScorer::native();
    let slow = native.score_batch(&wf, &waves, &servers, &grid, model);
    assert_eq!(fast.len(), slow.len());
    for (i, (f, n)) in fast.iter().zip(slow.iter()).enumerate() {
        assert!(
            (f.mean - n.mean).abs() < 3e-3 * (1.0 + n.mean),
            "cand {i}: xla {f:?} native {n:?}"
        );
        assert!(
            (f.var - n.var).abs() < 8e-3 * (1.0 + n.var),
            "cand {i}: xla {f:?} native {n:?}"
        );
    }

    // and the argmin (what the optimizer actually consumes) must agree
    let arg_fast = fast
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.mean.partial_cmp(&b.1.mean).unwrap())
        .unwrap()
        .0;
    let arg_slow = slow
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.mean.partial_cmp(&b.1.mean).unwrap())
        .unwrap()
        .0;
    assert!(
        (fast[arg_slow].mean - fast[arg_fast].mean).abs() < 1e-3,
        "backend argmin mismatch: {arg_fast} vs {arg_slow}"
    );
}

#[test]
fn xla_scorer_handles_unstable_candidates() {
    let Some(_) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let good = Planner::new(&wf, &servers)
        .model(model)
        .allocate(&ProposedPolicy::default())
        .unwrap();
    // force an unstable candidate: slot 2 (SDCC, λ=4) gets the μ=4 server
    // at rate 4 -> rho = 1
    let bad = Allocation {
        slot_server: vec![0, 1, 5, 2, 3, 4],
        slot_rate: vec![4.0, 4.0, 4.0, 4.0, 1.0, 1.0],
    };
    let mut xla = BatchScorer::open_auto();
    if xla.backend() != ScorerEngine::Xla {
        eprintln!("SKIP: xla backend unavailable");
        return;
    }
    let grid = GridSpec {
        dt: GridSpec::auto_response(&good, &servers, model).dt,
        n: xla.grid_n,
    };
    let out = xla.score_batch(&wf, &[good, bad], &servers, &grid, model);
    assert!(out[0].mean.is_finite());
    assert!(out[1].mean.is_infinite(), "unstable candidate must be INF");
}

#[test]
fn native_fallback_on_non_fig6_topologies() {
    let wf = Workflow::tandem(3, 1.0);
    let servers = Server::pool_exponential(&[6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let alloc = Planner::new(&wf, &servers)
        .model(model)
        .allocate(&ProposedPolicy::default())
        .unwrap();
    let grid = GridSpec::auto_response(&alloc, &servers, model);
    let mut scorer = BatchScorer::open_auto(); // xla if available
    let t = scorer.score_batch(&wf, &[alloc.clone()], &servers, &grid, model);
    let direct = Planner::new(&wf, &servers).model(model).grid(grid).score(&alloc);
    assert!((t[0].mean - direct.mean).abs() < 1e-9, "non-fig6 must use native path");
    // baseline comparators flow through too
    let _ = Planner::new(&wf, &servers)
        .model(model)
        .allocate(&BaselinePolicy::default());
}

#[test]
fn parametric_mmde_path_matches_native() {
    // the fully-fused parametric artifact must agree with the native
    // engine (all-exponential pool -> every M/M/1 response law is a
    // 1-mode atomless delayed-exp mixture, so the mmde path activates)
    let Some(_) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let mut rng = Rng::new(4242);
    let mut waves: Vec<Allocation> = Vec::new();
    while waves.len() < 16 {
        let mut p: Vec<usize> = (0..6).collect();
        rng.shuffle(&mut p);
        if let Ok(a) = schedule_rates(&wf, p, &servers, model) {
            waves.push(a);
        }
    }
    let probe = GridSpec::auto_response(&waves[0], &servers, model);
    let mut xla = BatchScorer::open_auto();
    if xla.backend() != ScorerEngine::Xla {
        eprintln!("SKIP: xla backend unavailable");
        return;
    }
    let grid = GridSpec { dt: probe.dt, n: xla.grid_n };
    let fast = xla.score_batch(&wf, &waves, &servers, &grid, model);
    let mut native = BatchScorer::native();
    let slow = native.score_batch(&wf, &waves, &servers, &grid, model);
    for (i, (f, n)) in fast.iter().zip(slow.iter()).enumerate() {
        assert!(
            (f.mean - n.mean).abs() < 3e-3 * (1.0 + n.mean),
            "cand {i}: mmde {f:?} native {n:?}"
        );
        assert!(
            (f.var - n.var).abs() < 8e-3 * (1.0 + n.var),
            "cand {i}: mmde {f:?} native {n:?}"
        );
    }
}

#[test]
fn mmde_param_extraction_rules() {
    use dcflow::dist::ServiceDist;
    use dcflow::runtime::scorer::mmde_params;
    // plain exponential: 1 mode
    let p = mmde_params(&ServiceDist::exponential(3.0), 4).unwrap();
    assert_eq!(p.len(), 1);
    assert!((p[0][1] - 3.0).abs() < 1e-6);
    // delayed exp: representable
    assert!(mmde_params(&ServiceDist::delayed_exponential(2.0, 0.5), 4).is_some());
    // straggler mixture: 2 modes, representable
    assert_eq!(
        mmde_params(&ServiceDist::straggler(8.0, 0.5, 0.1, 0.0), 4)
            .unwrap()
            .len(),
        2
    );
    // pareto: not representable on the device path
    assert!(mmde_params(&ServiceDist::delayed_pareto(3.0, 0.1), 4).is_none());
}
