//! Cross-module integration: distributions → composition → planning →
//! simulation must tell one consistent story (all scheduling through
//! the `Planner` surface).

use dcflow::flow::parse::{workflow_from_json, workflow_to_json};
use dcflow::prelude::*;
use dcflow::sched::schedule_rates;
use dcflow::util::prop;
use dcflow::util::rng::Rng;

fn sim_cfg(seed: u64) -> SimConfig {
    SimConfig {
        n_tasks: 120_000,
        warmup: 8_000,
        seed,
        queueing: true,
    }
}

#[test]
fn analytic_equals_sim_for_exponential_cluster() {
    // all-exponential ⇒ M/M/1 analytics are exact; analytic engine and
    // DES must agree on the full fig6 pipeline for every policy
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let planner = Planner::new(&wf, &servers).model(ResponseModel::Mm1);
    let plans: Vec<Plan> = planner
        .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default()])
        .into_iter()
        .collect::<Result<_, _>>()
        .unwrap();
    for plan in &plans {
        let sim = simulate(&wf, &plan.allocation, &servers, &sim_cfg(31));
        assert!(
            (plan.score.mean - sim.mean).abs() < 0.05 * sim.mean,
            "{}: analytic {} vs sim {}",
            plan.policy_name,
            plan.score.mean,
            sim.mean
        );
        assert!(
            (plan.score.var - sim.var).abs() < 0.20 * sim.var,
            "{}: analytic var {} vs sim var {}",
            plan.policy_name,
            plan.score.var,
            sim.var
        );
    }
}

#[test]
fn policy_ordering_holds_in_simulation() {
    // Table-2 ordering must hold not just analytically but in the DES
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let plans: Vec<Plan> = Planner::new(&wf, &servers)
        .model(ResponseModel::Mm1)
        .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
        .into_iter()
        .collect::<Result<_, _>>()
        .unwrap();

    let s_ours = simulate(&wf, &plans[0].allocation, &servers, &sim_cfg(77)).mean;
    let s_base = simulate(&wf, &plans[1].allocation, &servers, &sim_cfg(77)).mean;
    let s_opt = simulate(&wf, &plans[2].allocation, &servers, &sim_cfg(77)).mean;
    assert!(s_opt <= s_ours * 1.02, "opt {s_opt} ours {s_ours}");
    assert!(s_ours <= s_base * 1.02, "ours {s_ours} base {s_base}");
}

#[test]
fn mg1_approximation_tracks_heavy_tail_sim() {
    // delayed-pareto service: the P-K mean is exact, the in-family tail
    // approximation is not — mean must track tightly, variance loosely
    let wf = Workflow::tandem(2, 1.5);
    let servers = vec![
        Server::new(0, ServiceDist::delayed_pareto(4.0, 0.05)),
        Server::new(1, ServiceDist::delayed_pareto(5.0, 0.02)),
    ];
    let model = ResponseModel::Mg1;
    let assign = vec![0usize, 1];
    let alloc = schedule_rates(&wf, assign, &servers, model).unwrap();
    let grid = GridSpec::auto_response(&alloc, &servers, model);
    let s = Planner::new(&wf, &servers).model(model).grid(grid).score(&alloc);
    let sim = simulate(&wf, &alloc, &servers, &sim_cfg(13));
    assert!(
        (s.mean - sim.mean).abs() < 0.10 * sim.mean,
        "analytic {} vs sim {}",
        s.mean,
        sim.mean
    );
}

#[test]
fn json_spec_to_simulation_end_to_end() {
    // JSON spec → parse → plan → simulate, all layers composing
    let spec = r#"{
        "arrival_rate": 3.0,
        "root": {"type": "serial", "children": [
            {"type": "parallel", "rate": 3.0,
             "children": [{"type": "queue"}, {"type": "queue"}]},
            {"type": "queue", "rate": 1.5}
        ]}
    }"#;
    let wf = Workflow::from_json(spec).unwrap();
    let servers = Server::pool_exponential(&[8.0, 6.0, 5.0]);
    let plan = Planner::new(&wf, &servers)
        .plan(&ProposedPolicy::default())
        .unwrap();
    let sim = simulate(&wf, &plan.allocation, &servers, &sim_cfg(5));
    assert!((plan.score.mean - sim.mean).abs() < 0.08 * sim.mean);
    // round-trip the spec too
    let wf2 = workflow_from_json(&workflow_to_json(&wf)).unwrap();
    assert_eq!(wf.root(), wf2.root());
}

#[test]
fn random_workflows_analytic_vs_sim_property() {
    // property: for random series-parallel exponential workflows, the
    // analytic engine tracks the DES within MC tolerance
    prop::run("analytic tracks sim on random workflows", 6, |g| {
        let fan = g.usize_in(2, 3);
        let wf = Workflow::new(
            Dcc::serial(vec![
                Dcc::parallel((0..fan).map(|_| Dcc::queue()).collect()),
                Dcc::queue(),
            ]),
            g.f64_in(0.5, 1.5),
        )
        .unwrap();
        let rates: Vec<f64> = (0..wf.slots()).map(|_| g.f64_in(4.0, 12.0)).collect();
        let servers = Server::pool_exponential(&rates);
        let Ok(plan) = Planner::new(&wf, &servers).plan(&ProposedPolicy::default()) else {
            return; // infeasible draw: fine
        };
        let cfg = SimConfig {
            n_tasks: 60_000,
            warmup: 5_000,
            seed: g.seed,
            queueing: true,
        };
        let sim = simulate(&wf, &plan.allocation, &servers, &cfg);
        assert!(
            (plan.score.mean - sim.mean).abs() < 0.08 * sim.mean + 0.01,
            "analytic {} vs sim {} (wf {wf:?})",
            plan.score.mean,
            sim.mean
        );
    });
}

#[test]
fn monitored_refit_recovers_scoring_accuracy() {
    // fit a pool from observations only, then check allocations made
    // from the fitted pool score like the truth
    let truth = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let mut rng = Rng::new(3);
    let mut reg = dcflow::monitor::MonitorRegistry::new(6, 8192, 512);
    for (sid, s) in truth.iter().enumerate() {
        for _ in 0..6000 {
            reg.observe(sid, s.dist.sample(&mut rng));
        }
    }
    let mut believed = Server::pool_exponential(&[1.0; 6]); // wrong priors
    assert_eq!(reg.refresh_pool(&mut believed), 6);

    let wf = Workflow::fig6();
    let alloc_believed = Planner::new(&wf, &believed)
        .allocate(&ProposedPolicy::default())
        .unwrap();
    let truth_plan = Planner::new(&wf, &truth)
        .plan(&ProposedPolicy::default())
        .unwrap();
    // score the believed allocation against the TRUE laws, on the same grid
    let s_believed = Planner::new(&wf, &truth)
        .grid(truth_plan.diagnostics.grid)
        .score(&alloc_believed);
    assert!(
        s_believed.mean <= truth_plan.score.mean * 1.05,
        "fitted-pool allocation {} vs truth-pool {}",
        s_believed.mean,
        truth_plan.score.mean
    );
}

#[test]
fn surplus_servers_and_validation() {
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0]);
    let plan = Planner::new(&wf, &servers)
        .plan(&ProposedPolicy::default())
        .unwrap();
    plan.allocation.validate(&wf, servers.len()).unwrap();
    // the two slowest surplus servers must be unused
    let used: Vec<usize> = plan.allocation.assigned_servers().collect();
    assert!(!used.contains(&6) && !used.contains(&7), "slowest surplus used: {used:?}");
}

#[test]
fn infeasible_load_is_rejected_everywhere() {
    let wf = Workflow::tandem(2, 20.0);
    let servers = Server::pool_exponential(&[3.0, 4.0]);
    let planner = Planner::new(&wf, &servers);
    assert!(planner.plan(&ProposedPolicy::default()).is_err());
    assert!(planner.allocate(&BaselinePolicy::default()).is_err());
    let grid = GridSpec::new(0.01, 512);
    assert!(planner.grid(grid).plan(&OptimalPolicy).is_err());
    // manual unstable allocation scores infinite rather than panicking
    let alloc = Allocation {
        slot_server: vec![0, 1],
        slot_rate: vec![20.0, 20.0],
    };
    let s = planner.grid(grid).score(&alloc);
    assert!(!s.is_stable());
}
