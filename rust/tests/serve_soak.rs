//! Soak-determinism tests for the live re-planning service: the same
//! seed must produce the same re-plan sequence, the same trace and the
//! same admission decisions — twice, under any admission configuration —
//! and the admission counters must always balance.
//!
//! Property cases honor `DCFLOW_PROP_CASES` / `DCFLOW_PROP_SEED`.

use dcflow::prelude::*;
use dcflow::scenario::reports_identical;
use dcflow::util::prop;

/// Run the spec twice under `cfg` and require bit-identical outcomes;
/// returns the first run for further inspection.
fn deterministic_pair(spec: &ScenarioSpec, cfg: ServeConfig) -> (ServeReport, ExecTrace) {
    let (r1, t1) = Service::run_spec(spec, cfg)
        .unwrap_or_else(|e| panic!("{}: first run failed: {e}", spec.name));
    let (r2, t2) = Service::run_spec(spec, cfg)
        .unwrap_or_else(|e| panic!("{}: second run failed: {e}", spec.name));
    assert!(
        reports_identical(&r1.run, &r2.run),
        "{}: same seed, different run reports",
        spec.name
    );
    assert_eq!(t1, t2, "{}: same seed, different traces", spec.name);
    assert_eq!(
        r1.admission, r2.admission,
        "{}: same seed, different admission decisions",
        spec.name
    );
    assert_eq!(
        r1.run.swaps, r2.run.swaps,
        "{}: same seed, different re-plan sequences",
        spec.name
    );
    (r1, t1)
}

/// The counters must balance no matter what was shed.
fn assert_admission_invariants(st: &AdmissionStats, cfg: &ServeConfig, ctx: &str) {
    assert_eq!(
        st.offered,
        st.admitted + st.shed,
        "{ctx}: offered != admitted + shed: {st:?}"
    );
    assert_eq!(
        st.shed,
        st.shed_inflight + st.shed_debounce,
        "{ctx}: shed causes do not add up: {st:?}"
    );
    assert!(
        st.peak_inflight <= cfg.max_inflight.max(1),
        "{ctx}: in-flight re-plans exceeded the cap: {st:?}"
    );
    assert!(st.forced <= st.admitted, "{ctx}: forced exceeds admitted");
    assert!(
        st.swaps_applied <= st.admitted,
        "{ctx}: more swaps than admitted re-plans"
    );
}

#[test]
fn same_seed_twice_is_bit_identical_transparent() {
    let spec = ScenarioSpec::serve_soak_short();
    let (report, _) = deterministic_pair(&spec, ServeConfig::default());
    let st = report.admission;
    assert_admission_invariants(&st, &ServeConfig::default(), "transparent");
    // transparent config sheds nothing, and every planner invocation is
    // accounted for: the initial plan plus each admitted re-plan
    assert_eq!(st.shed, 0);
    assert_eq!(st.admitted as usize + 1, report.replan_secs.len());
    assert!(report.replan_secs.iter().all(|&s| s >= 0.0));
}

#[test]
fn debounce_sheds_deterministically() {
    // WorkerChurn config re-opts every 150 completions; a debounce
    // window wider than the whole run admits the first optimization
    // offer and sheds the rest — deterministically, twice
    let spec = ScenarioSpec::serve_soak_short().with_tasks(600);
    let cfg = ServeConfig {
        debounce: 10_000,
        ..ServeConfig::default()
    };
    let (report, _) = deterministic_pair(&spec, cfg);
    let st = report.admission;
    assert_admission_invariants(&st, &cfg, "debounce");
    assert!(
        st.shed_debounce > 0,
        "a run-length debounce window must shed periodic offers: {st:?}"
    );
    assert_eq!(st.shed_inflight, 0, "nothing held long enough to shed on cap");
    // forced churn re-plans are never debounced
    assert!(st.forced >= 1, "churn class must force re-plans");
}

#[test]
fn inflight_cap_sheds_deterministically() {
    // a re-plan hold longer than the run pins the single slot after the
    // first admitted optimization re-plan, so later offers shed on the
    // in-flight cap instead
    let spec = ScenarioSpec::serve_soak_short().with_tasks(600);
    let cfg = ServeConfig {
        replan_hold: 10_000,
        ..ServeConfig::default()
    };
    let (report, _) = deterministic_pair(&spec, cfg);
    let st = report.admission;
    assert_admission_invariants(&st, &cfg, "inflight");
    assert!(
        st.shed_inflight > 0,
        "a run-length hold must shed on the in-flight cap: {st:?}"
    );
    assert_eq!(st.peak_inflight, 1, "exactly the one held slot");
    assert!(st.forced >= 1, "forced churn re-plans bypass the held slot");
}

#[test]
fn transparent_soak_trace_replays_bit_identically() {
    // a serve-recorded trace is a first-class scenario trace: feeding it
    // back through the capture/replay stack reproduces the service's
    // run report exactly
    let spec = ScenarioSpec::serve_soak_short();
    let (served, trace) =
        Service::run_spec(&spec, ServeConfig::default()).expect("service runs");
    let (replayed, recaptured) = spec.replay(&trace).expect("serve trace replays");
    assert!(
        reports_identical(&served.run, &replayed),
        "replay of a serve trace diverges from the service run"
    );
    assert_eq!(recaptured, trace, "replay did not close the capture loop");
}

#[test]
fn soak_determinism_holds_across_zoo_classes_and_admission_configs() {
    // the general property: any zoo class, any seed, any admission
    // configuration — the service is a deterministic function of
    // (scenario, config), and the counters always balance
    prop::run("serve soak determinism", 4, |g| {
        let zoo = ScenarioSpec::zoo();
        let spec = g
            .choose(&zoo)
            .clone()
            .with_seed(g.usize_in(1, 1 << 20) as u64)
            .with_tasks(120);
        let cfg = ServeConfig {
            max_inflight: g.usize_in(1, 2),
            debounce: if g.bool(0.5) { 0 } else { 200 },
            replan_hold: if g.bool(0.5) { 0 } else { 300 },
            shards: g.usize_in(1, 3),
            wave_depth: g.usize_in(1, 4),
        };
        let (report, _) = deterministic_pair(&spec, cfg);
        assert_admission_invariants(&report.admission, &cfg, &spec.name);
        assert_eq!(
            report.admission.admitted as usize + 1,
            report.replan_secs.len(),
            "{}: every admitted offer ran the planner exactly once",
            spec.name
        );
    });
}
