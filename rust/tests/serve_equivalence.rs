//! Async-backend equivalence: [`AsyncScoreBackend`] pipelines chunks
//! through the scoring fabric with a bounded in-flight depth, and every
//! score it returns must be bit-identical to its inner synchronous
//! backend — across shard counts, in-flight depths, chunk policies and
//! randomized topologies, for both the batch entry point and the
//! overlapping `score_stream` path. This is the property the live
//! re-planning service (`serve::Service`) stands on: its plans equal
//! the plain coordinator's because the async adapter never perturbs a
//! bit.
//!
//! Property cases honor `DCFLOW_PROP_CASES` / `DCFLOW_PROP_SEED`.

use dcflow::prelude::*;
use dcflow::sched::schedule_rates;
use dcflow::util::prop;

/// Up to `n` distinct feasible candidates over `servers` (rotations +
/// adjacent transpositions, bounded attempts so an infeasible draw can
/// never loop forever). Requires `wf.slots() == servers.len()`.
fn candidate_wave(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    n: usize,
) -> Vec<Allocation> {
    let mut wave = Vec::new();
    let mut assign: Vec<usize> = (0..servers.len()).collect();
    for _ in 0..2 * n {
        if wave.len() >= n {
            break;
        }
        assign.rotate_left(1);
        if let Ok(a) = schedule_rates(wf, assign.clone(), servers, model) {
            wave.push(a);
        }
        for i in 0..servers.len().saturating_sub(1) {
            if wave.len() >= n {
                break;
            }
            let mut swapped = assign.clone();
            swapped.swap(i, i + 1);
            if let Ok(a) = schedule_rates(wf, swapped, servers, model) {
                wave.push(a);
            }
        }
    }
    wave.truncate(n);
    wave
}

fn assert_scores_bit_identical(got: &[Score], want: &[Score], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for (k, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.mean.to_bits(), w.mean.to_bits(), "{ctx} row {k} mean");
        assert_eq!(g.var.to_bits(), w.var.to_bits(), "{ctx} row {k} var");
        assert_eq!(g.p99.to_bits(), w.p99.to_bits(), "{ctx} row {k} p99");
        assert_eq!(g.mass.to_bits(), w.mass.to_bits(), "{ctx} row {k} mass");
        assert_eq!(g.pdf.len(), w.pdf.len(), "{ctx} row {k} pdf len");
        for (x, y) in g.pdf.iter().zip(w.pdf.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx} row {k} pdf");
        }
    }
}

#[test]
fn async_backend_bit_identical_across_matrix_on_random_topologies() {
    // the satellite property: for ANY feasible topology and wave, every
    // shards x depth x chunking combination of the async adapter equals
    // the inner analytic backend bit for bit — batch and stream alike
    prop::run("AsyncScoreBackend == inner backend", 6, |g| {
        let n_slots = g.usize_in(2, 5);
        let wf = match g.usize_in(0, 2) {
            0 => Workflow::tandem(n_slots, g.f64_in(0.3, 1.2)),
            1 => Workflow::forkjoin(n_slots, g.f64_in(0.3, 1.2)),
            _ => Workflow::new(
                Dcc::serial(vec![
                    Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                    Dcc::queue(),
                ]),
                g.f64_in(0.3, 1.2),
            )
            .unwrap(),
        };
        let rates: Vec<f64> = (0..wf.slots()).map(|_| g.f64_in(3.0, 20.0)).collect();
        let servers = Server::pool_exponential(&rates);
        let model = ResponseModel::Mm1;
        let width = g.usize_in(9, 30);
        let wave = candidate_wave(&wf, &servers, model, width);
        if wave.is_empty() {
            return; // infeasible draw
        }
        let grid = GridSpec::auto_response(&wave[0], &servers, model);
        let serial = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, model);

        for shards in [1usize, 2, 8] {
            for depth in [1usize, 2, 16] {
                for chunking in
                    [ChunkPolicy::Even, ChunkPolicy::Fixed(1), ChunkPolicy::Fixed(5)]
                {
                    let ctx = format!("shards={shards} depth={depth} {chunking:?}");
                    let backend = AsyncScoreBackend::new(&AnalyticBackend, shards)
                        .in_flight(depth)
                        .chunking(chunking);
                    let got = backend.score_batch(&wf, &wave, &servers, &grid, model);
                    assert_scores_bit_identical(&got, &serial, &format!("batch {ctx}"));
                    let streamed = backend.score_stream(
                        &wf,
                        wave.iter().cloned(),
                        &servers,
                        &grid,
                        model,
                    );
                    assert_scores_bit_identical(&streamed, &serial, &format!("stream {ctx}"));
                    assert!(
                        backend.peak_in_flight() <= depth,
                        "{ctx}: pipelining exceeded its bound ({} > {depth})",
                        backend.peak_in_flight()
                    );
                }
            }
        }
    });
}

#[test]
fn async_over_empirical_inner_matches_that_inner() {
    // the adapter composes over any Sync inner backend, not just the
    // analytic one: an empty empirical backend falls back to analytic
    // laws, and async(empirical) must equal empirical bit for bit
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let wave = candidate_wave(&wf, &servers, model, 20);
    assert!(wave.len() >= 16, "fig6 rotations are feasible");
    let grid = GridSpec::auto_response(&wave[0], &servers, model);
    let empirical = EmpiricalBackend::new();
    let want = empirical.score_batch(&wf, &wave, &servers, &grid, model);
    let backend = AsyncScoreBackend::new(&empirical, 3).in_flight(2);
    assert_eq!(backend.name(), "async(empirical)x3");
    let got = backend.score_batch(&wf, &wave, &servers, &grid, model);
    assert_scores_bit_identical(&got, &want, "async(empirical)");
}

#[test]
fn async_inline_rule_matches_sharded_inline_rule() {
    // narrow waves stay inline on both adapters — same threshold, same
    // single-thread scoring path, so identical counters and bits
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let wave = candidate_wave(&wf, &servers, model, ShardedBackend::MIN_PARALLEL_WAVE - 1);
    let grid = GridSpec::auto_response(&wave[0], &servers, model);
    let serial = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, model);
    let sharded = ShardedBackend::new(&AnalyticBackend, 4);
    let pipelined = AsyncScoreBackend::new(&AnalyticBackend, 4);
    assert_eq!(pipelined.min_wave(), sharded.min_wave());
    let s = sharded.score_batch(&wf, &wave, &servers, &grid, model);
    let a = pipelined.score_batch(&wf, &wave, &servers, &grid, model);
    assert_scores_bit_identical(&a, &s, "inline async vs sharded");
    assert_scores_bit_identical(&a, &serial, "inline async vs serial");
    let st = pipelined.fabric_stats().expect("async reports fabric stats");
    assert_eq!(st.waves_inline, 1, "sub-threshold wave stayed inline");
    assert_eq!(st.waves_dispatched, 0);
    assert_eq!(pipelined.peak_in_flight(), 0, "inline path never pipelines");
}

#[test]
fn planner_plans_are_identical_through_the_async_backend() {
    // the serve-facing corollary: a Planner wired to the async adapter
    // returns the same allocation and bit-identical scores as the plain
    // serial planner — this is why Service plans equal Coordinator's
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let serial_plan = Planner::new(&wf, &servers)
        .objective(Objective::Mean)
        .plan(&ProposedPolicy::default())
        .expect("feasible");
    for (shards, depth) in [(1usize, 1usize), (2, 2), (8, 16)] {
        let backend = AsyncScoreBackend::new(&AnalyticBackend, shards).in_flight(depth);
        let plan = Planner::new(&wf, &servers)
            .objective(Objective::Mean)
            .backend(&backend)
            .plan(&ProposedPolicy::default())
            .expect("feasible");
        assert_eq!(plan.allocation, serial_plan.allocation, "x{shards} d{depth}");
        assert_eq!(
            plan.score.mean.to_bits(),
            serial_plan.score.mean.to_bits(),
            "x{shards} d{depth}"
        );
        assert_eq!(
            plan.score.p99.to_bits(),
            serial_plan.score.p99.to_bits(),
            "x{shards} d{depth}"
        );
    }
}
