//! # dcflow — stochastic optimization of data computing flows
//!
//! Production-quality reproduction of *“Towards Optimizing Data Computing
//! Flow in the Cloud”* (Farhat, Tootaghaj, Arjomand, 2016): jobs are
//! series–parallel compositions of **Data Computing Components (DCCs)**
//! joined at **Data Access Points (DAPs)**; every server is a stochastic
//! queue whose service time follows one of the paper's Table-1 delayed-tail
//! families. The library provides
//!
//! * [`dist`] — the Table-1 distribution families (delayed exponential /
//!   pareto / weibull, multi-modal mixtures, empirical) with grid
//!   evaluation, sampling and moments;
//! * [`compose`] — the analytic engine: serial composition by PDF
//!   convolution (Eq. 1–2, direct + FFT), parallel composition by CDF
//!   product (Eq. 3–4), grid moments/quantiles, exponential-family
//!   closed forms used for validation, and the pluggable
//!   [`compose::backend::ScoreBackend`] scoring seam;
//! * [`flow`] — the series–parallel workflow graph and its JSON spec;
//! * [`plan`] — **the planning surface**: [`plan::Planner`] evaluates any
//!   [`plan::AllocationPolicy`] (the paper's Alg. 1–3, the §3 baseline,
//!   the exhaustive optimum, or your own) against any
//!   [`plan::ScoreBackend`] and returns scored [`plan::Plan`]s;
//! * [`sched`] — the engine underneath: sort-matching allocation, the
//!   rate-equilibrium solver, §3 balancing refinement, the exhaustive
//!   reference, capacity planning and multi-job partitioning;
//! * [`sim`] — a discrete-event fork–join queueing simulator used to
//!   validate the analytic engine and regenerate the paper's figures;
//! * [`monitor`] — online per-server service-time estimation (the input
//!   to Alg. 3's periodic re-optimization) with drift detection;
//! * [`obs`] — crate-wide telemetry: hierarchical spans over the whole
//!   planning pipeline, a metrics registry (counters / gauges /
//!   histograms), and JSONL + Chrome-trace exporters, all no-op unless
//!   enabled via `DCFLOW_TRACE=1` or [`obs::set_enabled`];
//! * [`runtime`] — the PJRT hot path: loads the AOT-compiled XLA
//!   artifacts (pallas/jax, lowered to HLO text at build time) and scores
//!   candidate allocations in batches; surfaced to the planner as the
//!   [`runtime::scorer::RuntimeBackend`] scoring backend with a native
//!   fallback;
//! * [`coordinator`] — the L3 system: leader/worker runtime implementing
//!   Alg. 3 (monitor → re-optimize → dispatch) over simulated clusters;
//! * [`scenario`] — trace capture/replay and the workload zoo: a
//!   coordinator run records a versioned JSONL [`scenario::ExecTrace`]
//!   that [`scenario::Replay`] feeds back through the live stack
//!   bit-identically, with a committed golden-result corpus per
//!   [`scenario::ScenarioSpec`] workload class;
//! * [`serve`] — the live re-planning service: a [`serve::Service`]
//!   event loop that ingests arrivals, churn and drift verdicts,
//!   re-plans under admission control through the pipelining
//!   [`compose::backend::AsyncScoreBackend`], and records every
//!   decision as a replayable [`scenario`] trace.
//!
//! A module-by-module map with the Planner/Policy/ScoreBackend seams and
//! a paper cross-reference lives in `docs/ARCHITECTURE.md`; migration
//! recipes off the legacy free functions (removed in 0.4.0) live in
//! `docs/MIGRATION.md`; every bench target, what it measures and the
//! `BENCH_*.json` schema the reproducible harness emits are documented
//! in `docs/BENCHMARKS.md`. Library diagnostics (grid clamps, scorer
//! fallbacks) flow through [`util::warn`] and can be silenced with
//! [`util::warn::set_quiet`] or `DCFLOW_QUIET=1`.
//!
//! ## Quickstart
//!
//! ```
//! use dcflow::prelude::*;
//!
//! // Six heterogeneous servers (exponential service, rates 9..4).
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//!
//! // The paper's Fig. 6 workflow: PDCC ; SDCC ; PDCC with DAP rates 8/4/2.
//! let wf = Workflow::fig6();
//!
//! // One builder configures the request; any policy plugs in.
//! let planner = Planner::new(&wf, &servers)
//!     .model(ResponseModel::Mm1)
//!     .objective(Objective::Mean);
//!
//! let plan = planner.plan(&ProposedPolicy::default()).expect("feasible");
//! println!(
//!     "{}: mean={:.3} var={:.3} p99={:.3}",
//!     plan.policy_name, plan.score.mean, plan.score.var, plan.score.p99
//! );
//!
//! // The paper's Table-2 bake-off, all policies on one common grid:
//! for plan in planner
//!     .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
//!     .into_iter()
//!     .flatten()
//! {
//!     println!("{:<10} mean={:.4}", plan.policy_name, plan.score.mean);
//! }
//! ```
//!
//! Custom strategies implement [`plan::AllocationPolicy`], custom
//! predictors implement [`plan::ScoreBackend`], and both run through the
//! same builder — see the [`plan`] and [`compose::backend`] module docs.
#![deny(missing_docs)]
#![deny(rustdoc::broken_intra_doc_links)]
#![warn(clippy::all)]

// the scoring fabric and swap engines are the hot loop: hold them to
// clippy's perf lints as errors
#[deny(clippy::perf)]
pub mod compose;
pub mod coordinator;
pub mod dist;
pub mod flow;
pub mod monitor;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod scenario;
#[deny(clippy::perf)]
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;

/// Convenience re-exports covering the common API surface: enough for
/// `use dcflow::prelude::*;` to drive the planner, the scoring
/// backends, capacity planning and the monitoring loop end to end.
pub mod prelude {
    pub use crate::compose::backend::{
        AnalyticBackend, AsyncScoreBackend, ChunkPolicy, Dispatch, EmpiricalBackend,
        ScoreBackend, ShardedBackend,
    };
    pub use crate::compose::fabric::{FabricStats, ScoringPool};
    pub use crate::compose::grid::GridSpec;
    pub use crate::compose::score::Score;
    pub use crate::compose::scratch::Scratch;
    pub use crate::dist::fit::{
        fit_delayed_exponential, fit_delayed_pareto, fit_multimodal_exp, select_family, Family,
    };
    pub use crate::dist::{Mode, ServiceDist, TailKind};
    pub use crate::flow::{Dcc, Workflow};
    pub use crate::monitor::drift::detect_drift;
    pub use crate::monitor::{MonitorRegistry, ServerMonitor};
    pub use crate::obs::Recorder;
    pub use crate::plan::{
        AllocationPolicy, BaselinePolicy, Diagnostics, OptimalPolicy, Plan, PlanContext,
        Planner, ProposedPolicy, SdccPolicy,
    };
    pub use crate::runtime::scorer::RuntimeBackend;
    pub use crate::scenario::{
        ExecTrace, GoldenStatus, Replay, ScenarioClass, ScenarioSpec, TRACE_FORMAT_VERSION,
    };
    pub use crate::sched::capacity::{
        max_load_scale, max_throughput, max_throughput_under_sla, required_speedup, Sla,
    };
    pub use crate::sched::memo::SwapMemo;
    pub use crate::sched::multijob::{
        cluster_objective, JobPlan, MultiJobConfig, RoundStats, SwapEngine, SwapStats,
    };
    pub use crate::sched::server::Server;
    pub use crate::sched::{Allocation, Objective, ResponseModel, SchedError, SplitPolicy};
    pub use crate::serve::{AdmissionStats, ServeConfig, ServeReport, Service};
    pub use crate::sim::network::{simulate, SimConfig, SimResult};
}
