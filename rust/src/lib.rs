//! # dcflow — stochastic optimization of data computing flows
//!
//! Production-quality reproduction of *“Towards Optimizing Data Computing
//! Flow in the Cloud”* (Farhat, Tootaghaj, Arjomand, 2016): jobs are
//! series–parallel compositions of **Data Computing Components (DCCs)**
//! joined at **Data Access Points (DAPs)**; every server is a stochastic
//! queue whose service time follows one of the paper's Table-1 delayed-tail
//! families. The library provides
//!
//! * [`dist`] — the Table-1 distribution families (delayed exponential /
//!   pareto / weibull, multi-modal mixtures, empirical) with grid
//!   evaluation, sampling and moments;
//! * [`compose`] — the analytic engine: serial composition by PDF
//!   convolution (Eq. 1–2, direct + FFT), parallel composition by CDF
//!   product (Eq. 3–4), grid moments/quantiles, and exponential-family
//!   closed forms used for validation;
//! * [`flow`] — the series–parallel workflow graph and its JSON spec;
//! * [`sched`] — the paper's contribution: `SDCC_allocate` (Alg. 1),
//!   `PDCC_allocate` (Alg. 2) with the rate-equilibrium solver, the
//!   heuristic baseline and the exhaustive optimal reference;
//! * [`sim`] — a discrete-event fork–join queueing simulator used to
//!   validate the analytic engine and regenerate the paper's figures;
//! * [`monitor`] — online per-server service-time estimation (the input
//!   to Alg. 3's periodic re-optimization) with drift detection;
//! * [`runtime`] — the PJRT hot path: loads the AOT-compiled XLA
//!   artifacts (pallas/jax, lowered to HLO text at build time) and scores
//!   candidate allocations in batches; falls back to the native engine;
//! * [`coordinator`] — the L3 system: leader/worker runtime implementing
//!   Alg. 3 (monitor → re-optimize → dispatch) over simulated clusters.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dcflow::prelude::*;
//!
//! // Six heterogeneous servers (exponential service, rates 9..4).
//! let servers: Vec<Server> = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0]
//!     .iter().enumerate()
//!     .map(|(i, &mu)| Server::new(i, ServiceDist::exponential(mu)))
//!     .collect();
//!
//! // The paper's Fig. 6 workflow: PDCC ; SDCC ; PDCC with DAP rates 8/4/2.
//! let wf = Workflow::fig6();
//!
//! // Allocate + rate-schedule with the paper's algorithms, score analytically.
//! let plan = sdcc_allocate(&wf, &servers).expect("allocation");
//! let grid = GridSpec::auto(&plan, &servers);
//! let score = score_allocation(&wf, &plan, &servers, &grid);
//! println!("mean={:.3} var={:.3} p99={:.3}", score.mean, score.var, score.p99);
//! ```
#![deny(missing_docs)]
#![warn(clippy::all)]

pub mod compose;
pub mod coordinator;
pub mod dist;
pub mod flow;
pub mod monitor;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;

/// Convenience re-exports covering the common API surface.
pub mod prelude {
    pub use crate::compose::grid::GridSpec;
    pub use crate::compose::score::{score_allocation, Score};
    pub use crate::dist::{ServiceDist, TailKind};
    pub use crate::flow::{Dcc, Workflow};
    pub use crate::sched::{
        baseline_allocate, optimal_allocate, sdcc_allocate, Allocation, Objective,
    };
    pub use crate::sched::server::Server;
    pub use crate::sim::network::{SimConfig, SimResult};
}
