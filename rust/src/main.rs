//! dcflow CLI — leader entrypoint.
//!
//! Subcommands:
//!   run       run a workflow over a synthetic trace with the coordinator
//!   score     analytically score all three policies on a workflow
//!   fig7      reproduce the paper's Fig. 7 / Table 2 comparison quickly
//!   info      show artifact/runtime status
//!
//! Examples:
//!   dcflow score --servers 9,8,7,6,5,4
//!   dcflow run --policy proposed --tasks 20000 --rate 3.0
//!   dcflow run --workflow my_flow.json --servers 5,5,4,4
//!   dcflow fig7

use dcflow::coordinator::{Coordinator, CoordinatorConfig, Policy};
use dcflow::flow::parse::workflow_from_json;
use dcflow::flow::Workflow;
use dcflow::plan::{
    AllocationPolicy, BaselinePolicy, OptimalPolicy, Planner, ProposedPolicy, SdccPolicy,
};
use dcflow::runtime::{ArtifactRegistry, BatchScorer, ScorerEngine};
use dcflow::sched::server::Server;
use dcflow::sched::{ResponseModel, SplitPolicy};
use dcflow::sim::trace::{ArrivalProcess, Trace};
use dcflow::util::cli::Cli;
use dcflow::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "run" => cmd_run(&rest),
        "score" => cmd_score(&rest),
        "fig7" => cmd_fig7(&rest),
        "capacity" => cmd_capacity(&rest),
        "serve" => cmd_serve(&rest),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "dcflow — stochastic optimization of data computing flows\n\
     commands:\n\
     \x20 run    run a workflow on the coordinator over a synthetic trace\n\
     \x20 score  analytically score proposed/baseline/optimal allocations\n\
     \x20 fig7   reproduce the paper's Fig. 7 / Table 2 comparison\n\
     \x20 info   artifact/runtime status\n\
     run '<cmd> --help' for per-command options"
        .to_string()
}

fn parse_servers(spec: &str) -> Vec<Server> {
    let rates: Vec<f64> = spec
        .split(',')
        .map(|s| s.trim().parse::<f64>().unwrap_or_else(|_| die(&format!("bad rate '{s}'"))))
        .collect();
    Server::pool_exponential(&rates)
}

fn die(msg: &str) -> ! {
    eprintln!("dcflow: {msg}");
    std::process::exit(2)
}

fn load_workflow(path: &str) -> Workflow {
    if path.is_empty() {
        return Workflow::fig6();
    }
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| die(&format!("cannot read workflow {path}: {e}")));
    workflow_from_json(&text).unwrap_or_else(|e| die(&e.to_string()))
}

fn cmd_run(argv: &[String]) -> i32 {
    let cli = Cli::new("dcflow run", "coordinator run over a synthetic trace")
        .opt("workflow", "", "workflow JSON path (default: fig6)")
        .opt("servers", "9,8,7,6,5,4", "exponential service rates")
        .opt("policy", "proposed", "proposed|baseline|optimal")
        .opt("tasks", "10000", "number of arrivals")
        .opt("rate", "2.0", "Poisson arrival rate")
        .opt("seed", "7", "rng seed")
        .opt("reopt-every", "1000", "re-optimization cadence (0=never)")
        .flag("reopt-always", "swap on every check, not only on drift");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let wf = load_workflow(a.get("workflow"));
    let servers = parse_servers(a.get("servers"));
    let policy = match a.get("policy") {
        "proposed" | "ours" => Policy::Proposed,
        "baseline" => Policy::Baseline,
        "optimal" => Policy::Optimal,
        p => die(&format!("unknown policy '{p}'")),
    };
    let cfg = CoordinatorConfig {
        seed: a.get_as::<u64>("seed").unwrap_or(7),
        policy,
        reopt_every: a.get_as::<u64>("reopt-every").unwrap_or(1000),
        reopt_on_drift_only: !a.has("reopt-always"),
        ..Default::default()
    };
    let n_tasks = a.get_as::<usize>("tasks").unwrap_or(10_000);
    let rate = a.get_as::<f64>("rate").unwrap_or(2.0);

    let mut rng = Rng::new(cfg.seed);
    let trace = Trace::generate(ArrivalProcess::Poisson { rate }, n_tasks, &mut rng);
    let mut coord = Coordinator::with_truthful_priors(servers, cfg);
    let job = coord.submit("cli-run", wf);
    match coord.run_job(&job, &trace) {
        Ok(report) => {
            println!("{}", report.metrics.summary());
            for (at, why) in &report.swaps {
                println!("  swap @task {at}: {why}");
            }
            coord.shutdown();
            0
        }
        Err(e) => {
            eprintln!("dcflow: {e}");
            1
        }
    }
}

fn cmd_score(argv: &[String]) -> i32 {
    let cli = Cli::new("dcflow score", "analytic policy comparison")
        .opt("workflow", "", "workflow JSON path (default: fig6)")
        .opt("servers", "9,8,7,6,5,4", "exponential service rates")
        .opt("model", "mm1", "service_only|mm1|mg1");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let wf = load_workflow(a.get("workflow"));
    let servers = parse_servers(a.get("servers"));
    let model = match a.get("model") {
        "service_only" => ResponseModel::ServiceOnly,
        "mm1" => ResponseModel::Mm1,
        "mg1" => ResponseModel::Mg1,
        m => die(&format!("unknown model '{m}'")),
    };
    let planner = Planner::new(&wf, &servers).model(model);
    println!("{:<12} {:>10} {:>10} {:>10}", "policy", "mean", "var", "p99");
    let results = planner.compare(&[
        &ProposedPolicy::default(),
        &SdccPolicy,
        &BaselinePolicy::default(),
        &OptimalPolicy,
    ]);
    let mut any = false;
    for r in results {
        match r {
            Ok(plan) => {
                any = true;
                println!(
                    "{:<12} {:>10.4} {:>10.4} {:>10.4}",
                    plan.policy_name, plan.score.mean, plan.score.var, plan.score.p99
                );
            }
            Err(e) => eprintln!("dcflow: {e}"),
        }
    }
    if !any {
        die("no policy produced a feasible allocation");
    }
    0
}

fn cmd_fig7(_argv: &[String]) -> i32 {
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);

    // the Table-2 bake-off on one common grid, straight off the planner
    let fair = BaselinePolicy {
        split: SplitPolicy::Equilibrium,
    };
    let policies: [&dyn AllocationPolicy; 4] = [
        &ProposedPolicy::default(),
        &OptimalPolicy,
        &BaselinePolicy::default(),
        &fair,
    ];
    let plans: Vec<_> = Planner::new(&wf, &servers)
        .model(ResponseModel::Mm1)
        .compare(&policies)
        .into_iter()
        .collect::<Result<_, _>>()
        .expect("fig6 feasible");

    println!("Fig.7 / Table 2 (analytic, M/M/1 model, λ_DAP = 8/4/2, μ = 9..4):");
    println!("{:<14} {:>10} {:>10}", "scheme", "mean", "variance");
    for plan in &plans {
        println!(
            "{:<14} {:>10.4} {:>10.4}",
            plan.policy_name, plan.score.mean, plan.score.var
        );
    }
    let (ours, base) = (&plans[0].score, &plans[2].score);
    println!(
        "improvement over baseline: mean {:.1}%  variance {:.1}%",
        100.0 * (base.mean - ours.mean) / base.mean,
        100.0 * (base.var - ours.var) / base.var
    );
    0
}

fn cmd_capacity(argv: &[String]) -> i32 {
    let cli = Cli::new("dcflow capacity", "capacity planning")
        .opt("workflow", "", "workflow JSON path (default: fig6)")
        .opt("servers", "9,8,7,6,5,4", "exponential service rates")
        .opt("model", "mm1", "service_only|mm1|mg1")
        .opt("sla-mean", "", "mean response-time bound")
        .opt("sla-p99", "", "p99 response-time bound");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let wf = load_workflow(a.get("workflow"));
    let servers = parse_servers(a.get("servers"));
    let model = match a.get("model") {
        "service_only" => ResponseModel::ServiceOnly,
        "mm1" => ResponseModel::Mm1,
        "mg1" => ResponseModel::Mg1,
        m => die(&format!("unknown model '{m}'")),
    };
    use dcflow::sched::capacity::{max_throughput, max_throughput_under_sla, Sla};
    match max_throughput(&wf, &servers, model) {
        Ok(cap) => println!(
            "max throughput: {cap:.4} tasks/s (declared: {})",
            wf.arrival_rate
        ),
        Err(e) => {
            eprintln!("dcflow: {e}");
            return 1;
        }
    }
    if !a.get("sla-mean").is_empty() {
        let b: f64 = a.get_as("sla-mean").unwrap_or_else(|e| die(&e));
        match max_throughput_under_sla(&wf, &servers, model, Sla::Mean(b)) {
            Ok(t) => println!("throughput under mean<={b}: {t:.4} tasks/s"),
            Err(e) => eprintln!("sla-mean: {e}"),
        }
    }
    if !a.get("sla-p99").is_empty() {
        let b: f64 = a.get_as("sla-p99").unwrap_or_else(|e| die(&e));
        match max_throughput_under_sla(&wf, &servers, model, Sla::P99(b)) {
            Ok(t) => println!("throughput under p99<={b}: {t:.4} tasks/s"),
            Err(e) => eprintln!("sla-p99: {e}"),
        }
    }
    0
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cli = Cli::new("dcflow serve", "JSON-over-TCP scheduling service")
        .opt("addr", "127.0.0.1:7411", "bind address");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    match dcflow::coordinator::ApiServer::start(a.get("addr")) {
        Ok(srv) => {
            println!("dcflow api listening on {}", srv.addr());
            println!("protocol: one JSON request per line; cmd = ping|score|allocate|capacity|shutdown");
            // park until a shutdown request kills the listener
            loop {
                std::thread::sleep(std::time::Duration::from_millis(200));
                if std::net::TcpStream::connect(srv.addr()).is_err() {
                    break;
                }
            }
            0
        }
        Err(e) => {
            eprintln!("dcflow: cannot bind: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("dcflow {}", env!("CARGO_PKG_VERSION"));
    match ArtifactRegistry::open_default() {
        Ok(reg) => {
            println!("artifacts: available");
            let mut names = reg.names().into_iter().map(String::from).collect::<Vec<_>>();
            names.sort();
            for n in names {
                let m = reg.meta(&n).unwrap();
                println!("  {n}: inputs {:?} outputs {}", m.inputs, m.num_outputs);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    let scorer = BatchScorer::open_auto();
    println!(
        "scorer backend: {}",
        match scorer.backend() {
            ScorerEngine::Xla => "xla/pjrt",
            ScorerEngine::Native => "native",
        }
    );
    0
}
