//! Multi-job scheduling: partition one heterogeneous pool across several
//! concurrent workflows (the paper's problem statement is "M
//! heterogeneous servers that collectively need to process a data
//! workflow" — production clusters run many at once).
//!
//! # Algorithm (greedy seed + wave-batched cross-job swap refinement)
//!
//! Each numbered step extends the paper's machinery to the multi-job
//! setting; the per-job inner steps are exactly Alg. 1/2 (+ §3):
//!
//! 1. **Order jobs by offered load** (entry rate × serial depth — the
//!    capacity pressure of the job). Heavier jobs pick servers first,
//!    the multi-job analogue of Alg. 1's "faster servers to
//!    higher-rate DCCs" sort-matching.
//! 2. **Seed each job in order with Alg. 1 + Alg. 2** against the
//!    *remaining* pool (one greedy pass; each job's pool view is kept).
//! 3. **Size one shared evaluation grid** for the whole job set — the
//!    widest per-job seed-response grid, so every job's law fits —
//!    unless the caller pinned one.
//! 4. **Refine each seed** with the §3 min-max balancing hill-climb on
//!    the shared grid.
//! 5. **Refine across jobs** with the wave-batched swap engine: per
//!    round, *every* independent (job-pair × server-pair) exchange is
//!    materialized as a rate-scheduled candidate (Alg. 2 re-run on the
//!    regrouped assignment), all candidates are scored through
//!    [`ScoreBackend::score_batch`] waves, and the best non-conflicting
//!    improvements are applied with a deterministic
//!    [`f64::total_cmp`] tie-break ([`select_swaps`]). Applied swaps
//!    get a §3 re-balance before the next round. See [`SwapEngine`]
//!    for the batched/serial execution modes (identical results).
//!
//! Scores are load-weighted so a job processing 8 tasks/s counts 4× a
//! 2 tasks/s job in the cluster objective (minimizing total expected
//! in-flight work). All scoring flows through an injected
//! [`ScoreBackend`] ([`multijob_allocate_cfg`]); [`multijob_allocate`]
//! is the analytic-backend convenience and
//! [`crate::plan::Planner::plan_jobs`] the builder surface:
//!
//! ```
//! use dcflow::prelude::*;
//!
//! let heavy = Workflow::fig6();
//! let light = Workflow::tandem(3, 1.0);
//! let pool = Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//! let plans = Planner::new(&heavy, &pool)
//!     .swap_rounds(2)              // cross-job refinement rounds
//!     .max_wave(512)               // cap candidates per scored wave
//!     .plan_jobs(&[&heavy, &light])
//!     .expect("feasible");
//! assert_eq!(plans.len(), 2);
//! // every job is scored on one shared grid, so swap decisions compare
//! // like with like
//! assert_eq!(plans[0].grid, plans[1].grid);
//! ```
//!
//! # Why waves
//!
//! The 0.4.0 engine scored swap candidates one pair at a time through
//! [`ScoreBackend::score`], so the one hot loop that dominates
//! multi-job planning could not exploit a sharded or fused-batch
//! backend. The wave engine turns each round into a few wide
//! `score_batch` calls (one per job side, chunked at
//! [`MultiJobConfig::max_wave`]), which a
//! [`ShardedBackend`](crate::compose::backend::ShardedBackend) fans
//! across worker threads bit-identically — benchmarked in
//! `benches/multijob_swap.rs` and `examples/multijob_bench.rs`
//! (`BENCH_multijob.json`; see `docs/BENCHMARKS.md`).
//!
//! # Why a memo
//!
//! A round only mutates the plans of the (at most two per applied
//! swap) jobs whose exchange won, yet the wave engine re-enumerates
//! and re-scores every pair from scratch each round.
//! [`SwapEngine::Incremental`] carries a [`crate::sched::memo::SwapMemo`]
//! across rounds: pairs whose incumbents are fingerprint-identical to
//! the previous round replay their cached scored exchanges, and only
//! pairs touching a mutated plan are rebuilt (through the same
//! `score_batch` waves). [`multijob_allocate_report`] exposes the
//! per-round hit/miss/invalidation counters as [`SwapStats`]. All
//! three engines are bit-identical; `SwapEngine::Serial` remains the
//! oracle (`tests/incremental_equivalence.rs`).

use crate::compose::backend::{AnalyticBackend, ScoreBackend};
use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::algorithms::allocate_with;
use crate::sched::memo::{AllocFingerprint, CachedExchange, SwapMemo};
use crate::sched::refine::refine_with;
use crate::sched::response::ResponseModel;
use crate::sched::schedule_rates;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// One job's placement in a multi-job plan.
#[derive(Clone, Debug)]
pub struct JobPlan {
    /// Index into the submitted job list.
    pub job: usize,
    /// Allocation in *global* server ids.
    pub alloc: Allocation,
    /// Exact score on the shared cluster grid.
    pub score: Score,
    /// The shared evaluation grid every job in the plan set was scored
    /// on (identical across the returned plans).
    pub grid: GridSpec,
}

/// How the cross-job swap refinement (step 5) executes. Every engine
/// runs the *same* enumeration, selection and tie-break logic and
/// produces identical plans for any deterministic backend whose
/// `score_batch` agrees with per-candidate `score` (all built-ins;
/// property-tested in `tests/backend_equivalence.rs` and
/// `tests/incremental_equivalence.rs`) — the engine choice is purely
/// about how candidate scores are obtained.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SwapEngine {
    /// Score every candidate through one [`ScoreBackend::score_batch`]
    /// wave per job side (chunked at [`MultiJobConfig::max_wave`]), so
    /// sharded/fused backends parallelize the round. The default.
    #[default]
    Wave,
    /// The reference pass: score candidates one at a time, in
    /// enumeration order, through [`ScoreBackend::score`]. Kept as the
    /// bit-identity oracle for the wave path and as the serial-loop
    /// baseline in `benches/multijob_swap.rs`.
    Serial,
    /// The wave engine plus a cross-round memo table
    /// ([`crate::sched::memo::SwapMemo`]): each round, a job pair
    /// whose two incumbent allocations are fingerprint-identical to
    /// the previous round replays its cached scored exchanges instead
    /// of re-enumerating and re-scoring them; only pairs touching a
    /// plan mutated by an applied swap are rebuilt (fresh sides go
    /// through the same `score_batch` wave path as [`SwapEngine::Wave`],
    /// so sharded backends still parallelize the misses). Turns the
    /// per-round cost from O(jobs² · servers²) toward
    /// O(changed-jobs · servers²) while staying bit-identical to both
    /// other engines (`tests/incremental_equivalence.rs`).
    Incremental,
}

/// Knobs for the multi-job cross-job refinement (step 5). Constructed
/// via [`Default`] (4 rounds, 4096-candidate waves, [`SwapEngine::Wave`])
/// or field-by-field; the planner surfaces each knob as a builder
/// method ([`swap_rounds`](crate::plan::Planner::swap_rounds),
/// [`max_wave`](crate::plan::Planner::max_wave),
/// [`swap_engine`](crate::plan::Planner::swap_engine)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiJobConfig {
    /// Maximum cross-job swap rounds; refinement stops earlier when a
    /// round applies no improving swap.
    pub swap_rounds: usize,
    /// Maximum candidates per scored wave. Values `< 1` are treated as
    /// 1. Chunking a round's candidates into `max_wave`-sized waves
    /// bounds the size of each [`ScoreBackend::score_batch`] call (what
    /// device-backed batch scorers size their buffers by) and never
    /// changes results — order is preserved.
    pub max_wave: usize,
    /// Wave-batched scoring, the serial reference pass, or the
    /// memoized incremental engine.
    pub engine: SwapEngine,
}

impl Default for MultiJobConfig {
    fn default() -> MultiJobConfig {
        MultiJobConfig {
            swap_rounds: 4,
            max_wave: 4096,
            engine: SwapEngine::Wave,
        }
    }
}

impl MultiJobConfig {
    /// The serial reference configuration: identical selection logic,
    /// per-candidate scoring (see [`SwapEngine::Serial`]).
    pub fn serial_reference() -> MultiJobConfig {
        MultiJobConfig {
            engine: SwapEngine::Serial,
            ..MultiJobConfig::default()
        }
    }

    /// The incremental configuration: wave-batched scoring plus the
    /// cross-round memo table (see [`SwapEngine::Incremental`]).
    pub fn incremental() -> MultiJobConfig {
        MultiJobConfig {
            engine: SwapEngine::Incremental,
            ..MultiJobConfig::default()
        }
    }
}

/// Telemetry from one cross-job swap round (step 5), recorded by
/// [`multijob_allocate_report`]. For every recorded round the sides
/// invariant holds: `scored + memo_hits == 2 * candidates` — each
/// candidate exchange has exactly two sides, and each side is either
/// scored through the backend or served from the memo table.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundStats {
    /// Exchange candidates this round ranked over (freshly enumerated
    /// plus memo-served).
    pub candidates: usize,
    /// Candidate sides scored through the [`ScoreBackend`] this round.
    pub scored: usize,
    /// Candidate sides served from the memo table this round (always 0
    /// under [`SwapEngine::Wave`] and [`SwapEngine::Serial`]).
    pub memo_hits: usize,
    /// Non-conflicting improving swaps applied this round.
    pub applied: usize,
}

/// Swap-phase telemetry for one [`multijob_allocate_report`] call: the
/// engine that ran, per-round counters, and the memo-table totals
/// (all zero for the non-incremental engines). Rounds that enumerate
/// zero candidates terminate the phase without being recorded.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SwapStats {
    /// Engine the swap phase executed with.
    pub engine: SwapEngine,
    /// One entry per executed swap round, in order.
    pub rounds: Vec<RoundStats>,
    /// Total candidate sides served from the memo table.
    pub memo_hits: usize,
    /// Total candidate sides scored fresh and inserted into the memo
    /// table. Under [`SwapEngine::Incremental`] this equals the total
    /// `scored` across rounds.
    pub memo_misses: usize,
    /// Total candidate sides dropped from the memo table because an
    /// applied swap mutated a plan they were enumerated against.
    pub memo_invalidated: usize,
    /// Scoring-fabric counter snapshot from the backend at the end of
    /// the call ([`ScoreBackend::fabric_stats`]) — `None` for backends
    /// without a fabric (plain predictors). Counters are cumulative
    /// over the backend's lifetime, not per call.
    pub fabric: Option<crate::compose::fabric::FabricStats>,
}

impl SwapStats {
    fn new(engine: SwapEngine) -> SwapStats {
        SwapStats {
            engine,
            ..SwapStats::default()
        }
    }

    /// Total candidate sides scored through the backend across rounds.
    pub fn scored_total(&self) -> usize {
        self.rounds.iter().map(|r| r.scored).sum()
    }

    /// Memo hit rate in `[0, 1]`: hits over hits + misses, `0.0` when
    /// no side was requested at all.
    pub fn hit_rate(&self) -> f64 {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            0.0
        } else {
            self.memo_hits as f64 / total as f64
        }
    }
}

/// §3 re-balance rounds applied to each side of an accepted swap before
/// the next round (matches the refinement depth the 0.4.0 serial loop
/// gave every candidate).
const POST_SWAP_REFINE_ROUNDS: usize = 4;

/// Acceptance margin: a swap must beat the incumbent weighted objective
/// by more than this to count as improving (guards against float noise
/// cycling the hill-climb).
const IMPROVE_MARGIN: f64 = 1e-9;

/// Candidates whose score captured less than this probability mass on
/// the shared grid are rejected: their moments are deceptively low
/// (mass-normalized truncation), so they must not win a swap. Backends
/// that do not track mass report NaN, which passes the `<` test.
const MIN_CANDIDATE_MASS: f64 = 0.95;

/// Partition `servers` across `jobs` and allocate each, scoring with
/// the default [`AnalyticBackend`] on an auto-sized shared grid.
pub fn multijob_allocate(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
) -> Result<Vec<JobPlan>, SchedError> {
    multijob_allocate_with(jobs, servers, model, objective, &AnalyticBackend, None)
}

/// Partition `servers` across `jobs` with an injected scoring backend
/// and an optional pinned evaluation grid, using the default
/// [`MultiJobConfig`] (wave engine). See [`multijob_allocate_cfg`] for
/// the round/wave knobs.
pub fn multijob_allocate_with(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
    backend: &dyn ScoreBackend,
    grid: Option<GridSpec>,
) -> Result<Vec<JobPlan>, SchedError> {
    multijob_allocate_cfg(
        jobs,
        servers,
        model,
        objective,
        backend,
        grid,
        &MultiJobConfig::default(),
    )
}

/// Partition `servers` across `jobs` with an injected scoring backend,
/// an optional pinned evaluation grid and explicit refinement knobs.
///
/// All jobs are evaluated on **one shared grid**: `grid` when pinned,
/// else the widest of the per-job Alg. 1/2 seed-response grids (sized
/// once, up front — jobs are not re-derived a grid each). This is what
/// lets a comparison of swap candidates across jobs, and downstream
/// consumers of [`JobPlan::score`], compare numbers computed on the
/// same support. See the [module docs](self) for the step-by-step
/// algorithm and its Alg. 1/2 cross-reference.
pub fn multijob_allocate_cfg(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
    backend: &dyn ScoreBackend,
    grid: Option<GridSpec>,
    cfg: &MultiJobConfig,
) -> Result<Vec<JobPlan>, SchedError> {
    multijob_allocate_report(jobs, servers, model, objective, backend, grid, cfg)
        .map(|(plans, _)| plans)
}

/// [`multijob_allocate_cfg`] plus swap-phase telemetry: returns the
/// plans together with [`SwapStats`] (per-round candidate/scored/hit
/// counters and the memo-table totals), so tests and the bench harness
/// can assert the incremental engine actually skips work. The plans
/// are identical to [`multijob_allocate_cfg`]'s for the same inputs —
/// the stats are observation only.
pub fn multijob_allocate_report(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
    backend: &dyn ScoreBackend,
    grid: Option<GridSpec>,
    cfg: &MultiJobConfig,
) -> Result<(Vec<JobPlan>, SwapStats), SchedError> {
    let mut stats = SwapStats::new(cfg.engine);
    if jobs.is_empty() {
        return Ok((Vec::new(), stats));
    }
    let need: usize = jobs.iter().map(|w| w.slots()).sum();
    if servers.len() < need {
        return Err(SchedError::NotEnoughServers {
            need,
            have: servers.len(),
        });
    }
    // telemetry root for this allocation call (one atomic load when
    // capture is off; attribute formatting gated so it never allocates)
    let mut mj_span = crate::obs::span("multijob");
    if mj_span.is_recording() {
        mj_span.attr("jobs", jobs.len());
        mj_span.attr("servers", servers.len());
        mj_span.attr("engine", format!("{:?}", cfg.engine));
    }

    // 1. order by capacity pressure. A degenerate job (NaN/infinite
    // arrival rate, e.g. from a poisoned fit upstream) is rejected with
    // a diagnosis instead of panicking the sort or silently corrupting
    // the greedy order; the sort itself uses the NaN-total `total_cmp`
    // as defense in depth.
    let pressure =
        |w: &Workflow| -> f64 { w.arrival_rate * w.serial_depth() as f64 };
    for (j, w) in jobs.iter().enumerate() {
        let p = pressure(w);
        if !p.is_finite() {
            return Err(SchedError::Infeasible(format!(
                "job {j} has non-finite capacity pressure {p} \
                 (arrival_rate {}, serial depth {})",
                w.arrival_rate,
                w.serial_depth()
            )));
        }
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| {
        pressure(jobs[b])
            .total_cmp(&pressure(jobs[a]))
            .then(a.cmp(&b))
    });

    // 2. one greedy Alg. 1/2 seed pass: each job seeded against the
    // remaining pool; the pool view each job saw is kept so refinement
    // can reuse it (refinement only permutes a seed's server set, so
    // the removal order is identical either way)
    let mut remaining: Vec<Server> = servers.to_vec();
    let mut staged: Vec<(usize, Allocation, Vec<Server>)> = Vec::with_capacity(jobs.len());
    {
        let _seed_span = crate::obs::span("multijob.seed");
        for &j in &order {
            let seed = allocate_with(jobs[j], &remaining, model)?;
            let pool_view = remaining.clone();
            let mut used = seed.slot_server.clone();
            used.sort_unstable_by(|a, b| b.cmp(a));
            for i in used {
                remaining.remove(i);
            }
            staged.push((j, seed, pool_view));
        }
    }

    // 3. one shared evaluation grid for the whole job set: the widest
    // (largest dt, i.e. longest horizon) of the per-job seed-response
    // grids, sized against the laws the backend actually scores
    let shared = {
        let _grid_sizing = crate::obs::span("multijob.shared_grid");
        grid.unwrap_or_else(|| {
            staged
                .iter()
                .map(|(_, seed, pool)| {
                    let pool = backend.resolve_scoring_pool(pool);
                    GridSpec::auto_response(seed, &pool, model)
                })
                // total_cmp: a degenerate per-job dt must widen the merge
                // deterministically, never panic it (auto grids clamp
                // non-finite horizons, so dt is finite here by construction)
                .max_by(|a, b| a.dt.total_cmp(&b.dt))
                .expect("staged is non-empty: jobs.is_empty() returned early")
        })
    };

    // 4. refine each job on the shared grid against its pool view
    let mut plans: Vec<JobPlan> = Vec::with_capacity(jobs.len());
    let refine_span = crate::obs::span("multijob.refine_seeds");
    for (j, seed, pool_view) in staged {
        let (local_alloc, score) =
            refine_with(jobs[j], seed, &pool_view, &shared, model, objective, 8, backend)?;
        // translate local pool indices to global server ids (ids stay
        // global; positions shifted as earlier jobs consumed servers)
        let global: Vec<usize> = local_alloc
            .slot_server
            .iter()
            .map(|&i| pool_view[i].id)
            .collect();
        plans.push(JobPlan {
            job: j,
            alloc: Allocation {
                slot_server: global,
                slot_rate: local_alloc.slot_rate,
            },
            score,
            grid: shared,
        });
    }
    drop(refine_span);

    // 5. cross-job swap refinement on the load-weighted objective:
    // enumerate (or replay from the memo) -> score fresh sides (wave or
    // serial) -> select non-conflicting -> apply + re-balance +
    // invalidate touched memo pairs, until a round improves nothing
    let mut memo = SwapMemo::new();
    for round_idx in 0..cfg.swap_rounds {
        let mut round_span = crate::obs::span("multijob.swap_round");
        if round_span.is_recording() {
            round_span.attr("round", round_idx);
        }
        let base: Vec<f64> = plans
            .iter()
            .map(|p| jobs[p.job].arrival_rate * objective.key(&p.score))
            .collect();

        let mut round = RoundStats::default();
        // pairs freshly enumerated this round: (a, b, fp_a, fp_b,
        // start..end range in `cands`), committed to the memo once
        // their sides carry scores
        let mut fresh: Vec<(usize, usize, AllocFingerprint, AllocFingerprint, usize, usize)> =
            Vec::new();
        let mut cands: Vec<SwapCandidate>;
        if cfg.engine == SwapEngine::Incremental {
            let hits_before = memo.hits();
            cands = Vec::new();
            for a in 0..plans.len() {
                for b in (a + 1)..plans.len() {
                    // same skip rule as enumerate_candidates: an
                    // unstable incumbent pair is never enumerated, so
                    // it is never cached either
                    if !(base[a] + base[b]).is_finite() {
                        continue;
                    }
                    let fp_a = AllocFingerprint::of(&plans[a].alloc);
                    let fp_b = AllocFingerprint::of(&plans[b].alloc);
                    if let Some(cached) = memo.lookup(a, b, &fp_a, &fp_b) {
                        // replay the cached exchange list: both
                        // enumeration order and scores are exactly
                        // what fresh enumeration would produce,
                        // because both incumbents are bit-identical
                        // to the round that built the entry
                        for ex in cached {
                            cands.push(SwapCandidate {
                                a,
                                b,
                                alloc_a: ex.alloc_a.clone(),
                                alloc_b: ex.alloc_b.clone(),
                                score_a: Some(ex.score_a.clone()),
                                score_b: Some(ex.score_b.clone()),
                            });
                        }
                        continue;
                    }
                    let start = cands.len();
                    enumerate_pair(jobs, servers, &plans, model, a, b, &mut cands);
                    fresh.push((a, b, fp_a, fp_b, start, cands.len()));
                }
            }
            round.memo_hits = memo.hits() - hits_before;
        } else {
            cands = enumerate_candidates(jobs, servers, &plans, model, &base);
        }
        round.candidates = cands.len();
        if round_span.is_recording() {
            round_span.attr("candidates", round.candidates);
            round_span.attr("memo_hits", round.memo_hits);
        }
        if cands.is_empty() {
            break;
        }
        round.scored = match cfg.engine {
            SwapEngine::Serial => {
                for c in cands.iter_mut() {
                    c.score_a = Some(backend.score(
                        jobs[plans[c.a].job],
                        &c.alloc_a,
                        servers,
                        &shared,
                        model,
                    ));
                    c.score_b = Some(backend.score(
                        jobs[plans[c.b].job],
                        &c.alloc_b,
                        servers,
                        &shared,
                        model,
                    ));
                }
                2 * cands.len()
            }
            SwapEngine::Wave | SwapEngine::Incremental => score_unscored_sides(
                jobs,
                servers,
                &plans,
                model,
                backend,
                &shared,
                cfg.max_wave,
                &mut cands,
            ),
        };
        // commit each freshly enumerated pair now that its sides carry
        // scores, so the next round can replay it on a hit
        for (a, b, fp_a, fp_b, start, end) in fresh {
            let exchanges: Vec<CachedExchange> = cands[start..end]
                .iter()
                .map(|c| CachedExchange {
                    alloc_a: c.alloc_a.clone(),
                    alloc_b: c.alloc_b.clone(),
                    score_a: c.score_a.clone().expect("fresh a-side scored"),
                    score_b: c.score_b.clone().expect("fresh b-side scored"),
                })
                .collect();
            memo.insert(a, b, fp_a, fp_b, exchanges);
        }

        // rank the improving candidates (enumeration order preserved)
        let mut ranked: Vec<RankedSwap> = Vec::new();
        let mut ranked_src: Vec<usize> = Vec::new();
        for (i, c) in cands.iter().enumerate() {
            let sa = c.score_a.as_ref().expect("candidate a-side scored");
            let sb = c.score_b.as_ref().expect("candidate b-side scored");
            // a candidate whose response tail escapes the shared grid
            // scores deceptively low — it must not win on a truncated
            // number (NaN mass from mass-less backends passes)
            if sa.mass < MIN_CANDIDATE_MASS || sb.mass < MIN_CANDIDATE_MASS {
                continue;
            }
            let cand_key = jobs[plans[c.a].job].arrival_rate * objective.key(sa)
                + jobs[plans[c.b].job].arrival_rate * objective.key(sb);
            let base_key = base[c.a] + base[c.b];
            if cand_key < base_key - IMPROVE_MARGIN {
                ranked.push(RankedSwap {
                    a: c.a,
                    b: c.b,
                    delta: cand_key - base_key,
                });
                ranked_src.push(i);
            }
        }
        let chosen = select_swaps(&ranked, plans.len());
        round.applied = chosen.len();
        if round_span.is_recording() {
            round_span.attr("scored", round.scored);
            round_span.attr("applied", round.applied);
        }
        if chosen.is_empty() {
            stats.rounds.push(round);
            break;
        }

        // apply each winning swap and §3-re-balance both touched jobs;
        // refine_with only ever improves its start score, so the
        // round's weighted objective decrease is preserved
        let mut mutated = vec![false; plans.len()];
        for pick in chosen {
            let c = &cands[ranked_src[pick]];
            mutated[c.a] = true;
            mutated[c.b] = true;
            let sides = [
                (c.a, c.alloc_a.clone(), c.score_a.clone().expect("scored")),
                (c.b, c.alloc_b.clone(), c.score_b.clone().expect("scored")),
            ];
            for (p, alloc, score) in sides {
                let (refined, rscore) = refine_with(
                    jobs[plans[p].job],
                    alloc.clone(),
                    servers,
                    &shared,
                    model,
                    objective,
                    POST_SWAP_REFINE_ROUNDS,
                    backend,
                )
                .unwrap_or_else(|_| (alloc.clone(), score.clone()));
                // the re-balance must not smuggle in a tail the shared
                // grid truncates: if refinement dropped captured mass
                // below the guard, keep the mass-checked candidate the
                // swap was accepted on (NaN mass still passes)
                if rscore.mass < MIN_CANDIDATE_MASS {
                    plans[p].alloc = alloc;
                    plans[p].score = score;
                } else {
                    plans[p].alloc = refined;
                    plans[p].score = rscore;
                }
            }
        }
        // drop every cached pair an applied swap touched — their
        // incumbents changed, so their exchange lists are stale (the
        // fingerprint check would reject them anyway; eager dropping
        // keeps the table small and the counters meaningful)
        if cfg.engine == SwapEngine::Incremental {
            memo.invalidate_touching(&mutated);
        }
        stats.rounds.push(round);
    }
    stats.memo_hits = memo.hits();
    stats.memo_misses = memo.misses();
    stats.memo_invalidated = memo.invalidated();
    stats.fabric = backend.fabric_stats();

    // publish the stat structs as registry views (sched.* / fabric.*),
    // so one snapshot covers the swap phase end to end
    if crate::obs::enabled() {
        let reg = crate::obs::registry();
        reg.counter("sched.swap.rounds").add(stats.rounds.len() as u64);
        reg.counter("sched.swap.candidates")
            .add(stats.rounds.iter().map(|r| r.candidates as u64).sum::<u64>());
        reg.counter("sched.swap.scored")
            .add(stats.rounds.iter().map(|r| r.scored as u64).sum::<u64>());
        reg.counter("sched.swap.applied")
            .add(stats.rounds.iter().map(|r| r.applied as u64).sum::<u64>());
        reg.counter("sched.memo.hits").add(stats.memo_hits as u64);
        reg.counter("sched.memo.misses").add(stats.memo_misses as u64);
        reg.counter("sched.memo.invalidated")
            .add(stats.memo_invalidated as u64);
        if let Some(f) = &stats.fabric {
            reg.gauge("fabric.workers").set(f.workers as f64);
            reg.gauge("fabric.waves_inline").set(f.waves_inline as f64);
            reg.gauge("fabric.waves_dispatched")
                .set(f.waves_dispatched as f64);
            reg.gauge("fabric.chunks_dispatched")
                .set(f.chunks_dispatched as f64);
            reg.gauge("fabric.max_queue_depth")
                .set(f.max_queue_depth as f64);
            reg.gauge("fabric.scratch_allocs").set(f.scratch_allocs as f64);
        }
    }

    plans.sort_by_key(|p| p.job);
    Ok((plans, stats))
}

/// One materialized cross-job swap candidate: plans `a` and `b`
/// exchange one server each; both regrouped assignments are re-run
/// through Alg. 2 rate scheduling (global server ids throughout).
struct SwapCandidate {
    a: usize,
    b: usize,
    alloc_a: Allocation,
    alloc_b: Allocation,
    score_a: Option<Score>,
    score_b: Option<Score>,
}

/// Enumerate every feasible (job-pair × server-pair) exchange, in
/// deterministic lexicographic order `(a, b, slot_a, slot_b)`. Pairs
/// whose combined base objective is non-finite (an unstable incumbent)
/// and exchanges Alg. 2 rejects are skipped.
fn enumerate_candidates(
    jobs: &[&Workflow],
    servers: &[Server],
    plans: &[JobPlan],
    model: ResponseModel,
    base: &[f64],
) -> Vec<SwapCandidate> {
    let mut out = Vec::new();
    for a in 0..plans.len() {
        for b in (a + 1)..plans.len() {
            if !(base[a] + base[b]).is_finite() {
                continue;
            }
            enumerate_pair(jobs, servers, plans, model, a, b, &mut out);
        }
    }
    out
}

/// Enumerate one job pair's feasible server exchanges in slot order
/// `(slot_a, slot_b)`, appending unscored candidates to `out`. Shared
/// by the full enumeration above and the incremental engine's
/// miss path — both therefore produce the same exchanges in the same
/// order for a given pair of incumbents.
fn enumerate_pair(
    jobs: &[&Workflow],
    servers: &[Server],
    plans: &[JobPlan],
    model: ResponseModel,
    a: usize,
    b: usize,
    out: &mut Vec<SwapCandidate>,
) {
    let (ja, jb) = (plans[a].job, plans[b].job);
    for ia in 0..plans[a].alloc.slot_server.len() {
        for ib in 0..plans[b].alloc.slot_server.len() {
            let mut ga = plans[a].alloc.slot_server.clone();
            let mut gb = plans[b].alloc.slot_server.clone();
            std::mem::swap(&mut ga[ia], &mut gb[ib]);
            let Ok(ca) = schedule_rates(jobs[ja], ga, servers, model) else {
                continue;
            };
            let Ok(cb) = schedule_rates(jobs[jb], gb, servers, model) else {
                continue;
            };
            out.push(SwapCandidate {
                a,
                b,
                alloc_a: ca,
                alloc_b: cb,
                score_a: None,
                score_b: None,
            });
        }
    }
}

/// Score every *unscored* candidate side on the shared grid through
/// `score_batch` waves: sides are grouped by the plan they score
/// against (enumeration order kept per group) and chunked at
/// `max_wave`. Returns the number of sides scored. Under
/// [`SwapEngine::Wave`] every side is unscored, so this is the whole
/// round; under [`SwapEngine::Incremental`] memo-served sides already
/// carry scores and are skipped, so only the miss pairs pay for
/// scoring. Because scoring is per-allocation (chunking never changes
/// values), the numbers are identical to the serial reference for any
/// backend whose `score_batch` equals mapping `score` (the trait's
/// default, and the contract all built-ins keep).
#[allow(clippy::too_many_arguments)]
fn score_unscored_sides(
    jobs: &[&Workflow],
    servers: &[Server],
    plans: &[JobPlan],
    model: ResponseModel,
    backend: &dyn ScoreBackend,
    grid: &GridSpec,
    max_wave: usize,
    cands: &mut [SwapCandidate],
) -> usize {
    let max_wave = max_wave.max(1);
    // one pass: bucket every unscored candidate side by the plan it
    // scores against, keeping enumeration order per bucket
    let mut buckets: Vec<Vec<(usize, bool)>> = vec![Vec::new(); plans.len()];
    let mut total = 0;
    for (i, c) in cands.iter().enumerate() {
        if c.score_a.is_none() {
            buckets[c.a].push((i, true));
            total += 1;
        }
        if c.score_b.is_none() {
            buckets[c.b].push((i, false));
            total += 1;
        }
    }
    for (p, bucket) in buckets.into_iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let wf = jobs[plans[p].job];
        let mut scored: Vec<Score> = Vec::with_capacity(bucket.len());
        for chunk in bucket.chunks(max_wave) {
            // score_batch takes owned allocations in one slice, so the
            // wave materializes per chunk
            let allocs: Vec<Allocation> = chunk
                .iter()
                .map(|&(i, is_a)| {
                    if is_a {
                        cands[i].alloc_a.clone()
                    } else {
                        cands[i].alloc_b.clone()
                    }
                })
                .collect();
            scored.extend(backend.score_batch(wf, &allocs, servers, grid, model));
        }
        // fail at the fault site if a custom backend violates the
        // one-Score-per-allocation contract, instead of leaving
        // unscored sides to panic later in ranking
        assert_eq!(
            scored.len(),
            bucket.len(),
            "ScoreBackend::score_batch of backend '{}' must return one Score \
             per allocation",
            backend.name()
        );
        for ((i, is_a), s) in bucket.into_iter().zip(scored) {
            if is_a {
                cands[i].score_a = Some(s);
            } else {
                cands[i].score_b = Some(s);
            }
        }
    }
    total
}

/// One improving cross-job swap as seen by the per-round selection:
/// the two plan indices it touches and the (negative) change it
/// promises in the load-weighted cluster objective.
#[derive(Clone, Copy, Debug)]
pub struct RankedSwap {
    /// First plan index the swap touches.
    pub a: usize,
    /// Second plan index the swap touches.
    pub b: usize,
    /// Weighted-objective change (improving swaps are negative; more
    /// negative is better).
    pub delta: f64,
}

/// Deterministic conflict resolution for one swap round: order the
/// candidates by `delta` ascending with [`f64::total_cmp`] (ties keep
/// input order, i.e. the engine's enumeration order), then greedily
/// keep every candidate whose two plans are still untouched this
/// round. Returns the indices of the kept candidates in application
/// order. Exposed so the conflict rule itself is directly testable.
pub fn select_swaps(ranked: &[RankedSwap], n_plans: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..ranked.len()).collect();
    order.sort_by(|&x, &y| ranked[x].delta.total_cmp(&ranked[y].delta).then(x.cmp(&y)));
    let mut touched = vec![false; n_plans];
    let mut applied = Vec::new();
    for i in order {
        let (a, b) = (ranked[i].a, ranked[i].b);
        if !touched[a] && !touched[b] {
            touched[a] = true;
            touched[b] = true;
            applied.push(i);
        }
    }
    applied
}

/// Load-weighted cluster objective of a plan set.
pub fn cluster_objective(plans: &[JobPlan], jobs: &[&Workflow], objective: Objective) -> f64 {
    plans
        .iter()
        .map(|p| jobs[p.job].arrival_rate * objective.key(&p.score))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::score::score_allocation_with;
    use crate::sched::refine::propose;

    fn pool() -> Vec<Server> {
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0])
    }

    #[test]
    fn partitions_are_disjoint_and_complete() {
        let j1 = Workflow::fig6(); // 6 slots, heavy (rate 8)
        let j2 = Workflow::tandem(3, 1.0); // 3 slots, light
        let jobs = [&j1, &j2];
        let plans = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        assert_eq!(plans.len(), 2);
        let mut all: Vec<usize> = plans
            .iter()
            .flat_map(|p| p.alloc.slot_server.clone())
            .collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(before, all.len(), "server used by two jobs");
        assert_eq!(before, 9);
        for p in &plans {
            assert!(p.score.is_stable(), "job {} unstable", p.job);
        }
    }

    #[test]
    fn all_jobs_share_one_grid() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let plans = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        assert_eq!(plans[0].grid, plans[1].grid, "jobs must share the grid");
    }

    #[test]
    fn pinned_grid_flows_through() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let pinned = GridSpec::new(0.02, 2048);
        let plans = multijob_allocate_with(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            Some(pinned),
        )
        .unwrap();
        for p in &plans {
            assert_eq!(p.grid, pinned);
        }
    }

    #[test]
    fn shared_grid_matches_per_job_grids_on_three_jobs() {
        // the shared-grid scores must agree with rescoring each job on
        // its own response-aware grid (grids differ only in resolution)
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let j3 = Workflow::forkjoin(2, 2.0);
        let jobs = [&j1, &j2, &j3];
        let servers = Server::pool_exponential(&[
            16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
        ]);
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        assert_eq!(plans.len(), 3);
        assert!(plans.iter().all(|p| p.grid == plans[0].grid));
        for p in &plans {
            // local view of this job's servers
            let local_pool: Vec<Server> = p
                .alloc
                .slot_server
                .iter()
                .enumerate()
                .map(|(k, &sid)| Server::new(k, servers[sid].dist.clone()))
                .collect();
            let local = Allocation {
                slot_server: (0..local_pool.len()).collect(),
                slot_rate: p.alloc.slot_rate.clone(),
            };
            let own_grid = GridSpec::auto_response(&local, &local_pool, ResponseModel::Mm1);
            let own = score_allocation_with(
                jobs[p.job],
                &local,
                &local_pool,
                &own_grid,
                ResponseModel::Mm1,
            );
            assert!(
                (own.mean - p.score.mean).abs() < 0.02 * own.mean,
                "job {}: shared-grid {} vs per-job-grid {}",
                p.job,
                p.score.mean,
                own.mean
            );
        }
    }

    #[test]
    fn heavy_job_gets_stronger_servers() {
        let heavy = Workflow::fig6(); // rate 8, depth 4
        let light = Workflow::tandem(3, 0.5);
        let jobs = [&heavy, &light];
        let servers = pool();
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let avg_rate = |p: &JobPlan| -> f64 {
            p.alloc
                .slot_server
                .iter()
                .map(|&sid| servers[sid].service_rate())
                .sum::<f64>()
                / p.alloc.slot_server.len() as f64
        };
        assert!(
            avg_rate(&plans[0]) > avg_rate(&plans[1]),
            "heavy job should hold faster servers on average"
        );
    }

    #[test]
    fn not_enough_servers_for_all_jobs() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::fig6();
        let jobs = [&j1, &j2];
        let servers = Server::pool_exponential(&[9.0; 10]); // need 12
        assert!(matches!(
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean),
            Err(SchedError::NotEnoughServers { need: 12, have: 10 })
        ));
    }

    #[test]
    fn single_job_reduces_to_proposed() {
        let j = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let jobs = [&j];
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let (_, direct) = propose(&j, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        assert!((plans[0].score.mean - direct.mean).abs() < 0.05 * direct.mean);
    }

    #[test]
    fn swap_refinement_does_not_hurt() {
        // cluster objective after refinement must be <= greedy-only
        // (we can't observe the intermediate, so check stability + sane
        // weighted objective)
        let j1 = Workflow::forkjoin(3, 6.0);
        let j2 = Workflow::tandem(2, 3.0);
        let jobs = [&j1, &j2];
        let plans =
            multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        let total = cluster_objective(&plans, &jobs, Objective::Mean);
        assert!(total.is_finite() && total > 0.0);
    }

    #[test]
    fn empty_job_set_is_empty_plan() {
        let plans =
            multijob_allocate(&[], &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        assert!(plans.is_empty());
    }

    #[test]
    fn nan_pressure_is_rejected_not_a_panic() {
        // regression: a degenerate job (NaN arrival rate leaking in
        // through the public field) used to panic the pressure sort's
        // partial_cmp().unwrap(); it must now surface as Infeasible
        let mut poisoned = Workflow::tandem(2, 1.0);
        poisoned.arrival_rate = f64::NAN;
        let healthy = Workflow::tandem(3, 1.0);
        let jobs = [&healthy, &poisoned];
        match multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean) {
            Err(SchedError::Infeasible(why)) => {
                assert!(why.contains("job 1"), "diagnosis names the job: {why}");
                assert!(why.contains("non-finite"), "diagnosis says why: {why}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // infinite arrival rate is equally degenerate
        let mut inf_job = Workflow::tandem(2, 1.0);
        inf_job.arrival_rate = f64::INFINITY;
        assert!(matches!(
            multijob_allocate(&[&inf_job], &pool(), ResponseModel::Mm1, Objective::Mean),
            Err(SchedError::Infeasible(_))
        ));
    }

    #[test]
    fn wave_engine_matches_serial_reference_bit_for_bit() {
        // the tentpole property: the wave engine's plans are the serial
        // reference pass's plans, bit for bit
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let serial = multijob_allocate_cfg(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &MultiJobConfig::serial_reference(),
        )
        .unwrap();
        let wave = multijob_allocate_cfg(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &MultiJobConfig::default(),
        )
        .unwrap();
        assert_eq!(serial.len(), wave.len());
        for (s, w) in serial.iter().zip(wave.iter()) {
            assert_eq!(s.job, w.job);
            assert_eq!(s.alloc, w.alloc);
            assert_eq!(s.grid, w.grid);
            assert_eq!(s.score.mean, w.score.mean);
            assert_eq!(s.score.var, w.score.var);
            assert_eq!(s.score.p99, w.score.p99);
        }
    }

    #[test]
    fn max_wave_chunking_does_not_change_plans() {
        // chunking a round's candidates into tiny waves only changes
        // scheduling granularity, never the plans
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let reference = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        for max_wave in [1usize, 7, 64] {
            let cfg = MultiJobConfig {
                max_wave,
                ..MultiJobConfig::default()
            };
            let got = multijob_allocate_cfg(
                &jobs,
                &pool(),
                ResponseModel::Mm1,
                Objective::Mean,
                &AnalyticBackend,
                None,
                &cfg,
            )
            .unwrap();
            for (r, g) in reference.iter().zip(got.iter()) {
                assert_eq!(r.alloc, g.alloc, "max_wave {max_wave}");
                assert_eq!(r.score.mean, g.score.mean);
            }
        }
    }

    #[test]
    fn incremental_engine_matches_serial_reference_bit_for_bit() {
        // the memoized engine must replay exactly what fresh
        // enumeration would have produced — plans, scores and grid
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let j3 = Workflow::forkjoin(2, 2.0);
        let jobs = [&j1, &j2, &j3];
        let servers = Server::pool_exponential(&[
            16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
        ]);
        let serial = multijob_allocate_cfg(
            &jobs,
            &servers,
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &MultiJobConfig::serial_reference(),
        )
        .unwrap();
        let incremental = multijob_allocate_cfg(
            &jobs,
            &servers,
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &MultiJobConfig::incremental(),
        )
        .unwrap();
        assert_eq!(serial.len(), incremental.len());
        for (s, i) in serial.iter().zip(incremental.iter()) {
            assert_eq!(s.job, i.job);
            assert_eq!(s.alloc, i.alloc);
            assert_eq!(s.grid, i.grid);
            assert_eq!(s.score.mean.to_bits(), i.score.mean.to_bits());
            assert_eq!(s.score.var.to_bits(), i.score.var.to_bits());
            assert_eq!(s.score.p99.to_bits(), i.score.p99.to_bits());
        }
    }

    #[test]
    fn report_counters_reconcile_per_round() {
        // the sides invariant: every candidate has exactly two sides,
        // each either scored through the backend or served by the memo
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let j3 = Workflow::forkjoin(2, 2.0);
        let j4 = Workflow::tandem(2, 3.0);
        let jobs = [&j1, &j2, &j3, &j4];
        let servers = Server::pool_exponential(&[
            18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
        ]);
        for cfg in [
            MultiJobConfig::default(),
            MultiJobConfig::serial_reference(),
            MultiJobConfig::incremental(),
        ] {
            let (_, stats) = multijob_allocate_report(
                &jobs,
                &servers,
                ResponseModel::Mm1,
                Objective::Mean,
                &AnalyticBackend,
                None,
                &cfg,
            )
            .unwrap();
            assert_eq!(stats.engine, cfg.engine);
            assert!(!stats.rounds.is_empty(), "job set produces candidates");
            for r in &stats.rounds {
                assert_eq!(
                    r.scored + r.memo_hits,
                    2 * r.candidates,
                    "{:?}: sides invariant broken in {r:?}",
                    cfg.engine
                );
            }
            match cfg.engine {
                SwapEngine::Incremental => {
                    assert_eq!(stats.rounds[0].memo_hits, 0, "round 1 is all fresh");
                    assert_eq!(
                        stats.scored_total(),
                        stats.memo_misses,
                        "every fresh side is inserted into the memo"
                    );
                    assert_eq!(
                        stats.rounds.iter().map(|r| r.memo_hits).sum::<usize>(),
                        stats.memo_hits
                    );
                }
                SwapEngine::Wave | SwapEngine::Serial => {
                    assert_eq!(stats.memo_hits, 0);
                    assert_eq!(stats.memo_misses, 0);
                    assert_eq!(stats.memo_invalidated, 0);
                    assert!(stats.rounds.iter().all(|r| r.memo_hits == 0));
                }
            }
        }
    }

    #[test]
    fn report_and_cfg_agree() {
        // the report surface is observation only: same plans
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let cfg = MultiJobConfig::incremental();
        let plain = multijob_allocate_cfg(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &cfg,
        )
        .unwrap();
        let (with_stats, _) = multijob_allocate_report(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            None,
            &cfg,
        )
        .unwrap();
        for (p, w) in plain.iter().zip(with_stats.iter()) {
            assert_eq!(p.alloc, w.alloc);
            assert_eq!(p.score.mean.to_bits(), w.score.mean.to_bits());
        }
    }

    #[test]
    fn hit_rate_is_guarded_and_bounded() {
        let mut stats = SwapStats::new(SwapEngine::Incremental);
        assert_eq!(stats.hit_rate(), 0.0, "0/0 guarded");
        stats.memo_hits = 3;
        stats.memo_misses = 9;
        assert!((stats.hit_rate() - 0.25).abs() < 1e-15);
    }

    #[test]
    fn sharded_backend_plans_jobs_bit_identically() {
        // the multijob engine through ShardedBackend(Analytic) must
        // produce the same partition, scores and shared grid as serial
        use crate::compose::backend::ShardedBackend;
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let serial =
            multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        let backend = ShardedBackend::new(&AnalyticBackend, 4);
        let sharded = multijob_allocate_with(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &backend,
            None,
        )
        .unwrap();
        assert_eq!(serial.len(), sharded.len());
        for (a, b) in serial.iter().zip(sharded.iter()) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.alloc, b.alloc);
            assert_eq!(a.grid, b.grid);
            assert_eq!(a.score.mean, b.score.mean);
            assert_eq!(a.score.var, b.score.var);
            assert_eq!(a.score.p99, b.score.p99);
        }
    }
}
