//! Multi-job scheduling: partition one heterogeneous pool across several
//! concurrent workflows (the paper's problem statement is "M
//! heterogeneous servers that collectively need to process a data
//! workflow" — production clusters run many at once).
//!
//! Algorithm (greedy + cross-job swap refinement):
//! 1. order jobs by offered load (entry rate × serial depth, the
//!    capacity pressure of the job);
//! 2. seed each job in order with Alg. 1/2 against the *remaining*
//!    pool (one pass; each job's pool view is kept);
//! 3. size **one shared evaluation grid** for the whole job set — the
//!    widest per-job seed-response grid, so every job's law fits —
//!    unless the caller pinned one;
//! 4. refine each seed (§3 balancing) on the shared grid;
//! 5. refine across jobs: try swapping any pair of servers between two
//!    jobs, keep the swap if the load-weighted objective sum improves —
//!    every candidate scored on the same shared grid, so swap decisions
//!    compare like with like.
//!
//! Scores are load-weighted so a job processing 8 tasks/s counts 4× a
//! 2 tasks/s job in the cluster objective (minimizing total expected
//! in-flight work). All scoring flows through an injected
//! [`ScoreBackend`] ([`multijob_allocate_with`]); [`multijob_allocate`]
//! is the analytic-backend convenience.

use crate::compose::backend::{AnalyticBackend, ScoreBackend};
use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::algorithms::allocate_with;
use crate::sched::refine::refine_with;
use crate::sched::response::ResponseModel;
use crate::sched::schedule_rates;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// One job's placement in a multi-job plan.
#[derive(Clone, Debug)]
pub struct JobPlan {
    /// Index into the submitted job list.
    pub job: usize,
    /// Allocation in *global* server ids.
    pub alloc: Allocation,
    /// Exact score on the shared cluster grid.
    pub score: Score,
    /// The shared evaluation grid every job in the plan set was scored
    /// on (identical across the returned plans).
    pub grid: GridSpec,
}

/// Partition `servers` across `jobs` and allocate each, scoring with
/// the default [`AnalyticBackend`] on an auto-sized shared grid.
pub fn multijob_allocate(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
) -> Result<Vec<JobPlan>, SchedError> {
    multijob_allocate_with(jobs, servers, model, objective, &AnalyticBackend, None)
}

/// Partition `servers` across `jobs` with an injected scoring backend
/// and an optional pinned evaluation grid.
///
/// All jobs are evaluated on **one shared grid**: `grid` when pinned,
/// else the widest of the per-job Alg. 1/2 seed-response grids (sized
/// once, up front — jobs are not re-derived a grid each). This is what
/// lets a comparison of swap candidates across jobs, and downstream
/// consumers of [`JobPlan::score`], compare numbers computed on the
/// same support.
pub fn multijob_allocate_with(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
    backend: &dyn ScoreBackend,
    grid: Option<GridSpec>,
) -> Result<Vec<JobPlan>, SchedError> {
    if jobs.is_empty() {
        return Ok(Vec::new());
    }
    let need: usize = jobs.iter().map(|w| w.slots()).sum();
    if servers.len() < need {
        return Err(SchedError::NotEnoughServers {
            need,
            have: servers.len(),
        });
    }

    // 1. order by capacity pressure. A degenerate job (NaN/infinite
    // arrival rate, e.g. from a poisoned fit upstream) is rejected with
    // a diagnosis instead of panicking the sort or silently corrupting
    // the greedy order; the sort itself uses the NaN-total `total_cmp`
    // as defense in depth.
    let pressure =
        |w: &Workflow| -> f64 { w.arrival_rate * w.serial_depth() as f64 };
    for (j, w) in jobs.iter().enumerate() {
        let p = pressure(w);
        if !p.is_finite() {
            return Err(SchedError::Infeasible(format!(
                "job {j} has non-finite capacity pressure {p} \
                 (arrival_rate {}, serial depth {})",
                w.arrival_rate,
                w.serial_depth()
            )));
        }
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| {
        pressure(jobs[b])
            .total_cmp(&pressure(jobs[a]))
            .then(a.cmp(&b))
    });

    // 2. one greedy Alg. 1/2 seed pass: each job seeded against the
    // remaining pool; the pool view each job saw is kept so refinement
    // can reuse it (refinement only permutes a seed's server set, so
    // the removal order is identical either way)
    let mut remaining: Vec<Server> = servers.to_vec();
    let mut staged: Vec<(usize, Allocation, Vec<Server>)> = Vec::with_capacity(jobs.len());
    for &j in &order {
        let seed = allocate_with(jobs[j], &remaining, model)?;
        let pool_view = remaining.clone();
        let mut used = seed.slot_server.clone();
        used.sort_unstable_by(|a, b| b.cmp(a));
        for i in used {
            remaining.remove(i);
        }
        staged.push((j, seed, pool_view));
    }

    // 3. one shared evaluation grid for the whole job set: the widest
    // (largest dt, i.e. longest horizon) of the per-job seed-response
    // grids, sized against the laws the backend actually scores
    let shared = grid.unwrap_or_else(|| {
        staged
            .iter()
            .map(|(_, seed, pool)| {
                let pool = backend.resolve_scoring_pool(pool);
                GridSpec::auto_response(seed, &pool, model)
            })
            // total_cmp: a degenerate per-job dt must widen the merge
            // deterministically, never panic it (auto grids clamp
            // non-finite horizons, so dt is finite here by construction)
            .max_by(|a, b| a.dt.total_cmp(&b.dt))
            .expect("staged is non-empty: jobs.is_empty() returned early")
    });

    // 4. refine each job on the shared grid against its pool view
    let mut plans: Vec<JobPlan> = Vec::with_capacity(jobs.len());
    for (j, seed, pool_view) in staged {
        let (local_alloc, score) =
            refine_with(jobs[j], seed, &pool_view, &shared, model, objective, 8, backend)?;
        // translate local pool indices to global server ids (ids stay
        // global; positions shifted as earlier jobs consumed servers)
        let global: Vec<usize> = local_alloc
            .slot_server
            .iter()
            .map(|&i| pool_view[i].id)
            .collect();
        plans.push(JobPlan {
            job: j,
            alloc: Allocation {
                slot_server: global,
                slot_rate: local_alloc.slot_rate,
            },
            score,
            grid: shared,
        });
    }

    // 5. cross-job pairwise swap refinement on the weighted objective,
    // every candidate rescored on the same shared grid
    let weight = |j: usize| jobs[j].arrival_rate;
    let rescore = |j: usize, global_assign: &[usize]| -> Option<(Allocation, Score)> {
        // build a local pool view for this job's servers only
        let pool: Vec<Server> = global_assign
            .iter()
            .map(|&sid| servers[sid].clone())
            .collect();
        let local: Vec<usize> = (0..pool.len()).collect();
        let alloc = schedule_rates(jobs[j], local, &pool, model).ok()?;
        let (refined, score) =
            refine_with(jobs[j], alloc, &pool, &shared, model, objective, 4, backend).ok()?;
        // a candidate whose response tail escapes the shared grid scores
        // deceptively low (moments are mass-normalized) — it must not be
        // allowed to win a swap on a truncated number. (Backends that do
        // not track mass report NaN, which passes.)
        if score.mass < 0.95 {
            return None;
        }
        Some((
            Allocation {
                slot_server: refined
                    .slot_server
                    .iter()
                    .map(|&i| global_assign[i])
                    .collect(),
                slot_rate: refined.slot_rate,
            },
            score,
        ))
    };

    let mut improved = true;
    let mut rounds = 0;
    while improved && rounds < 4 {
        improved = false;
        rounds += 1;
        for a in 0..plans.len() {
            for b in (a + 1)..plans.len() {
                let (ja, jb) = (plans[a].job, plans[b].job);
                let base = weight(ja) * objective.key(&plans[a].score)
                    + weight(jb) * objective.key(&plans[b].score);
                if !base.is_finite() {
                    continue;
                }
                // try swapping each server pair between jobs a and b
                'outer: for ia in 0..plans[a].alloc.slot_server.len() {
                    for ib in 0..plans[b].alloc.slot_server.len() {
                        let mut ga = plans[a].alloc.slot_server.clone();
                        let mut gb = plans[b].alloc.slot_server.clone();
                        std::mem::swap(&mut ga[ia], &mut gb[ib]);
                        let (Some((na, sa)), Some((nb, sb))) =
                            (rescore(ja, &ga), rescore(jb, &gb))
                        else {
                            continue;
                        };
                        let cand =
                            weight(ja) * objective.key(&sa) + weight(jb) * objective.key(&sb);
                        if cand < base - 1e-9 {
                            plans[a].alloc = na;
                            plans[a].score = sa;
                            plans[b].alloc = nb;
                            plans[b].score = sb;
                            improved = true;
                            break 'outer;
                        }
                    }
                }
            }
        }
    }

    plans.sort_by_key(|p| p.job);
    Ok(plans)
}

/// Load-weighted cluster objective of a plan set.
pub fn cluster_objective(plans: &[JobPlan], jobs: &[&Workflow], objective: Objective) -> f64 {
    plans
        .iter()
        .map(|p| jobs[p.job].arrival_rate * objective.key(&p.score))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::score::score_allocation_with;
    use crate::sched::refine::propose;

    fn pool() -> Vec<Server> {
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0])
    }

    #[test]
    fn partitions_are_disjoint_and_complete() {
        let j1 = Workflow::fig6(); // 6 slots, heavy (rate 8)
        let j2 = Workflow::tandem(3, 1.0); // 3 slots, light
        let jobs = [&j1, &j2];
        let plans = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        assert_eq!(plans.len(), 2);
        let mut all: Vec<usize> = plans
            .iter()
            .flat_map(|p| p.alloc.slot_server.clone())
            .collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(before, all.len(), "server used by two jobs");
        assert_eq!(before, 9);
        for p in &plans {
            assert!(p.score.is_stable(), "job {} unstable", p.job);
        }
    }

    #[test]
    fn all_jobs_share_one_grid() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let plans = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        assert_eq!(plans[0].grid, plans[1].grid, "jobs must share the grid");
    }

    #[test]
    fn pinned_grid_flows_through() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let pinned = GridSpec::new(0.02, 2048);
        let plans = multijob_allocate_with(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &AnalyticBackend,
            Some(pinned),
        )
        .unwrap();
        for p in &plans {
            assert_eq!(p.grid, pinned);
        }
    }

    #[test]
    fn shared_grid_matches_per_job_grids_on_three_jobs() {
        // the shared-grid scores must agree with rescoring each job on
        // its own response-aware grid (grids differ only in resolution)
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let j3 = Workflow::forkjoin(2, 2.0);
        let jobs = [&j1, &j2, &j3];
        let servers = Server::pool_exponential(&[
            16.0, 14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.5, 6.0, 5.0, 4.0,
        ]);
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        assert_eq!(plans.len(), 3);
        assert!(plans.iter().all(|p| p.grid == plans[0].grid));
        for p in &plans {
            // local view of this job's servers
            let local_pool: Vec<Server> = p
                .alloc
                .slot_server
                .iter()
                .enumerate()
                .map(|(k, &sid)| Server::new(k, servers[sid].dist.clone()))
                .collect();
            let local = Allocation {
                slot_server: (0..local_pool.len()).collect(),
                slot_rate: p.alloc.slot_rate.clone(),
            };
            let own_grid = GridSpec::auto_response(&local, &local_pool, ResponseModel::Mm1);
            let own = score_allocation_with(
                jobs[p.job],
                &local,
                &local_pool,
                &own_grid,
                ResponseModel::Mm1,
            );
            assert!(
                (own.mean - p.score.mean).abs() < 0.02 * own.mean,
                "job {}: shared-grid {} vs per-job-grid {}",
                p.job,
                p.score.mean,
                own.mean
            );
        }
    }

    #[test]
    fn heavy_job_gets_stronger_servers() {
        let heavy = Workflow::fig6(); // rate 8, depth 4
        let light = Workflow::tandem(3, 0.5);
        let jobs = [&heavy, &light];
        let servers = pool();
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let avg_rate = |p: &JobPlan| -> f64 {
            p.alloc
                .slot_server
                .iter()
                .map(|&sid| servers[sid].service_rate())
                .sum::<f64>()
                / p.alloc.slot_server.len() as f64
        };
        assert!(
            avg_rate(&plans[0]) > avg_rate(&plans[1]),
            "heavy job should hold faster servers on average"
        );
    }

    #[test]
    fn not_enough_servers_for_all_jobs() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::fig6();
        let jobs = [&j1, &j2];
        let servers = Server::pool_exponential(&[9.0; 10]); // need 12
        assert!(matches!(
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean),
            Err(SchedError::NotEnoughServers { need: 12, have: 10 })
        ));
    }

    #[test]
    fn single_job_reduces_to_proposed() {
        let j = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let jobs = [&j];
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let (_, direct) = propose(&j, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        assert!((plans[0].score.mean - direct.mean).abs() < 0.05 * direct.mean);
    }

    #[test]
    fn swap_refinement_does_not_hurt() {
        // cluster objective after refinement must be <= greedy-only
        // (we can't observe the intermediate, so check stability + sane
        // weighted objective)
        let j1 = Workflow::forkjoin(3, 6.0);
        let j2 = Workflow::tandem(2, 3.0);
        let jobs = [&j1, &j2];
        let plans =
            multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        let total = cluster_objective(&plans, &jobs, Objective::Mean);
        assert!(total.is_finite() && total > 0.0);
    }

    #[test]
    fn empty_job_set_is_empty_plan() {
        let plans =
            multijob_allocate(&[], &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        assert!(plans.is_empty());
    }

    #[test]
    fn nan_pressure_is_rejected_not_a_panic() {
        // regression: a degenerate job (NaN arrival rate leaking in
        // through the public field) used to panic the pressure sort's
        // partial_cmp().unwrap(); it must now surface as Infeasible
        let mut poisoned = Workflow::tandem(2, 1.0);
        poisoned.arrival_rate = f64::NAN;
        let healthy = Workflow::tandem(3, 1.0);
        let jobs = [&healthy, &poisoned];
        match multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean) {
            Err(SchedError::Infeasible(why)) => {
                assert!(why.contains("job 1"), "diagnosis names the job: {why}");
                assert!(why.contains("non-finite"), "diagnosis says why: {why}");
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // infinite arrival rate is equally degenerate
        let mut inf_job = Workflow::tandem(2, 1.0);
        inf_job.arrival_rate = f64::INFINITY;
        assert!(matches!(
            multijob_allocate(&[&inf_job], &pool(), ResponseModel::Mm1, Objective::Mean),
            Err(SchedError::Infeasible(_))
        ));
    }

    #[test]
    fn sharded_backend_plans_jobs_bit_identically() {
        // the multijob engine through ShardedBackend(Analytic) must
        // produce the same partition, scores and shared grid as serial
        use crate::compose::backend::ShardedBackend;
        let j1 = Workflow::fig6();
        let j2 = Workflow::tandem(3, 1.0);
        let jobs = [&j1, &j2];
        let serial =
            multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        let backend = ShardedBackend::new(&AnalyticBackend, 4);
        let sharded = multijob_allocate_with(
            &jobs,
            &pool(),
            ResponseModel::Mm1,
            Objective::Mean,
            &backend,
            None,
        )
        .unwrap();
        assert_eq!(serial.len(), sharded.len());
        for (a, b) in serial.iter().zip(sharded.iter()) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.alloc, b.alloc);
            assert_eq!(a.grid, b.grid);
            assert_eq!(a.score.mean, b.score.mean);
            assert_eq!(a.score.var, b.score.var);
            assert_eq!(a.score.p99, b.score.p99);
        }
    }
}
