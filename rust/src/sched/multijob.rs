//! Multi-job scheduling: partition one heterogeneous pool across several
//! concurrent workflows (the paper's problem statement is "M
//! heterogeneous servers that collectively need to process a data
//! workflow" — production clusters run many at once).
//!
//! Algorithm (greedy + cross-job swap refinement):
//! 1. order jobs by offered load (entry rate × serial depth, the
//!    capacity pressure of the job);
//! 2. allocate each job in order with [`propose`] against the
//!    *remaining* pool (the allocator keeps the fastest `slots` servers
//!    and the refinement places them);
//! 3. refine across jobs: try swapping any pair of servers between two
//!    jobs, keep the swap if the load-weighted objective sum improves.
//!
//! Scores are load-weighted so a job processing 8 tasks/s counts 4× a
//! 2 tasks/s job in the cluster objective (minimizing total expected
//! in-flight work).

use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::refine::{propose, refine};
use crate::sched::response::ResponseModel;
use crate::sched::schedule_rates;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// One job's placement in a multi-job plan.
#[derive(Clone, Debug)]
pub struct JobPlan {
    /// Index into the submitted job list.
    pub job: usize,
    /// Allocation in *global* server ids.
    pub alloc: Allocation,
    /// Exact score under the job's own grid.
    pub score: Score,
}

/// Partition `servers` across `jobs` and allocate each.
pub fn multijob_allocate(
    jobs: &[&Workflow],
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
) -> Result<Vec<JobPlan>, SchedError> {
    let need: usize = jobs.iter().map(|w| w.slots()).sum();
    if servers.len() < need {
        return Err(SchedError::NotEnoughServers {
            need,
            have: servers.len(),
        });
    }

    // 1. order by capacity pressure
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    let pressure =
        |w: &Workflow| -> f64 { w.arrival_rate * w.serial_depth() as f64 };
    order.sort_by(|&a, &b| {
        pressure(jobs[b])
            .partial_cmp(&pressure(jobs[a]))
            .unwrap()
            .then(a.cmp(&b))
    });

    // 2. greedy allocation against the remaining pool
    let mut remaining: Vec<Server> = servers.to_vec();
    let mut plans: Vec<JobPlan> = Vec::with_capacity(jobs.len());
    for &j in &order {
        let wf = jobs[j];
        let (local_alloc, score) = propose(wf, &remaining, model, objective)?;
        // translate local pool indices to global server ids, and drop the
        // used servers from the pool
        let used_local: Vec<usize> = local_alloc.slot_server.clone();
        let global: Vec<usize> = used_local.iter().map(|&i| remaining[i].id).collect();
        let mut used_sorted = used_local.clone();
        used_sorted.sort_unstable_by(|a, b| b.cmp(a));
        for i in used_sorted {
            remaining.remove(i);
        }
        // re-index the remaining pool (ids stay global; positions shift)
        plans.push(JobPlan {
            job: j,
            alloc: Allocation {
                slot_server: global,
                slot_rate: local_alloc.slot_rate,
            },
            score,
        });
    }

    // 3. cross-job pairwise swap refinement on the weighted objective
    let weight = |j: usize| jobs[j].arrival_rate;
    let rescore = |j: usize, global_assign: &[usize]| -> Option<(Allocation, Score)> {
        // build a local pool view for this job's servers only
        let pool: Vec<Server> = global_assign
            .iter()
            .map(|&sid| servers[sid].clone())
            .collect();
        let local: Vec<usize> = (0..pool.len()).collect();
        let alloc = schedule_rates(jobs[j], local, &pool, model).ok()?;
        let grid = GridSpec::auto_response(&alloc, &pool, model);
        let (refined, score) =
            refine(jobs[j], alloc, &pool, &grid, model, objective, 4).ok()?;
        Some((
            Allocation {
                slot_server: refined
                    .slot_server
                    .iter()
                    .map(|&i| global_assign[i])
                    .collect(),
                slot_rate: refined.slot_rate,
            },
            score,
        ))
    };

    let mut improved = true;
    let mut rounds = 0;
    while improved && rounds < 4 {
        improved = false;
        rounds += 1;
        for a in 0..plans.len() {
            for b in (a + 1)..plans.len() {
                let (ja, jb) = (plans[a].job, plans[b].job);
                let base = weight(ja) * objective.key(&plans[a].score)
                    + weight(jb) * objective.key(&plans[b].score);
                if !base.is_finite() {
                    continue;
                }
                // try swapping each server pair between jobs a and b
                'outer: for ia in 0..plans[a].alloc.slot_server.len() {
                    for ib in 0..plans[b].alloc.slot_server.len() {
                        let mut ga = plans[a].alloc.slot_server.clone();
                        let mut gb = plans[b].alloc.slot_server.clone();
                        std::mem::swap(&mut ga[ia], &mut gb[ib]);
                        let (Some((na, sa)), Some((nb, sb))) =
                            (rescore(ja, &ga), rescore(jb, &gb))
                        else {
                            continue;
                        };
                        let cand =
                            weight(ja) * objective.key(&sa) + weight(jb) * objective.key(&sb);
                        if cand < base - 1e-9 {
                            plans[a].alloc = na;
                            plans[a].score = sa;
                            plans[b].alloc = nb;
                            plans[b].score = sb;
                            improved = true;
                            break 'outer;
                        }
                    }
                }
            }
        }
    }

    plans.sort_by_key(|p| p.job);
    Ok(plans)
}

/// Load-weighted cluster objective of a plan set.
pub fn cluster_objective(plans: &[JobPlan], jobs: &[&Workflow], objective: Objective) -> f64 {
    plans
        .iter()
        .map(|p| jobs[p.job].arrival_rate * objective.key(&p.score))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Vec<Server> {
        Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0])
    }

    #[test]
    fn partitions_are_disjoint_and_complete() {
        let j1 = Workflow::fig6(); // 6 slots, heavy (rate 8)
        let j2 = Workflow::tandem(3, 1.0); // 3 slots, light
        let jobs = [&j1, &j2];
        let plans = multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean)
            .unwrap();
        assert_eq!(plans.len(), 2);
        let mut all: Vec<usize> = plans
            .iter()
            .flat_map(|p| p.alloc.slot_server.clone())
            .collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(before, all.len(), "server used by two jobs");
        assert_eq!(before, 9);
        for p in &plans {
            assert!(p.score.is_stable(), "job {} unstable", p.job);
        }
    }

    #[test]
    fn heavy_job_gets_stronger_servers() {
        let heavy = Workflow::fig6(); // rate 8, depth 4
        let light = Workflow::tandem(3, 0.5);
        let jobs = [&heavy, &light];
        let servers = pool();
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let avg_rate = |p: &JobPlan| -> f64 {
            p.alloc
                .slot_server
                .iter()
                .map(|&sid| servers[sid].service_rate())
                .sum::<f64>()
                / p.alloc.slot_server.len() as f64
        };
        assert!(
            avg_rate(&plans[0]) > avg_rate(&plans[1]),
            "heavy job should hold faster servers on average"
        );
    }

    #[test]
    fn not_enough_servers_for_all_jobs() {
        let j1 = Workflow::fig6();
        let j2 = Workflow::fig6();
        let jobs = [&j1, &j2];
        let servers = Server::pool_exponential(&[9.0; 10]); // need 12
        assert!(matches!(
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean),
            Err(SchedError::NotEnoughServers { need: 12, have: 10 })
        ));
    }

    #[test]
    fn single_job_reduces_to_proposed() {
        let j = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let jobs = [&j];
        let plans =
            multijob_allocate(&jobs, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        let (_, direct) = propose(&j, &servers, ResponseModel::Mm1, Objective::Mean).unwrap();
        assert!((plans[0].score.mean - direct.mean).abs() < 0.05 * direct.mean);
    }

    #[test]
    fn swap_refinement_does_not_hurt() {
        // cluster objective after refinement must be <= greedy-only
        // (we can't observe the intermediate, so check stability + sane
        // weighted objective)
        let j1 = Workflow::forkjoin(3, 6.0);
        let j2 = Workflow::tandem(2, 3.0);
        let jobs = [&j1, &j2];
        let plans =
            multijob_allocate(&jobs, &pool(), ResponseModel::Mm1, Objective::Mean).unwrap();
        let total = cluster_objective(&plans, &jobs, Objective::Mean);
        assert!(total.is_finite() && total > 0.0);
    }
}
