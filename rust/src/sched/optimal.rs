//! The exhaustive "optimal" reference (paper §3: "chooses the best
//! allocation of servers, using exhaustive search over all possible
//! cases, to DCCs and uses optimal task scheduling for PDCCs").
//!
//! Two-stage search keeps exact scoring affordable:
//! 1. enumerate every injective assignment of servers to slots and rank
//!    by the cheap recursive mean-RT estimator (`branch_mean_rt`);
//! 2. exactly (grid-)score the `SHORTLIST` best candidates and return the
//!    winner under the requested [`Objective`].
//!
//! With the paper's 6-server / 6-slot Fig. 6 setup this is 720 cheap
//! evaluations + 32 exact scores. A hard cap guards against accidental
//! factorial blowups on big pools.

use crate::compose::backend::{AnalyticBackend, ScoreBackend};
use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::algorithms::{branch_mean_rt, schedule_rates};
use crate::sched::allocation::{Allocation, SchedError};
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::Objective;

/// Exact-scored shortlist size.
const SHORTLIST: usize = 32;
/// Refuse to enumerate more candidate assignments than this.
const MAX_CANDIDATES: usize = 2_000_000;

/// Exhaustive optimal allocation under `objective` with the default
/// [`AnalyticBackend`] (engine layer — surfaced publicly as
/// [`crate::plan::OptimalPolicy`]).
///
/// Returns the winning allocation and its exact score.
pub fn exhaustive(
    wf: &Workflow,
    servers: &[Server],
    grid: &GridSpec,
    objective: Objective,
    model: ResponseModel,
) -> Result<(Allocation, Score), SchedError> {
    exhaustive_with(wf, servers, grid, objective, model, &AnalyticBackend)
}

/// Exhaustive optimal allocation, exact-scoring the shortlist through
/// `backend` (one [`ScoreBackend::score_batch`] wave, so the PJRT
/// scorer evaluates the whole shortlist fused and a
/// [`ShardedBackend`](crate::compose::backend::ShardedBackend) scores
/// shortlist chunks on parallel workers). With [`AnalyticBackend`] —
/// sharded or not — this is bit-identical to [`exhaustive`].
pub fn exhaustive_with(
    wf: &Workflow,
    servers: &[Server],
    grid: &GridSpec,
    objective: Objective,
    model: ResponseModel,
    backend: &dyn ScoreBackend,
) -> Result<(Allocation, Score), SchedError> {
    let slots = wf.slots();
    if servers.len() < slots {
        return Err(SchedError::NotEnoughServers {
            need: slots,
            have: servers.len(),
        });
    }
    let n_cand = count_injections(servers.len(), slots);
    if n_cand > MAX_CANDIDATES {
        return Err(SchedError::Infeasible(format!(
            "exhaustive search over {n_cand} assignments exceeds cap {MAX_CANDIDATES}"
        )));
    }

    // stage 1: cheap ranking of every injective assignment
    let mut ranked: Vec<(f64, Vec<usize>)> = Vec::new();
    let mut current = Vec::with_capacity(slots);
    let mut used = vec![false; servers.len()];
    enumerate(
        wf,
        servers,
        model,
        &mut current,
        &mut used,
        slots,
        &mut ranked,
    );
    if ranked.is_empty() {
        return Err(SchedError::Infeasible(
            "no stable assignment exists for the offered load".into(),
        ));
    }
    // total_cmp: a NaN estimate from a degenerate law ranks last
    // instead of panicking the sort
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0));

    // stage 2: exact scoring of the shortlist, one backend wave
    let mut shortlist: Vec<Allocation> = ranked
        .into_iter()
        .take(SHORTLIST)
        .filter_map(|(_, assign)| schedule_rates(wf, assign, servers, model).ok())
        .collect();
    let scores = backend.score_batch(wf, &shortlist, servers, grid, model);
    let mut best: Option<(usize, Score)> = None;
    for (idx, score) in scores.into_iter().enumerate() {
        if !score.is_stable() {
            continue;
        }
        let better = match &best {
            None => true,
            Some((_, b)) => objective.key(&score) < objective.key(b),
        };
        if better {
            best = Some((idx, score));
        }
    }
    best.map(|(idx, score)| (shortlist.swap_remove(idx), score))
        .ok_or_else(|| SchedError::Infeasible("no stable shortlist candidate".into()))
}

fn enumerate(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    current: &mut Vec<usize>,
    used: &mut [bool],
    slots: usize,
    out: &mut Vec<(f64, Vec<usize>)>,
) {
    if current.len() == slots {
        // cheap estimator: recursive mean RT from the root
        if let Some(mean) = branch_mean_rt(wf.root(), wf.arrival_rate, current, servers, model)
        {
            out.push((mean, current.clone()));
        }
        return;
    }
    for sid in 0..servers.len() {
        if !used[sid] {
            used[sid] = true;
            current.push(sid);
            enumerate(wf, servers, model, current, used, slots, out);
            current.pop();
            used[sid] = false;
        }
    }
}

fn count_injections(pool: usize, slots: usize) -> usize {
    ((pool - slots + 1)..=pool).product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::score::score_allocation_with;
    use crate::sched::algorithms::{allocate_with, baseline_allocate_split, SplitPolicy};

    fn fig6() -> (Workflow, Vec<Server>, GridSpec) {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let grid = GridSpec::auto_pool(&wf, &servers);
        (wf, servers, grid)
    }

    #[test]
    fn optimal_beats_or_ties_everyone() {
        let (wf, servers, grid) = fig6();
        let model = ResponseModel::Mm1;
        let (_, opt) = exhaustive(&wf, &servers, &grid, Objective::Mean, model).unwrap();
        let ours = allocate_with(&wf, &servers, model).unwrap();
        let ours_s = score_allocation_with(&wf, &ours, &servers, &grid, model);
        let base =
            baseline_allocate_split(&wf, &servers, model, SplitPolicy::Uniform).unwrap();
        let base_s = score_allocation_with(&wf, &base, &servers, &grid, model);
        assert!(opt.mean <= ours_s.mean + 1e-6, "opt {} ours {}", opt.mean, ours_s.mean);
        assert!(opt.mean <= base_s.mean + 1e-6, "opt {} base {}", opt.mean, base_s.mean);
    }

    #[test]
    fn sharded_exhaustive_is_bit_identical() {
        use crate::compose::backend::{AnalyticBackend, ShardedBackend};
        let (wf, servers, grid) = fig6();
        let model = ResponseModel::Mm1;
        let (serial_alloc, serial_score) =
            exhaustive(&wf, &servers, &grid, Objective::Mean, model).unwrap();
        for shards in [2usize, 8] {
            let backend = ShardedBackend::new(&AnalyticBackend, shards);
            let (alloc, score) =
                exhaustive_with(&wf, &servers, &grid, Objective::Mean, model, &backend)
                    .unwrap();
            assert_eq!(alloc, serial_alloc, "{shards} shards changed the winner");
            assert_eq!(score.mean, serial_score.mean);
            assert_eq!(score.p99, serial_score.p99);
        }
    }

    #[test]
    fn injection_count() {
        assert_eq!(count_injections(6, 6), 720);
        assert_eq!(count_injections(8, 6), 20160);
        assert_eq!(count_injections(6, 1), 6);
    }

    #[test]
    fn too_few_servers_rejected() {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0]);
        let grid = GridSpec::new(0.01, 1024);
        assert!(matches!(
            exhaustive(&wf, &servers, &grid, Objective::Mean, ResponseModel::Mm1),
            Err(SchedError::NotEnoughServers { .. })
        ));
    }

    #[test]
    fn overload_is_infeasible() {
        // tandem of 2 with lambda above every server's capacity
        let wf = Workflow::tandem(2, 10.0);
        let servers = Server::pool_exponential(&[2.0, 3.0]);
        let grid = GridSpec::new(0.01, 1024);
        assert!(exhaustive(&wf, &servers, &grid, Objective::Mean, ResponseModel::Mm1)
            .is_err());
    }

    #[test]
    fn surplus_pool_allowed() {
        // 7 servers, 6 slots: 5040 assignments, still fast
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.5]);
        let grid = GridSpec::auto_pool(&wf, &servers);
        let (alloc, score) =
            exhaustive(&wf, &servers, &grid, Objective::Mean, ResponseModel::Mm1)
                .unwrap();
        assert!(score.is_stable());
        alloc.validate(&wf, servers.len()).unwrap();
    }
}
