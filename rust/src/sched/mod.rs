//! Resource allocation and task (rate) scheduling — the paper's
//! contribution (Algorithms 1–3).
//!
//! The public planning surface is [`crate::plan::Planner`] with its
//! policy objects; this module hosts the engine underneath:
//!
//! * [`allocate_with`] — Alg. 1/2 sort-matching + equilibrium rates
//!   (behind [`crate::plan::SdccPolicy`]);
//! * [`baseline_allocate_split`] — the §3 heuristic comparator (behind
//!   [`crate::plan::BaselinePolicy`]);
//! * [`refine::propose`] / [`refine::refine_with`] — the §3 min-max
//!   balancing (behind [`crate::plan::ProposedPolicy`]), scoring
//!   through an injected [`crate::compose::backend::ScoreBackend`];
//! * [`optimal::exhaustive_with`] — exhaustive-search reference (behind
//!   [`crate::plan::OptimalPolicy`]);
//! * [`equilibrium`] — Algorithm 2's rate scheduling;
//! * [`response`] — service-law → response-law queueing models;
//! * [`multijob`] — pool partitioning across concurrent workflows;
//! * [`memo`] — the cross-round swap memo table behind
//!   [`multijob::SwapEngine::Incremental`].
//!
//! The deprecated legacy free functions (`sdcc_allocate`,
//! `baseline_allocate`, `proposed_allocate`, `optimal_allocate`) were
//! removed in 0.4.0 — `docs/MIGRATION.md` maps each onto the
//! [`Planner`](crate::plan::Planner) call that replaced it.

pub mod algorithms;
pub mod allocation;
pub mod capacity;
pub mod equilibrium;
pub mod memo;
pub mod multijob;
pub mod optimal;
pub mod refine;
pub mod response;
pub mod server;

pub use algorithms::{allocate_with, baseline_allocate_split, schedule_rates, SplitPolicy};
pub use allocation::{Allocation, SchedError};
pub use memo::{AllocFingerprint, CachedExchange, SwapMemo};
pub use refine::{propose, refine, refine_with};
pub use response::ResponseModel;

use crate::compose::score::Score;

/// What the administrator optimizes (paper §3: "we aim for throughput or
/// response time; our strategy can also be used for other objectives").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize mean end-to-end response time.
    Mean,
    /// Minimize response-time variance (tail stabilization).
    Variance,
    /// Minimize the 99th percentile.
    P99,
}

impl Objective {
    /// Sort key: smaller is better. Infeasible candidates carry the
    /// [`Score::unstable`] infinity sentinel, so their key is `+∞` and
    /// they lose every comparison; a NaN component (a degenerate fitted
    /// law leaking through a backend that skipped the sentinel
    /// contract) also maps to `+∞`, so a poisoned candidate can never
    /// win an ordering — keys are always comparable with plain `<` or
    /// [`f64::total_cmp`].
    pub fn key(&self, s: &Score) -> f64 {
        let k = match self {
            Objective::Mean => s.mean,
            Objective::Variance => s.var,
            Objective::P99 => s.p99,
        };
        if k.is_nan() {
            f64::INFINITY
        } else {
            k
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{Dcc, Workflow};
    use crate::plan::{BaselinePolicy, Planner, ProposedPolicy};
    use crate::sched::server::Server;
    use crate::util::prop;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn paper_scheme_beats_baseline_on_fig6() {
        // the paper's headline claim (Table 2): ours <= baseline in mean,
        // with the full proposed scheme (Alg. 1/2 + §3 balancing)
        let (wf, servers) = fig6();
        let plans: Vec<_> = Planner::new(&wf, &servers)
            .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default()])
            .into_iter()
            .collect::<Result<_, _>>()
            .unwrap();
        let (ours, base) = (&plans[0], &plans[1]);
        assert!(ours.diagnostics.stable && base.diagnostics.stable);
        assert!(
            ours.score.mean < base.score.mean + 1e-9,
            "ours {} vs baseline {}",
            ours.score.mean,
            base.score.mean
        );
    }

    #[test]
    fn fast_servers_go_to_high_rate_dccs() {
        // paper §3: "faster servers are placed into the DCC with higher
        // data arrival rates". Fig6 slots 0,1 belong to the λ=8 PDCC.
        let (wf, servers) = fig6();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let rate_of = |slot: usize| servers[alloc.server_for(slot)].service_rate();
        // λ=8 PDCC (slots 0,1) should hold the two fastest servers
        let mut top: Vec<f64> = (0..6).map(rate_of).collect();
        top.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let got: Vec<f64> = [0usize, 1].iter().map(|&s| rate_of(s)).collect();
        assert!(
            got.contains(&top[0]) && got.contains(&top[1]),
            "λ=8 PDCC got {got:?}, fastest are {top:?}"
        );
    }

    #[test]
    fn allocations_always_valid_property() {
        prop::run("scheduler output is always a valid allocation", 30, |g| {
            let n_slots = g.usize_in(2, 5);
            let wf = match g.usize_in(0, 2) {
                0 => Workflow::tandem(n_slots, 0.5),
                1 => Workflow::forkjoin(n_slots, 0.5),
                _ => Workflow::new(
                    Dcc::serial(vec![
                        Dcc::parallel((0..n_slots).map(|_| Dcc::queue()).collect()),
                        Dcc::queue(),
                    ]),
                    0.5,
                )
                .unwrap(),
            };
            let extra = g.usize_in(0, 2);
            let rates: Vec<f64> = (0..wf.slots() + extra).map(|_| g.f64_in(2.0, 20.0)).collect();
            let servers = Server::pool_exponential(&rates);
            for res in [
                allocate_with(&wf, &servers, ResponseModel::Mm1),
                baseline_allocate_split(
                    &wf,
                    &servers,
                    ResponseModel::Mm1,
                    SplitPolicy::Uniform,
                ),
            ] {
                match res {
                    Ok(a) => a.validate(&wf, servers.len()).unwrap(),
                    Err(SchedError::Infeasible(_)) => {} // overload is legal
                    Err(e) => panic!("unexpected error {e}"),
                }
            }
        });
    }

    #[test]
    fn equilibrium_rates_flow_to_slots() {
        // fig6 DCC0 (λ=8) slots must have rates summing to 8
        let (wf, servers) = fig6();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let sum01 = alloc.rate_for(0) + alloc.rate_for(1);
        assert!((sum01 - 8.0).abs() < 1e-6, "PDCC0 split {sum01}");
        // SDCC slots see the full DAP1 rate
        assert!((alloc.rate_for(2) - 4.0).abs() < 1e-9);
        assert!((alloc.rate_for(3) - 4.0).abs() < 1e-9);
        // PDCC2 splits λ=2
        let sum45 = alloc.rate_for(4) + alloc.rate_for(5);
        assert!((sum45 - 2.0).abs() < 1e-6, "PDCC2 split {sum45}");
    }

    #[test]
    fn not_enough_servers_reported() {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[5.0, 5.5]);
        assert!(matches!(
            allocate_with(&wf, &servers, ResponseModel::Mm1),
            Err(SchedError::NotEnoughServers { need: 6, have: 2 })
        ));
    }

    #[test]
    fn objective_keys() {
        let s = Score::point(1.0, 2.0, 3.0);
        assert_eq!(Objective::Mean.key(&s), 1.0);
        assert_eq!(Objective::Variance.key(&s), 2.0);
        assert_eq!(Objective::P99.key(&s), 3.0);
    }

    #[test]
    fn objective_keys_are_never_nan() {
        // degenerate scores must lose comparisons, not poison them
        let nan = Score::point(f64::NAN, f64::NAN, f64::NAN);
        for o in [Objective::Mean, Objective::Variance, Objective::P99] {
            assert_eq!(o.key(&nan), f64::INFINITY);
        }
        let finite = Score::point(1.0, 1.0, 1.0);
        assert!(Objective::Mean.key(&finite) < Objective::Mean.key(&nan));
    }
}
