//! The paper's allocation algorithms (engine layer — the public
//! surface is [`crate::plan::Planner`] with its policy objects).
//!
//! * [`allocate_with`] — Algorithm 1 + Algorithm 2, applied recursively
//!   from the workflow root (Algorithm 3's core step): slower servers go
//!   to lower-arrival-rate DCCs, then fork rates are set by the
//!   equilibrium of Algorithm 2. Behind [`crate::plan::SdccPolicy`].
//! * [`baseline_allocate_split`] — the §3 heuristic baseline: fastest
//!   servers to SDCCs first ("intuitively bottleneck servers"), PDCCs
//!   get the rest; fork splits per [`SplitPolicy`]. Behind
//!   [`crate::plan::BaselinePolicy`].
//! * [`schedule_rates`] — phase 2 alone, for external assignments (the
//!   optimal search and the coordinator's re-planning reuse it).
//!
//! Interpretation notes (the paper's pseudocode is terse):
//! * Alg. 1 sorts servers by expected response DESC and DCCs by arrival
//!   rate ASC, pairing head-to-head — so the *slowest* server lands on
//!   the *lowest-rate* DCC, i.e. "faster servers are placed into the DCC
//!   with higher data arrival rates" (paper §3). We implement exactly
//!   that by drawing from the slow end of the pool for low-rate DCCs.
//! * Alg. 2's unknown-λ branch sorts fork branches "by the number of
//!   internal DAPs in descending order". Read literally against the
//!   descending RES_Array this would give the *slowest* server to the
//!   *deepest* branch, which contradicts the paper's own principle
//!   (deep branches are the heavy ones). We resolve the inconsistency in
//!   favor of the principle: deeper branches draw from the fast end.
//!   DESIGN.md §substitutions records this choice.

use crate::flow::{Dcc, Workflow};
use crate::sched::allocation::{Allocation, SchedError};
use crate::sched::equilibrium::{equilibrium, uniform_split, BranchRt, FnBranch};
use crate::sched::response::{mean_response, ResponseModel};
use crate::sched::server::Server;

/// Paper's scheme (Alg. 1 + 2 + equilibrium) with an explicit response
/// model.
pub fn allocate_with(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
) -> Result<Allocation, SchedError> {
    let mut pool = sorted_pool(wf, servers)?;
    let mut assign = vec![usize::MAX; wf.slots()];
    place(wf.root(), wf.arrival_rate, &mut pool, servers, &mut assign);
    finish(wf, servers, assign, model, SplitPolicy::Equilibrium)
}

/// How fork rates are split when the spec leaves them open.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitPolicy {
    /// Algorithm 2's equilibrium `λ_i·RT_i = const`.
    Equilibrium,
    /// Uniform `λ/n` — the "homogeneous assumption" the paper says real
    /// schedulers make (§3 parenthetical). The paper's Table-2 baseline
    /// gap is only reproducible with this split; the equilibrium-split
    /// baseline is kept as the `fair-baseline` ablation.
    Uniform,
}

/// Baseline with an explicit split policy (`Uniform` = the paper's
/// Table-2 comparator, `Equilibrium` = the "to be fair, optimal task
/// scheduling" variant).
pub fn baseline_allocate_split(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    split: SplitPolicy,
) -> Result<Allocation, SchedError> {
    let mut pool = sorted_pool(wf, servers)?; // slowest-first
    let mut assign = vec![usize::MAX; wf.slots()];

    // serial-context slots get the fastest (pool back), others the rest
    let mut serial_slots = Vec::new();
    let mut parallel_slots = Vec::new();
    classify_slots(wf.root(), false, &mut serial_slots, &mut parallel_slots);
    for slot in serial_slots {
        assign[slot] = pool.pop().expect("pool sized in sorted_pool");
    }
    for slot in parallel_slots {
        assign[slot] = pool.pop().expect("pool sized in sorted_pool");
    }
    finish(wf, servers, assign, model, split)
}

/// Phase 2 only: equilibrium rate scheduling for an existing assignment.
pub fn schedule_rates(
    wf: &Workflow,
    assign: Vec<usize>,
    servers: &[Server],
    model: ResponseModel,
) -> Result<Allocation, SchedError> {
    finish(wf, servers, assign, model, SplitPolicy::Equilibrium)
}

// ---------------------------------------------------------------- phase 1

/// Servers sorted by expected response time DESC (slowest first), as a
/// pool drawn from both ends: front = slowest, back = fastest.
fn sorted_pool(wf: &Workflow, servers: &[Server]) -> Result<Vec<usize>, SchedError> {
    if servers.len() < wf.slots() {
        return Err(SchedError::NotEnoughServers {
            need: wf.slots(),
            have: servers.len(),
        });
    }
    let mut pool: Vec<usize> = (0..servers.len()).collect();
    // sort by mean service time ASC then reverse => DESC (slowest first);
    // ties broken by id for determinism
    pool.sort_by(|&a, &b| {
        servers[a]
            .mean_service()
            .partial_cmp(&servers[b].mean_service())
            .unwrap()
            .then(a.cmp(&b))
    });
    pool.reverse();
    // drop the globally slowest surplus servers: the paper assumes
    // exactly-sized pools; with surplus we keep the fastest `slots()`.
    let surplus = servers.len() - wf.slots();
    Ok(pool[surplus..].to_vec())
}

/// Recursive placement (Alg. 1 for serial, Alg. 2 for parallel).
fn place(
    node: &Dcc,
    rate: f64,
    pool: &mut Vec<usize>,
    servers: &[Server],
    assign: &mut [usize],
) {
    match node {
        Dcc::Queue { slot } => {
            // head of RES_Array = slowest remaining
            assign[*slot] = pool.remove(0);
        }
        Dcc::Serial { children, rates } => {
            // Alg. 1: DCCs ascending by arrival rate; slowest servers to
            // the lowest-rate DCCs. A child without its own DAP rate
            // inherits the stream from the previous stage (tandem flow).
            let mut order: Vec<usize> = (0..children.len()).collect();
            let mut eff = Vec::with_capacity(children.len());
            let mut current = rate;
            for r in rates {
                current = r.unwrap_or(current);
                eff.push(current);
            }
            order.sort_by(|&a, &b| eff[a].partial_cmp(&eff[b]).unwrap().then(a.cmp(&b)));
            for i in order {
                place(&children[i], eff[i], pool, servers, assign);
            }
        }
        Dcc::Parallel { children, rates } => {
            let known = rates.iter().all(|r| r.is_some());
            let mut order: Vec<usize> = (0..children.len()).collect();
            if known {
                // Alg. 2, known λ_i: ascending by λ — slowest to lightest.
                order.sort_by(|&a, &b| {
                    rates[a]
                        .unwrap()
                        .partial_cmp(&rates[b].unwrap())
                        .unwrap()
                        .then(a.cmp(&b))
                });
                for i in order {
                    place(&children[i], rates[i].unwrap(), pool, servers, assign);
                }
            } else {
                // Alg. 2, unknown λ_i: shallow branches first (they draw
                // the slow pool head), deep branches last (fast end).
                order.sort_by_key(|&i| children[i].slot_count());
                // provisional per-branch rate for recursion ordering only:
                // uniform share (the real split comes from phase 2).
                let share = rate / children.len() as f64;
                for i in order {
                    place(&children[i], share, pool, servers, assign);
                }
            }
        }
    }
}

fn classify_slots(node: &Dcc, in_parallel: bool, ser: &mut Vec<usize>, par: &mut Vec<usize>) {
    match node {
        Dcc::Queue { slot } => {
            if in_parallel {
                par.push(*slot);
            } else {
                ser.push(*slot);
            }
        }
        Dcc::Serial { children, .. } => {
            for c in children {
                classify_slots(c, in_parallel, ser, par);
            }
        }
        Dcc::Parallel { children, .. } => {
            for c in children {
                classify_slots(c, true, ser, par);
            }
        }
    }
}

// ---------------------------------------------------------------- phase 2

fn finish(
    wf: &Workflow,
    servers: &[Server],
    assign: Vec<usize>,
    model: ResponseModel,
    split: SplitPolicy,
) -> Result<Allocation, SchedError> {
    debug_assert!(assign.iter().all(|&s| s != usize::MAX));
    let mut slot_rate = vec![0.0; wf.slots()];
    set_rates(
        wf.root(),
        wf.arrival_rate,
        &assign,
        servers,
        model,
        split,
        &mut slot_rate,
    )?;
    Allocation::new(assign, slot_rate, wf, servers.len())
}

/// Walk the tree, resolving DAP rates and solving fork equilibria.
fn set_rates(
    node: &Dcc,
    rate: f64,
    assign: &[usize],
    servers: &[Server],
    model: ResponseModel,
    split: SplitPolicy,
    out: &mut [f64],
) -> Result<(), SchedError> {
    match node {
        Dcc::Queue { slot } => {
            // leaf stability: a queue whose load meets/exceeds capacity
            // has no finite response law under this model
            if mean_response(model, &servers[assign[*slot]].dist, rate).is_none() {
                return Err(SchedError::Infeasible(format!(
                    "slot {slot}: server {} (mean service {:.4}) cannot absorb rate {rate:.4}",
                    assign[*slot],
                    servers[assign[*slot]].mean_service()
                )));
            }
            out[*slot] = rate;
            Ok(())
        }
        Dcc::Serial { children, rates } => {
            // tandem flow: rate persists from the last specified DAP
            let mut current = rate;
            for (c, r) in children.iter().zip(rates) {
                current = r.unwrap_or(current);
                set_rates(c, current, assign, servers, model, split, out)?;
            }
            Ok(())
        }
        Dcc::Parallel { children, rates } => {
            let branch_rates: Vec<f64> = if rates.iter().all(|r| r.is_some()) {
                rates.iter().map(|r| r.unwrap()).collect()
            } else if split == SplitPolicy::Uniform {
                uniform_split(children.len(), rate)
            } else {
                // Algorithm 2's equilibrium over branch mean-RT curves
                let branches: Vec<FnBranch<Box<dyn Fn(f64) -> Option<f64>>>> = children
                    .iter()
                    .map(|c| {
                        let cap = branch_capacity(c, assign, servers);
                        let c = c.clone();
                        let assign = assign.to_vec();
                        let servers = servers.to_vec();
                        FnBranch {
                            f: Box::new(move |l: f64| {
                                branch_mean_rt(&c, l, &assign, &servers, model)
                            }) as Box<dyn Fn(f64) -> Option<f64>>,
                            cap,
                        }
                    })
                    .collect();
                let refs: Vec<&dyn BranchRt> =
                    branches.iter().map(|b| b as &dyn BranchRt).collect();
                equilibrium(&refs, rate)
                    .map_err(|e| SchedError::Infeasible(e.to_string()))?
            };
            for (c, l) in children.iter().zip(branch_rates) {
                set_rates(c, l, assign, servers, model, split, out)?;
            }
            Ok(())
        }
    }
}

/// Cheap recursive mean-RT estimator for a branch under load `lambda`:
/// serial = sum of stage means, parallel = max of branch means after an
/// inner equilibrium split. None = unstable anywhere inside.
pub fn branch_mean_rt(
    node: &Dcc,
    lambda: f64,
    assign: &[usize],
    servers: &[Server],
    model: ResponseModel,
) -> Option<f64> {
    match node {
        Dcc::Queue { slot } => mean_response(model, &servers[assign[*slot]].dist, lambda),
        Dcc::Serial { children, rates } => {
            let mut total = 0.0;
            let mut current = lambda;
            for (c, r) in children.iter().zip(rates) {
                current = r.unwrap_or(current);
                total += branch_mean_rt(c, current, assign, servers, model)?;
            }
            Some(total)
        }
        Dcc::Parallel { children, rates } => {
            let split: Vec<f64> = if rates.iter().all(|r| r.is_some()) {
                rates.iter().map(|r| r.unwrap()).collect()
            } else {
                let branches: Vec<FnBranch<Box<dyn Fn(f64) -> Option<f64>>>> = children
                    .iter()
                    .map(|c| {
                        let c = c.clone();
                        let assign = assign.to_vec();
                        let servers = servers.to_vec();
                        let cap = branch_capacity(&c, &assign, &servers);
                        FnBranch {
                            f: Box::new(move |l: f64| {
                                branch_mean_rt(&c, l, &assign, &servers, model)
                            }) as Box<dyn Fn(f64) -> Option<f64>>,
                            cap,
                        }
                    })
                    .collect();
                let refs: Vec<&dyn BranchRt> =
                    branches.iter().map(|b| b as &dyn BranchRt).collect();
                equilibrium(&refs, lambda).ok()?
            };
            let mut worst = 0.0f64;
            for (c, l) in children.iter().zip(split) {
                let m = branch_mean_rt(c, l, assign, servers, model)?;
                worst = worst.max(m);
            }
            Some(worst)
        }
    }
}

/// Capacity bound of a branch: leaf = service rate; serial = min over
/// inherited-rate children; parallel = sum over branches.
pub fn branch_capacity(node: &Dcc, assign: &[usize], servers: &[Server]) -> f64 {
    match node {
        Dcc::Queue { slot } => servers[assign[*slot]].service_rate(),
        Dcc::Serial { children, rates } => {
            // only the prefix before the first fixed-rate DAP sees the
            // branch's input stream (tandem flow-through semantics)
            let mut cap = f64::INFINITY;
            for (c, r) in children.iter().zip(rates) {
                if r.is_some() {
                    break;
                }
                cap = cap.min(branch_capacity(c, assign, servers));
            }
            cap
        }
        Dcc::Parallel { children, .. } => children
            .iter()
            .map(|c| branch_capacity(c, assign, servers))
            .sum(),
    }
}
