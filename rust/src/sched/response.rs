//! Response-time models: from (service law, arrival rate) to the law the
//! composition engine actually composes.
//!
//! The paper treats each server as "a queue, where tasks come for service
//! with a specific service rate". How waiting time enters the composed
//! law is a model choice:
//!
//! * [`ResponseModel::ServiceOnly`] — response = service time (no
//!   queueing). This is what the paper's Fig. 2/3 tail plots use.
//! * [`ResponseModel::Mm1`] — exact M/M/1 sojourn: `Exp(mu - lambda)`
//!   for exponential service (plus the delay for delayed-exponential).
//! * [`ResponseModel::Mg1`] — M/G/1 Pollaczek–Khinchine *mean* mapped
//!   back into a delayed exponential with the service law's minimum as
//!   the delay. The family approximation keeps grid composition closed;
//!   mean is exact, higher moments approximate. Used for pareto /
//!   multi-modal service laws.

use crate::dist::{ServiceDist, TailKind};

/// Queueing model used to turn service laws into response laws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseModel {
    /// No queueing: response = service.
    ServiceOnly,
    /// M/M/1 sojourn time (exact for exponential service).
    Mm1,
    /// M/G/1 P-K mean folded into a delayed exponential (approximation).
    Mg1,
}

/// Outcome of applying a response model at one server.
#[derive(Clone, Debug)]
pub enum Response {
    /// Stable queue with the given response-time law.
    Stable(ServiceDist),
    /// `lambda >= mu`: the queue diverges; no finite response law.
    Unstable,
}

/// Response-time law of one server receiving Poisson arrivals at `lambda`.
pub fn response_dist(model: ResponseModel, service: &ServiceDist, lambda: f64) -> Response {
    match model {
        ResponseModel::ServiceOnly => Response::Stable(service.clone()),
        ResponseModel::Mm1 => {
            // treat the tail beyond the deterministic delay as exponential:
            // X = T + Exp(mu_tail); the queue serves at effective rate
            // 1/mean overall.
            let delay = service.min_time();
            let mean = service.mean();
            let mu = 1.0 / mean;
            if lambda >= mu {
                return Response::Unstable;
            }
            // M/M/1 sojourn for the memoryless part, delay preserved:
            // mean response = delay + 1/((1/(mean-delay)) - lambda_eff)
            // where the delay portion is capacity the queue also spends.
            // Standard simplification (documented): Exp(mu - lambda)
            // shifted by nothing when delay = 0.
            if delay <= f64::EPSILON {
                Response::Stable(ServiceDist::exponential(mu - lambda))
            } else {
                // Effective tail rate so that the P-K mean is matched for
                // the delayed-exponential service law.
                mg1_response(service, lambda)
            }
        }
        ResponseModel::Mg1 => mg1_response(service, lambda),
    }
}

/// Mean response time under the model without building the law —
/// the cheap estimator the equilibrium solver iterates on.
pub fn mean_response(model: ResponseModel, service: &ServiceDist, lambda: f64) -> Option<f64> {
    match model {
        ResponseModel::ServiceOnly => Some(service.mean()),
        ResponseModel::Mm1 => {
            let mu = 1.0 / service.mean();
            if lambda >= mu {
                None
            } else {
                Some(1.0 / (mu - lambda))
            }
        }
        ResponseModel::Mg1 => pk_mean(service, lambda),
    }
}

/// Pollaczek–Khinchine mean response: `E[S] + lambda E[S^2] / (2 (1-rho))`.
fn pk_mean(service: &ServiceDist, lambda: f64) -> Option<f64> {
    let es = service.mean();
    let rho = lambda * es;
    if rho >= 1.0 {
        return None;
    }
    let es2 = service.variance() + es * es;
    Some(es + lambda * es2 / (2.0 * (1.0 - rho)))
}

fn mg1_response(service: &ServiceDist, lambda: f64) -> Response {
    match pk_mean(service, lambda) {
        None => Response::Unstable,
        Some(mean_resp) => {
            let delay = service.min_time();
            let tail_mean = (mean_resp - delay).max(1e-9);
            Response::Stable(ServiceDist::delayed_exponential(1.0 / tail_mean, delay))
        }
    }
}

/// Convenience: the paper's plain-exponential case, `Exp(mu - lambda)`.
pub fn mm1_exponential(mu: f64, lambda: f64) -> Response {
    if lambda >= mu {
        Response::Unstable
    } else {
        Response::Stable(ServiceDist::exponential(mu - lambda))
    }
}

/// True if the service law is plain exponential (T=0, single mode).
pub fn is_plain_exponential(d: &ServiceDist) -> bool {
    d.modes().len() == 1 && {
        let m = d.modes()[0].1;
        m.delay == 0.0 && matches!(m.kind, TailKind::Exponential)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_only_passthrough() {
        let s = ServiceDist::exponential(3.0);
        match response_dist(ResponseModel::ServiceOnly, &s, 100.0) {
            Response::Stable(d) => assert!((d.mean() - s.mean()).abs() < 1e-12),
            _ => panic!("service-only never unstable"),
        }
    }

    #[test]
    fn mm1_exact_for_exponential() {
        let s = ServiceDist::exponential(5.0);
        match response_dist(ResponseModel::Mm1, &s, 2.0) {
            Response::Stable(d) => assert!((d.mean() - 1.0 / 3.0).abs() < 1e-9),
            _ => panic!("stable"),
        }
        assert!(matches!(
            response_dist(ResponseModel::Mm1, &s, 5.0),
            Response::Unstable
        ));
        assert!(matches!(
            response_dist(ResponseModel::Mm1, &s, 7.0),
            Response::Unstable
        ));
    }

    #[test]
    fn mm1_mean_matches_formula() {
        let s = ServiceDist::exponential(4.0);
        assert!((mean_response(ResponseModel::Mm1, &s, 1.0).unwrap() - 1.0 / 3.0).abs() < 1e-12);
        assert!(mean_response(ResponseModel::Mm1, &s, 4.5).is_none());
    }

    #[test]
    fn pk_reduces_to_mm1_for_exponential() {
        // M/M/1 sojourn mean = 1/(mu-lambda); P-K with exp service agrees
        let s = ServiceDist::exponential(3.0);
        let pk = mean_response(ResponseModel::Mg1, &s, 1.0).unwrap();
        assert!((pk - 0.5).abs() < 1e-6, "pk {pk}");
    }

    #[test]
    fn mg1_heavier_service_waits_longer() {
        let light = ServiceDist::exponential(2.0);
        let heavy = ServiceDist::delayed_pareto(3.0, 0.0); // fatter tail
        let ml = mean_response(ResponseModel::Mg1, &light, 1.0).unwrap();
        // pick lambda so both are stable
        let lam = 0.5 / heavy.mean().max(0.5);
        let mh = mean_response(ResponseModel::Mg1, &heavy, lam);
        if let Some(mh) = mh {
            assert!(mh.is_finite() && ml.is_finite());
        }
    }

    #[test]
    fn mg1_preserves_delay() {
        let s = ServiceDist::delayed_exponential(4.0, 0.5);
        match response_dist(ResponseModel::Mg1, &s, 0.8) {
            Response::Stable(d) => {
                assert!((d.min_time() - 0.5).abs() < 1e-9);
                let want = mean_response(ResponseModel::Mg1, &s, 0.8).unwrap();
                assert!((d.mean() - want).abs() < 1e-6);
            }
            _ => panic!("stable"),
        }
    }

    #[test]
    fn plain_exponential_detector() {
        assert!(is_plain_exponential(&ServiceDist::exponential(1.0)));
        assert!(!is_plain_exponential(&ServiceDist::delayed_exponential(1.0, 0.1)));
        assert!(!is_plain_exponential(&ServiceDist::delayed_pareto(2.0, 0.0)));
    }
}
