//! Rate scheduling: the paper's equilibrium (Algorithm 2, bottom).
//!
//! Split a fork DAP's arrival rate λ over n parallel branches so that
//!
//! ```text
//! λ_1·RT_1(λ_1) = λ_2·RT_2(λ_2) = … = λ_n·RT_n(λ_n),   Σ λ_i = λ
//! ```
//!
//! where `RT_i(λ_i)` is the branch's mean response time under load λ_i.
//! Since `g_i(λ_i) = λ_i·RT_i(λ_i)` is continuous and strictly increasing
//! on the branch's stable range (RT is nondecreasing in load), each
//! branch has a well-defined inverse `λ_i(c) = g_i⁻¹(c)`, and
//! `c ↦ Σ_i λ_i(c)` is strictly increasing — so the equilibrium is found
//! by bisection on `c`. For M/M/1 branches there is a closed form:
//! `λ_i = c·μ_i/(1+c)` with `c = λ/(Σμ − λ)` — used as a fast path and
//! as the oracle in tests.

/// A branch's load→mean-response curve. Returns `None` when the branch
/// is unstable at that load (finite capacity exceeded).
pub trait BranchRt {
    /// Mean response time at arrival rate `lambda` (None = unstable).
    fn mean_rt(&self, lambda: f64) -> Option<f64>;
    /// Capacity upper bound: loads >= this are certainly unstable.
    fn capacity(&self) -> f64;
}

/// M/M/1 branch with service rate `mu`.
#[derive(Clone, Copy, Debug)]
pub struct Mm1Branch {
    /// Service rate.
    pub mu: f64,
}

impl BranchRt for Mm1Branch {
    fn mean_rt(&self, lambda: f64) -> Option<f64> {
        if lambda >= self.mu {
            None
        } else {
            Some(1.0 / (self.mu - lambda))
        }
    }
    fn capacity(&self) -> f64 {
        self.mu
    }
}

/// Closure-backed branch (used by the scheduler for composite sub-DCCs).
pub struct FnBranch<F: Fn(f64) -> Option<f64>> {
    /// Load → mean RT.
    pub f: F,
    /// Capacity bound.
    pub cap: f64,
}

impl<F: Fn(f64) -> Option<f64>> BranchRt for FnBranch<F> {
    fn mean_rt(&self, lambda: f64) -> Option<f64> {
        (self.f)(lambda)
    }
    fn capacity(&self) -> f64 {
        self.cap
    }
}

/// Equilibrium failure.
#[derive(Debug, Clone, PartialEq)]
pub enum EquilibriumError {
    /// Σ capacities <= λ: no stable split exists.
    Overloaded {
        /// Offered load.
        lambda: f64,
        /// Total capacity.
        capacity: f64,
    },
}

impl std::fmt::Display for EquilibriumError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EquilibriumError::Overloaded { lambda, capacity } => write!(
                f,
                "offered load {lambda} exceeds total branch capacity {capacity}"
            ),
        }
    }
}

impl std::error::Error for EquilibriumError {}

/// Closed-form equilibrium for all-M/M/1 branches:
/// `c = λ/(Σμ − λ)`, `λ_i = c·μ_i/(1+c)`.
pub fn equilibrium_mm1(mus: &[f64], lambda: f64) -> Result<Vec<f64>, EquilibriumError> {
    let total: f64 = mus.iter().sum();
    if lambda >= total {
        return Err(EquilibriumError::Overloaded {
            lambda,
            capacity: total,
        });
    }
    let c = lambda / (total - lambda);
    Ok(mus.iter().map(|&mu| c * mu / (1.0 + c)).collect())
}

/// General equilibrium by nested bisection.
///
/// Outer bisection on the common value `c`; inner bisection inverts each
/// branch's `g_i(λ) = λ·RT_i(λ)` (strictly increasing on `[0, cap_i)`).
pub fn equilibrium(
    branches: &[&dyn BranchRt],
    lambda: f64,
) -> Result<Vec<f64>, EquilibriumError> {
    assert!(!branches.is_empty() && lambda > 0.0);
    let capacity: f64 = branches.iter().map(|b| b.capacity()).sum();
    if lambda >= capacity {
        return Err(EquilibriumError::Overloaded { lambda, capacity });
    }

    // λ_i(c): invert g_i by bisection on [0, min(cap_i, λ)] — no branch
    // can ever receive more than the whole offered load, which also
    // bounds infinite-capacity branches (e.g. constant-RT models).
    let lam_of_c = |b: &dyn BranchRt, c: f64| -> f64 {
        let cap = b.capacity();
        let mut hi = if cap.is_finite() {
            (cap * (1.0 - 1e-12)).min(lambda)
        } else {
            lambda
        };
        // shrink hi until stable (mean_rt defined)
        while b.mean_rt(hi).is_none() {
            hi *= 0.999;
            if hi < 1e-300 {
                return 0.0;
            }
        }
        let g = |x: f64| x * b.mean_rt(x).unwrap_or(f64::INFINITY);
        // g(hi) below c: the whole bound is allocatable at this c
        if g(hi) <= c {
            return hi;
        }
        let mut lo = 0.0;
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            if g(mid) < c {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    };

    // outer bisection on c: Σ λ_i(c) = λ
    let total_at = |c: f64| -> f64 { branches.iter().map(|b| lam_of_c(*b, c)).sum() };
    let mut c_lo = 1e-12;
    let mut c_hi = 1.0;
    while total_at(c_hi) < lambda {
        c_hi *= 2.0;
        if c_hi > 1e18 {
            break;
        }
    }
    for _ in 0..100 {
        let mid = 0.5 * (c_lo + c_hi);
        if total_at(mid) < lambda {
            c_lo = mid;
        } else {
            c_hi = mid;
        }
    }
    let c = 0.5 * (c_lo + c_hi);
    let mut rates: Vec<f64> = branches.iter().map(|b| lam_of_c(*b, c)).collect();

    // normalize the residual bisection error so Σλ_i = λ exactly
    let sum: f64 = rates.iter().sum();
    if sum > 0.0 {
        let k = lambda / sum;
        rates.iter_mut().for_each(|r| *r *= k);
    }
    Ok(rates)
}

/// Uniform split (the "homogeneous assumption" the paper's baseline
/// discussion warns about) — kept as an ablation comparator.
pub fn uniform_split(n: usize, lambda: f64) -> Vec<f64> {
    vec![lambda / n as f64; n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn mm1_closed_form_balances() {
        let mus = [9.0, 8.0, 7.0];
        let lambda = 8.0;
        let rates = equilibrium_mm1(&mus, lambda).unwrap();
        assert!((rates.iter().sum::<f64>() - lambda).abs() < 1e-9);
        // λ_i RT_i all equal
        let g: Vec<f64> = rates
            .iter()
            .zip(mus.iter())
            .map(|(&l, &mu)| l / (mu - l))
            .collect();
        for w in g.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-9, "{g:?}");
        }
    }

    #[test]
    fn mm1_overload_rejected() {
        assert!(equilibrium_mm1(&[2.0, 3.0], 5.0).is_err());
        assert!(equilibrium_mm1(&[2.0, 3.0], 6.0).is_err());
    }

    #[test]
    fn general_matches_closed_form() {
        let mus = [9.0, 8.0, 7.0, 4.0];
        let lambda = 11.0;
        let branches: Vec<Mm1Branch> = mus.iter().map(|&mu| Mm1Branch { mu }).collect();
        let refs: Vec<&dyn BranchRt> = branches.iter().map(|b| b as &dyn BranchRt).collect();
        let got = equilibrium(&refs, lambda).unwrap();
        let want = equilibrium_mm1(&mus, lambda).unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-6, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn equilibrium_property_balanced_and_feasible() {
        prop::run("equilibrium balances λ·RT", 40, |g| {
            let n = g.usize_in(2, 6);
            let mus: Vec<f64> = (0..n).map(|_| g.f64_in(1.0, 20.0)).collect();
            let cap: f64 = mus.iter().sum();
            let lambda = g.f64_in(0.1, 0.95) * cap;
            let rates = equilibrium_mm1(&mus, lambda).unwrap();
            assert!((rates.iter().sum::<f64>() - lambda).abs() < 1e-8);
            for (&l, &mu) in rates.iter().zip(mus.iter()) {
                assert!(l > 0.0 && l < mu, "rate {l} vs mu {mu}");
            }
            let g0 = rates[0] / (mus[0] - rates[0]);
            for (&l, &mu) in rates.iter().zip(mus.iter()).skip(1) {
                assert!((l / (mu - l) - g0).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn faster_branches_get_more_load() {
        let rates = equilibrium_mm1(&[10.0, 2.0], 6.0).unwrap();
        assert!(rates[0] > rates[1] * 3.0, "{rates:?}");
    }

    #[test]
    fn fn_branch_with_fixed_rt() {
        // constant RT branches: equilibrium λ_i ∝ 1/RT_i
        let b1 = FnBranch {
            f: |_l| Some(2.0),
            cap: f64::INFINITY,
        };
        let b2 = FnBranch {
            f: |_l| Some(1.0),
            cap: f64::INFINITY,
        };
        let refs: Vec<&dyn BranchRt> = vec![&b1, &b2];
        let rates = equilibrium(&refs, 3.0).unwrap();
        assert!((rates[0] - 1.0).abs() < 1e-6, "{rates:?}");
        assert!((rates[1] - 2.0).abs() < 1e-6, "{rates:?}");
    }
}
