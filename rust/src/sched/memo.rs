//! Cross-round memoization for the incremental swap engine
//! ([`SwapEngine::Incremental`](crate::sched::multijob::SwapEngine)).
//!
//! A swap round only mutates the two plans whose exchange was applied,
//! yet the wave engine re-enumerates and re-scores *every*
//! (job-pair × server-pair) exchange each round. [`SwapMemo`] keys a
//! pair's fully-scored exchange list by the exact
//! [`AllocFingerprint`]s of both incumbent allocations; on the next
//! round, a pair whose two plans are untouched hits the memo and skips
//! both enumeration and scoring, while pairs touching a mutated plan
//! are invalidated eagerly ([`SwapMemo::invalidate_touching`]) and
//! re-scored fresh through the same `score_batch` wave path. Because
//! the fingerprint is an exact structural key — not a lossy hash — a
//! hit reproduces bit-for-bit what fresh enumeration would have
//! produced, which is what lets the incremental engine stay
//! bit-identical to the wave and serial oracles
//! (`tests/incremental_equivalence.rs`).
//!
//! The table exposes hit/miss/invalidation counters (in candidate
//! *sides*, i.e. individual scores, two per exchange) so tests and the
//! bench harness can assert the memo actually skips work:
//! `scored + hits == 2 × candidates` holds for every round.

use crate::compose::score::Score;
use crate::sched::Allocation;
use std::collections::HashMap;

/// Exact structural fingerprint of an [`Allocation`]: the per-slot
/// `(server id, rate bits)` sequence. Two allocations fingerprint equal
/// **iff** they are bit-identical (`to_bits` on every rate), so a memo
/// hit can never alias two different incumbents. Construction is
/// deterministic — it depends only on the allocation's own vectors,
/// never on hash-map iteration order or addresses.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct AllocFingerprint(Box<[(usize, u64)]>);

impl AllocFingerprint {
    /// Fingerprint `alloc` exactly (slot order preserved).
    pub fn of(alloc: &Allocation) -> AllocFingerprint {
        AllocFingerprint(
            alloc
                .slot_server
                .iter()
                .zip(&alloc.slot_rate)
                .map(|(&s, &r)| (s, r.to_bits()))
                .collect(),
        )
    }

    /// FNV-1a digest of the fingerprint, for compact display in
    /// diagnostics. Equality checks always use the full structural key;
    /// the digest is never used for lookup.
    pub fn digest64(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |x: u64| {
            for byte in x.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for &(s, r) in self.0.iter() {
            eat(s as u64);
            eat(r);
        }
        h
    }
}

/// One cached, fully-scored exchange of a job pair: the two
/// rate-scheduled allocations plus their scores (full [`Score`]s,
/// including the pdf, so replaying a hit is indistinguishable from
/// fresh scoring).
#[derive(Clone, Debug)]
pub struct CachedExchange {
    /// The `a`-side regrouped allocation (global server ids).
    pub alloc_a: Allocation,
    /// The `b`-side regrouped allocation (global server ids).
    pub alloc_b: Allocation,
    /// Score of `alloc_a` on the shared grid.
    pub score_a: Score,
    /// Score of `alloc_b` on the shared grid.
    pub score_b: Score,
}

/// A pair's cached exchange list, pinned to the exact incumbents it was
/// enumerated against.
#[derive(Clone, Debug)]
struct PairEntry {
    fp_a: AllocFingerprint,
    fp_b: AllocFingerprint,
    exchanges: Vec<CachedExchange>,
}

/// Memo table carried across swap rounds by the incremental engine:
/// maps a plan pair `(a, b)` (with `a < b`) to its scored exchange
/// list, guarded by both incumbents' fingerprints.
///
/// Counters are in candidate *sides* (individual scores; one exchange
/// contributes two): [`hits`](SwapMemo::hits) counts sides served from
/// the table, [`misses`](SwapMemo::misses) sides inserted after fresh
/// scoring, [`invalidated`](SwapMemo::invalidated) sides dropped
/// because a plan they were enumerated against was mutated (or, as
/// defense in depth, because a lookup saw a mismatched fingerprint).
#[derive(Debug, Default)]
pub struct SwapMemo {
    pairs: HashMap<(usize, usize), PairEntry>,
    hits: usize,
    misses: usize,
    invalidated: usize,
}

impl SwapMemo {
    /// An empty memo table with zeroed counters.
    pub fn new() -> SwapMemo {
        SwapMemo::default()
    }

    /// Look up pair `(a, b)`'s cached exchanges against the *current*
    /// incumbent fingerprints. Returns the cached list only when both
    /// fingerprints match the ones the entry was enumerated under — a
    /// stale entry (either side mutated) is evicted on sight and
    /// counted as invalidated, so no stale hit is observable even if a
    /// caller forgets [`invalidate_touching`](SwapMemo::invalidate_touching).
    pub fn lookup(
        &mut self,
        a: usize,
        b: usize,
        fp_a: &AllocFingerprint,
        fp_b: &AllocFingerprint,
    ) -> Option<&[CachedExchange]> {
        let fresh = match self.pairs.get(&(a, b)) {
            None => return None,
            Some(e) => e.fp_a == *fp_a && e.fp_b == *fp_b,
        };
        if !fresh {
            let stale = self.pairs.remove(&(a, b)).expect("entry checked above");
            self.invalidated += 2 * stale.exchanges.len();
            return None;
        }
        let n = self.pairs[&(a, b)].exchanges.len();
        self.hits += 2 * n;
        self.pairs.get(&(a, b)).map(|e| e.exchanges.as_slice())
    }

    /// Cache pair `(a, b)`'s freshly scored exchange list under the
    /// incumbents it was enumerated against. An empty list is cached
    /// too — "this pair has no feasible exchange" is itself a result
    /// worth not recomputing. Replaces any previous entry for the pair.
    pub fn insert(
        &mut self,
        a: usize,
        b: usize,
        fp_a: AllocFingerprint,
        fp_b: AllocFingerprint,
        exchanges: Vec<CachedExchange>,
    ) {
        self.misses += 2 * exchanges.len();
        self.pairs.insert(
            (a, b),
            PairEntry {
                fp_a,
                fp_b,
                exchanges,
            },
        );
    }

    /// Drop every cached pair touching a mutated plan (`mutated[p]` is
    /// true for plans an applied swap rewrote this round). Indices past
    /// `mutated`'s length are conservatively treated as mutated.
    /// Returns the number of sides dropped (also accumulated into
    /// [`invalidated`](SwapMemo::invalidated)). The retained set and
    /// the counters depend only on `mutated`, never on hash-map
    /// iteration order.
    pub fn invalidate_touching(&mut self, mutated: &[bool]) -> usize {
        let mut dropped = 0;
        self.pairs.retain(|&(a, b), e| {
            let touched = mutated.get(a).copied().unwrap_or(true)
                || mutated.get(b).copied().unwrap_or(true);
            if touched {
                dropped += 2 * e.exchanges.len();
            }
            !touched
        });
        self.invalidated += dropped;
        if dropped > 0 && crate::obs::enabled() {
            crate::obs::event(
                "memo.invalidate",
                vec![("sides_dropped".to_string(), dropped.into())],
            );
        }
        dropped
    }

    /// Total candidate sides served from the table.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Total candidate sides inserted after fresh scoring.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Total candidate sides dropped by invalidation (eager or
    /// lookup-time eviction).
    pub fn invalidated(&self) -> usize {
        self.invalidated
    }

    /// Number of pairs currently cached.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// True when no pair is cached.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(servers: &[usize], rates: &[f64]) -> Allocation {
        Allocation {
            slot_server: servers.to_vec(),
            slot_rate: rates.to_vec(),
        }
    }

    fn exchange(sa: usize, sb: usize) -> CachedExchange {
        CachedExchange {
            alloc_a: alloc(&[sa], &[1.0]),
            alloc_b: alloc(&[sb], &[1.0]),
            score_a: Score::point(1.0, 0.0, 1.0),
            score_b: Score::point(2.0, 0.0, 2.0),
        }
    }

    #[test]
    fn fingerprints_are_deterministic_and_exact() {
        let a = alloc(&[3, 1, 4], &[0.5, 0.25, 0.125]);
        let b = alloc(&[3, 1, 4], &[0.5, 0.25, 0.125]);
        assert_eq!(AllocFingerprint::of(&a), AllocFingerprint::of(&b));
        assert_eq!(
            AllocFingerprint::of(&a).digest64(),
            AllocFingerprint::of(&b).digest64()
        );
        // any single-bit rate change or server change breaks equality
        let mut c = alloc(&[3, 1, 4], &[0.5, 0.25, 0.125]);
        c.slot_rate[1] = f64::from_bits(c.slot_rate[1].to_bits() ^ 1);
        assert_ne!(AllocFingerprint::of(&a), AllocFingerprint::of(&c));
        let d = alloc(&[3, 2, 4], &[0.5, 0.25, 0.125]);
        assert_ne!(AllocFingerprint::of(&a), AllocFingerprint::of(&d));
        // negative zero is a different incumbent than positive zero
        let z1 = alloc(&[0], &[0.0]);
        let z2 = alloc(&[0], &[-0.0]);
        assert_ne!(AllocFingerprint::of(&z1), AllocFingerprint::of(&z2));
    }

    #[test]
    fn lookup_hits_only_on_matching_fingerprints() {
        let pa = alloc(&[0, 1], &[1.0, 2.0]);
        let pb = alloc(&[2], &[3.0]);
        let (fa, fb) = (AllocFingerprint::of(&pa), AllocFingerprint::of(&pb));
        let mut memo = SwapMemo::new();
        assert!(memo.lookup(0, 1, &fa, &fb).is_none(), "empty table misses");
        memo.insert(0, 1, fa.clone(), fb.clone(), vec![exchange(0, 2), exchange(1, 2)]);
        assert_eq!(memo.misses(), 4);
        let hit = memo.lookup(0, 1, &fa, &fb).expect("fresh entry hits");
        assert_eq!(hit.len(), 2);
        assert_eq!(memo.hits(), 4);
        // a mutated a-side incumbent must not hit — the stale entry is
        // evicted and counted, and the pair misses until re-inserted
        let mutated = alloc(&[5, 1], &[1.0, 2.0]);
        let fm = AllocFingerprint::of(&mutated);
        assert!(memo.lookup(0, 1, &fm, &fb).is_none(), "stale entry must not hit");
        assert_eq!(memo.invalidated(), 4);
        assert!(memo.is_empty());
        assert!(memo.lookup(0, 1, &fa, &fb).is_none());
        assert_eq!(memo.hits(), 4, "no further hits after eviction");
    }

    #[test]
    fn invalidation_drops_exactly_the_pairs_touching_a_mutated_plan() {
        let fp = |s: usize| AllocFingerprint::of(&alloc(&[s], &[1.0]));
        // insertion order A: (0,1), (0,2), (1,2), (2,3)
        let mut a = SwapMemo::new();
        for &(x, y) in &[(0usize, 1usize), (0, 2), (1, 2), (2, 3)] {
            a.insert(x, y, fp(x), fp(y), vec![exchange(x, y)]);
        }
        // insertion order B: reversed — the retained set must agree
        let mut b = SwapMemo::new();
        for &(x, y) in &[(2usize, 3usize), (1, 2), (0, 2), (0, 1)] {
            b.insert(x, y, fp(x), fp(y), vec![exchange(x, y)]);
        }
        let mutated = [true, true, false, false];
        assert_eq!(a.invalidate_touching(&mutated), 6, "three pairs of one exchange");
        assert_eq!(b.invalidate_touching(&mutated), 6);
        for memo in [&mut a, &mut b] {
            assert_eq!(memo.len(), 1, "only (2,3) survives");
            assert!(memo.lookup(2, 3, &fp(2), &fp(3)).is_some());
            assert!(memo.lookup(0, 1, &fp(0), &fp(1)).is_none());
            assert_eq!(memo.invalidated(), 6);
        }
        // indices past the mutated slice are conservatively dropped
        let mut c = SwapMemo::new();
        c.insert(7, 9, fp(7), fp(9), vec![exchange(7, 9)]);
        assert_eq!(c.invalidate_touching(&[false; 4]), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn counters_reconcile_with_traffic() {
        let fp = |s: usize| AllocFingerprint::of(&alloc(&[s], &[1.0]));
        let mut memo = SwapMemo::new();
        // empty exchange lists are cached and hit at zero cost
        memo.insert(0, 1, fp(0), fp(1), Vec::new());
        assert_eq!(memo.misses(), 0);
        assert!(memo.lookup(0, 1, &fp(0), &fp(1)).is_some());
        assert_eq!(memo.hits(), 0, "empty hit contributes zero sides");
        memo.insert(1, 2, fp(1), fp(2), vec![exchange(1, 2), exchange(2, 1), exchange(1, 1)]);
        assert_eq!(memo.misses(), 6);
        for _ in 0..3 {
            assert_eq!(memo.lookup(1, 2, &fp(1), &fp(2)).unwrap().len(), 3);
        }
        assert_eq!(memo.hits(), 18);
        assert_eq!(memo.invalidate_touching(&[false, true, false]), 6);
        assert_eq!(memo.invalidated(), 6);
        assert_eq!(memo.len(), 1, "(0,1) untouched");
    }
}
