//! Allocation results and their invariants.

use crate::flow::Workflow;

/// Result of resource allocation + task (rate) scheduling: which server
/// sits in each leaf slot and what arrival rate it receives.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// slot index (DFS order) -> server id.
    pub slot_server: Vec<usize>,
    /// slot index -> Poisson arrival rate λ_i routed to that slot.
    pub slot_rate: Vec<f64>,
}

/// Scheduler failure.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedError {
    /// Fewer servers than workflow slots.
    NotEnoughServers {
        /// Slots required by the workflow.
        need: usize,
        /// Servers offered.
        have: usize,
    },
    /// No feasible (stable) allocation exists for the offered load.
    Infeasible(String),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::NotEnoughServers { need, have } => {
                write!(f, "need {need} servers, have {have}")
            }
            SchedError::Infeasible(why) => write!(f, "infeasible allocation: {why}"),
        }
    }
}

impl std::error::Error for SchedError {}

impl Allocation {
    /// Construct with invariant checks against a workflow and pool size.
    pub fn new(
        slot_server: Vec<usize>,
        slot_rate: Vec<f64>,
        wf: &Workflow,
        pool_size: usize,
    ) -> Result<Allocation, SchedError> {
        let a = Allocation {
            slot_server,
            slot_rate,
        };
        a.validate(wf, pool_size)?;
        Ok(a)
    }

    /// Invariants: every slot filled, each server used at most once,
    /// server ids in range, all rates positive and finite.
    pub fn validate(&self, wf: &Workflow, pool_size: usize) -> Result<(), SchedError> {
        if self.slot_server.len() != wf.slots() || self.slot_rate.len() != wf.slots() {
            return Err(SchedError::Infeasible(format!(
                "allocation covers {} slots; workflow has {}",
                self.slot_server.len(),
                wf.slots()
            )));
        }
        let mut used = vec![false; pool_size];
        for &sid in &self.slot_server {
            if sid >= pool_size {
                return Err(SchedError::Infeasible(format!("server id {sid} out of range")));
            }
            if used[sid] {
                return Err(SchedError::Infeasible(format!("server {sid} used twice")));
            }
            used[sid] = true;
        }
        if let Some(r) = self.slot_rate.iter().find(|r| !(**r > 0.0) || !r.is_finite()) {
            return Err(SchedError::Infeasible(format!("bad slot rate {r}")));
        }
        Ok(())
    }

    /// Iterator over assigned server ids.
    pub fn assigned_servers(&self) -> impl Iterator<Item = usize> + '_ {
        self.slot_server.iter().copied()
    }

    /// Server id in a slot.
    pub fn server_for(&self, slot: usize) -> usize {
        self.slot_server[slot]
    }

    /// Arrival rate into a slot.
    pub fn rate_for(&self, slot: usize) -> f64 {
        self.slot_rate[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::Workflow;

    #[test]
    fn valid_allocation_passes() {
        let wf = Workflow::fig6();
        let a = Allocation::new(vec![0, 1, 2, 3, 4, 5], vec![4.0; 6], &wf, 6);
        assert!(a.is_ok());
    }

    #[test]
    fn duplicate_server_rejected() {
        let wf = Workflow::fig6();
        let a = Allocation::new(vec![0, 0, 2, 3, 4, 5], vec![4.0; 6], &wf, 6);
        assert!(matches!(a, Err(SchedError::Infeasible(_))));
    }

    #[test]
    fn out_of_range_rejected() {
        let wf = Workflow::fig6();
        let a = Allocation::new(vec![0, 1, 2, 3, 4, 9], vec![4.0; 6], &wf, 6);
        assert!(a.is_err());
    }

    #[test]
    fn wrong_arity_rejected() {
        let wf = Workflow::fig6();
        let a = Allocation::new(vec![0, 1, 2], vec![4.0; 3], &wf, 6);
        assert!(a.is_err());
    }

    #[test]
    fn bad_rate_rejected() {
        let wf = Workflow::fig6();
        let a = Allocation::new(vec![0, 1, 2, 3, 4, 5], vec![0.0; 6], &wf, 6);
        assert!(a.is_err());
        let a = Allocation::new(
            vec![0, 1, 2, 3, 4, 5],
            vec![1.0, 1.0, 1.0, 1.0, 1.0, f64::NAN],
            &wf,
            6,
        );
        assert!(a.is_err());
    }
}
