//! Deprecated free-function shims over the [`crate::plan`] surface.
//!
//! These are the crate's original five mutually-inconsistent entry
//! points. They survive for source compatibility only: each is a thin
//! wrapper over [`Planner`] with the matching policy object, returns
//! exactly the allocation the new path produces, and carries a
//! `#[deprecated]` pointer at its replacement. New code (and everything
//! inside this crate outside this module and its equivalence tests)
//! uses [`Planner`] directly. Before/after migration snippets for every
//! shim live in `docs/MIGRATION.md` at the repository root.

use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::plan::{BaselinePolicy, OptimalPolicy, Planner, ProposedPolicy, SdccPolicy};
use crate::sched::allocation::{Allocation, SchedError};
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::Objective;

/// Paper's scheme (Alg. 1 + 2 + equilibrium) with the default M/M/1
/// response model.
#[deprecated(
    since = "0.2.0",
    note = "use `Planner::new(wf, servers).allocate(&SdccPolicy)`; see docs/MIGRATION.md"
)]
pub fn sdcc_allocate(wf: &Workflow, servers: &[Server]) -> Result<Allocation, SchedError> {
    Planner::new(wf, servers).allocate(&SdccPolicy)
}

/// §3 heuristic baseline with uniform (homogeneous-assumption) fork
/// splits.
#[deprecated(
    since = "0.2.0",
    note = "use `Planner::new(wf, servers).model(model).allocate(&BaselinePolicy::default())`; see docs/MIGRATION.md"
)]
pub fn baseline_allocate(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
) -> Result<Allocation, SchedError> {
    Planner::new(wf, servers)
        .model(model)
        .allocate(&BaselinePolicy::default())
}

/// The paper's full proposed scheme (Alg. 1/2 seed + §3 balancing).
/// Returns the same `(Allocation, Score)` the legacy pipeline did: the
/// planner's evaluation grid is the seed-derived response grid the
/// legacy function scored on.
#[deprecated(
    since = "0.2.0",
    note = "use `Planner::new(wf, servers).model(model).objective(objective).plan(&ProposedPolicy::default())`; see docs/MIGRATION.md"
)]
pub fn proposed_allocate(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
) -> Result<(Allocation, Score), SchedError> {
    let plan = Planner::new(wf, servers)
        .model(model)
        .objective(objective)
        .plan(&ProposedPolicy::default())?;
    Ok((plan.allocation, plan.score))
}

/// Exhaustive-search optimal reference on an explicit grid.
#[deprecated(
    since = "0.2.0",
    note = "use `Planner::new(wf, servers).model(model).objective(objective).grid(grid).plan(&OptimalPolicy)`; see docs/MIGRATION.md"
)]
pub fn optimal_allocate(
    wf: &Workflow,
    servers: &[Server],
    grid: &GridSpec,
    objective: Objective,
    model: ResponseModel,
) -> Result<(Allocation, Score), SchedError> {
    let plan = Planner::new(wf, servers)
        .model(model)
        .objective(objective)
        .grid(*grid)
        .plan(&OptimalPolicy)?;
    Ok((plan.allocation, plan.score))
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::plan::AllocationPolicy;
    use crate::sched::algorithms::{allocate_with, baseline_allocate_split, SplitPolicy};
    use crate::sched::optimal::exhaustive;
    use crate::sched::refine::propose;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn shims_match_engine_bit_for_bit() {
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        assert_eq!(
            sdcc_allocate(&wf, &servers).unwrap(),
            allocate_with(&wf, &servers, model).unwrap()
        );
        assert_eq!(
            baseline_allocate(&wf, &servers, model).unwrap(),
            baseline_allocate_split(&wf, &servers, model, SplitPolicy::Uniform).unwrap()
        );
        let (a_shim, s_shim) = proposed_allocate(&wf, &servers, model, Objective::Mean).unwrap();
        let (a_engine, s_engine) = propose(&wf, &servers, model, Objective::Mean).unwrap();
        assert_eq!(a_shim, a_engine);
        // same seed-derived evaluation grid => bit-identical scores too
        assert_eq!(s_shim.mean, s_engine.mean);
        assert_eq!(s_shim.var, s_engine.var);
        assert_eq!(s_shim.p99, s_engine.p99);
        let grid = GridSpec::auto_pool(&wf, &servers);
        let (o_shim, s_shim) =
            optimal_allocate(&wf, &servers, &grid, Objective::Mean, model).unwrap();
        let (o_engine, s_engine) =
            exhaustive(&wf, &servers, &grid, Objective::Mean, model).unwrap();
        assert_eq!(o_shim, o_engine);
        assert_eq!(s_shim.mean, s_engine.mean);
    }

    #[test]
    fn shim_errors_match_planner_errors() {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[5.0, 5.5]);
        let via_shim = sdcc_allocate(&wf, &servers);
        let via_planner = Planner::new(&wf, &servers).allocate(&SdccPolicy);
        assert_eq!(via_shim, via_planner);
        assert!(matches!(
            via_shim,
            Err(SchedError::NotEnoughServers { need: 6, have: 2 })
        ));
    }

    #[test]
    fn policy_names_are_stable() {
        // the names appear in CSVs and reports; keep them pinned
        assert_eq!(SdccPolicy.name(), "sdcc");
        assert_eq!(BaselinePolicy::default().name(), "baseline");
        assert_eq!(
            BaselinePolicy {
                split: SplitPolicy::Equilibrium
            }
            .name(),
            "fair-baseline"
        );
        assert_eq!(ProposedPolicy::default().name(), "proposed");
        assert_eq!(OptimalPolicy.name(), "optimal");
    }
}
