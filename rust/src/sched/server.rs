//! Server descriptors.

use crate::dist::ServiceDist;

/// A compute server: an identity plus its (monitored or declared)
/// service-time law. The paper's "compute power of a server, i.e. recent
/// waiting time distribution" (Alg. 3 input).
#[derive(Clone, Debug)]
pub struct Server {
    /// Stable id; also its index in the pool slice handed to schedulers.
    pub id: usize,
    /// Service-time distribution.
    pub dist: ServiceDist,
}

impl Server {
    /// New server.
    pub fn new(id: usize, dist: ServiceDist) -> Server {
        Server { id, dist }
    }

    /// Pool of exponential servers from service rates (the paper's
    /// "servers with service rates 9, 8, 7, 6, 5, 4" style setup).
    pub fn pool_exponential(rates: &[f64]) -> Vec<Server> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &mu)| Server::new(i, ServiceDist::exponential(mu)))
            .collect()
    }

    /// Mean service time.
    pub fn mean_service(&self) -> f64 {
        self.dist.mean()
    }

    /// Nominal service rate (1 / mean service time).
    pub fn service_rate(&self) -> f64 {
        self.dist.rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_builder() {
        let pool = Server::pool_exponential(&[9.0, 8.0, 7.0]);
        assert_eq!(pool.len(), 3);
        assert_eq!(pool[2].id, 2);
        assert!((pool[0].service_rate() - 9.0).abs() < 1e-6);
        assert!((pool[1].mean_service() - 0.125).abs() < 1e-6);
    }
}
