//! Capacity planning: the paper's dual objective ("minimizing response
//! time … is the dual optimization of maximizing the throughput", §3).
//!
//! * [`max_throughput`] — the largest entry-DAP rate λ* a server pool can
//!   sustain on a workflow (bisection over λ with feasibility given by
//!   the allocator — every queue stable and the equilibrium solvable);
//! * [`max_throughput_under_sla`] — λ* subject to a response-time SLA
//!   (mean or p99 bound), the knob an operator actually sets;
//! * [`required_speedup`] — how much faster a *uniform* pool would have
//!   to be to match a target load (sizing what heterogeneity costs).

use crate::compose::grid::GridSpec;
use crate::compose::score::score_allocation_with;
use crate::flow::{Dcc, Workflow};
use crate::sched::refine::propose;
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::{Objective, SchedError};

/// Rebuild a workflow with every DAP rate scaled by `k` (shape preserved).
pub fn scale_rates(wf: &Workflow, k: f64) -> Workflow {
    fn scale(d: &Dcc, k: f64) -> Dcc {
        match d {
            Dcc::Queue { .. } => Dcc::queue(),
            Dcc::Serial { children, rates } => Dcc::Serial {
                children: children.iter().map(|c| scale(c, k)).collect(),
                rates: rates.iter().map(|r| r.map(|x| x * k)).collect(),
            },
            Dcc::Parallel { children, rates } => Dcc::Parallel {
                children: children.iter().map(|c| scale(c, k)).collect(),
                rates: rates.iter().map(|r| r.map(|x| x * k)).collect(),
            },
        }
    }
    Workflow::new(scale(wf.root(), k), wf.arrival_rate * k).expect("scaled workflow valid")
}

/// Feasibility of the workflow at load scale `k` for this pool.
fn feasible(wf: &Workflow, servers: &[Server], model: ResponseModel, k: f64) -> bool {
    let scaled = scale_rates(wf, k);
    propose(&scaled, servers, model, Objective::Mean)
        .map(|(_, s)| s.is_stable())
        .unwrap_or(false)
}

/// Largest load scale `k*` (relative to the workflow's declared rates)
/// the pool sustains, to `tol` relative precision.
pub fn max_load_scale(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    tol: f64,
) -> Result<f64, SchedError> {
    if !feasible(wf, servers, model, 1e-6) {
        return Err(SchedError::Infeasible(
            "pool cannot sustain any load on this workflow".into(),
        ));
    }
    let (mut lo, mut hi) = (1e-6f64, 1.0f64);
    while feasible(wf, servers, model, hi) {
        lo = hi;
        hi *= 2.0;
        if hi > 1e6 {
            break;
        }
    }
    while (hi - lo) / hi > tol {
        let mid = 0.5 * (lo + hi);
        if feasible(wf, servers, model, mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(lo)
}

/// Largest sustainable entry rate λ* = k* · λ_declared.
pub fn max_throughput(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
) -> Result<f64, SchedError> {
    Ok(max_load_scale(wf, servers, model, 1e-3)? * wf.arrival_rate)
}

/// SLA bound kind for [`max_throughput_under_sla`].
#[derive(Clone, Copy, Debug)]
pub enum Sla {
    /// Mean end-to-end response time ≤ bound.
    Mean(f64),
    /// 99th percentile ≤ bound.
    P99(f64),
}

/// Largest entry rate whose *optimized* allocation still meets the SLA.
pub fn max_throughput_under_sla(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    sla: Sla,
) -> Result<f64, SchedError> {
    let meets = |k: f64| -> bool {
        let scaled = scale_rates(wf, k);
        let Ok((alloc, _)) = propose(&scaled, servers, model, Objective::Mean)
        else {
            return false;
        };
        let grid = GridSpec::auto_response(&alloc, servers, model);
        let s = score_allocation_with(&scaled, &alloc, servers, &grid, model);
        if !s.is_stable() {
            return false;
        }
        match sla {
            Sla::Mean(b) => s.mean <= b,
            Sla::P99(b) => s.p99 <= b,
        }
    };
    if !meets(1e-6) {
        return Err(SchedError::Infeasible(
            "SLA unreachable even at negligible load".into(),
        ));
    }
    let (mut lo, mut hi) = (1e-6f64, 1.0f64);
    while meets(hi) {
        lo = hi;
        hi *= 2.0;
        if hi > 1e6 {
            break;
        }
    }
    for _ in 0..30 {
        let mid = 0.5 * (lo + hi);
        if meets(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(lo * wf.arrival_rate)
}

/// Uniform-pool service rate needed to sustain the workflow at its
/// declared rates (heterogeneity cost probe): the smallest `mu` such
/// that `slots()` copies of Exp(mu) are feasible at k = 1.
pub fn required_speedup(wf: &Workflow, model: ResponseModel) -> f64 {
    let feas = |mu: f64| -> bool {
        let servers = Server::pool_exponential(&vec![mu; wf.slots()]);
        feasible(wf, &servers, model, 1.0)
    };
    let (mut lo, mut hi) = (1e-3f64, 1.0f64);
    while !feas(hi) {
        lo = hi;
        hi *= 2.0;
        if hi > 1e9 {
            return hi;
        }
    }
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feas(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_queue_capacity_is_mu() {
        // tandem(1): capacity = the (single, fastest-kept) server rate
        let wf = Workflow::tandem(1, 1.0);
        let servers = Server::pool_exponential(&[5.0]);
        let cap = max_throughput(&wf, &servers, ResponseModel::Mm1).unwrap();
        assert!((cap - 5.0).abs() < 0.02 * 5.0, "cap {cap}");
    }

    #[test]
    fn forkjoin_capacity_is_sum() {
        // 2-branch fork with equilibrium split: capacity = mu1 + mu2
        let wf = Workflow::forkjoin(2, 1.0);
        let servers = Server::pool_exponential(&[4.0, 2.0]);
        let cap = max_throughput(&wf, &servers, ResponseModel::Mm1).unwrap();
        assert!((cap - 6.0).abs() < 0.05 * 6.0, "cap {cap}");
    }

    #[test]
    fn fig6_capacity_reasonable() {
        // fig6 bottleneck: SDCC stages carry λ/2 each relative to entry 8;
        // with refinement the binding constraint is an SDCC single queue
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let k = max_load_scale(&wf, &servers, ResponseModel::Mm1, 1e-3).unwrap();
        // from the load sweep: feasible at 1.5, infeasible by ~2
        assert!(k > 1.4 && k < 2.2, "k* = {k}");
    }

    #[test]
    fn sla_throughput_below_raw_capacity() {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let raw = max_throughput(&wf, &servers, ResponseModel::Mm1).unwrap();
        let sla = max_throughput_under_sla(
            &wf,
            &servers,
            ResponseModel::Mm1,
            Sla::Mean(2.0),
        )
        .unwrap();
        assert!(sla < raw, "sla {sla} raw {raw}");
        assert!(sla > 0.2 * raw, "sla {sla} unreasonably small vs {raw}");
    }

    #[test]
    fn tighter_sla_lower_throughput() {
        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let loose = max_throughput_under_sla(&wf, &servers, ResponseModel::Mm1, Sla::Mean(3.0))
            .unwrap();
        let tight = max_throughput_under_sla(&wf, &servers, ResponseModel::Mm1, Sla::Mean(1.6))
            .unwrap();
        assert!(tight < loose, "tight {tight} loose {loose}");
    }

    #[test]
    fn required_speedup_matches_bottleneck() {
        // fig6 at declared rates: a uniform pool must cover the SDCC's
        // λ=4 single-queue stages, so mu must exceed 4
        let wf = Workflow::fig6();
        let mu = required_speedup(&wf, ResponseModel::Mm1);
        assert!(mu > 4.0 && mu < 8.0, "mu {mu}");
    }

    #[test]
    fn infeasible_pool_reported() {
        let wf = Workflow::tandem(2, 1.0);
        let servers = Server::pool_exponential(&[1.0]); // too few servers
        assert!(max_throughput(&wf, &servers, ResponseModel::Mm1).is_err());
    }

    #[test]
    fn scale_rates_preserves_shape() {
        let wf = Workflow::fig6();
        let scaled = scale_rates(&wf, 2.0);
        assert_eq!(scaled.slots(), wf.slots());
        assert_eq!(scaled.arrival_rate, 16.0);
        match scaled.root() {
            Dcc::Serial { rates, .. } => assert_eq!(rates[0], Some(16.0)),
            _ => panic!("shape changed"),
        }
    }
}
