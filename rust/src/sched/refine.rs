//! Local-search refinement — the §3 min-max balancing step.
//!
//! The paper's §3 states the optimization principles behind its scheme:
//! "the waiting time of all serial components must be minimum and the
//! same … we desire to minimize the delay of the SDCC which has the
//! highest delay" and Lemma 1 (divide and conquer over serial/parallel
//! components). Algorithm 1/2's sort-matching produces a good seed but
//! does not by itself *balance* delays across components; this module
//! completes the scheme with a greedy pairwise-swap hill-climb:
//!
//! 1. start from the Alg. 1/2 placement;
//! 2. try every slot-pair server swap; re-schedule rates; keep the swap
//!    that most improves the objective (exact grid scoring);
//! 3. repeat until no swap improves (or `max_rounds`).
//!
//! [`propose`] = Alg. 1/2 seed + this refinement: the "our approach"
//! line of the paper's Fig. 7 / Table 2, surfaced publicly as
//! [`crate::plan::ProposedPolicy`]. Cost: O(S²) exact scores per
//! round, S = slots — trivially affordable next to the exhaustive
//! optimal's O(S!) and far below it in latency, preserving the paper's
//! "little gap from the optimal choice" framing.

use crate::compose::backend::{AnalyticBackend, ScoreBackend};
use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::algorithms::{allocate_with, schedule_rates};
use crate::sched::allocation::{Allocation, SchedError};
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::Objective;

/// The paper's full proposed scheme: Alg. 1/2 seed + §3 balancing.
/// Engine-layer function; prefer [`crate::plan::ProposedPolicy`] via
/// the planner.
pub fn propose(
    wf: &Workflow,
    servers: &[Server],
    model: ResponseModel,
    objective: Objective,
) -> Result<(Allocation, Score), SchedError> {
    let seed = allocate_with(wf, servers, model)?;
    let grid = GridSpec::auto_response(&seed, servers, model);
    refine(wf, seed, servers, &grid, model, objective, 8)
}

/// Hill-climb from an existing allocation with the default
/// [`AnalyticBackend`]. Returns the refined allocation and its exact
/// score on `grid`. See [`refine_with`] for an injected backend.
pub fn refine(
    wf: &Workflow,
    start: Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
    objective: Objective,
    max_rounds: usize,
) -> Result<(Allocation, Score), SchedError> {
    refine_with(
        wf,
        start,
        servers,
        grid,
        model,
        objective,
        max_rounds,
        &AnalyticBackend,
    )
}

/// Hill-climb from an existing allocation, scoring every candidate
/// through `backend`. Each round's swap candidates are scored as one
/// wave ([`ScoreBackend::score_batch`]), so batched backends (the PJRT
/// scorer) evaluate a whole round in one fused call and a
/// [`ShardedBackend`](crate::compose::backend::ShardedBackend) spreads
/// the round across its worker threads. With [`AnalyticBackend`] —
/// sharded or not — this is bit-identical to the historical
/// one-at-a-time loop.
#[allow(clippy::too_many_arguments)]
pub fn refine_with(
    wf: &Workflow,
    start: Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
    objective: Objective,
    max_rounds: usize,
    backend: &dyn ScoreBackend,
) -> Result<(Allocation, Score), SchedError> {
    let slots = wf.slots();
    let mut best = start;
    let mut best_score = backend.score(wf, &best, servers, grid, model);

    for _round in 0..max_rounds {
        // enumerate this round's feasible swap candidates
        let mut candidates: Vec<Allocation> = Vec::new();
        for i in 0..slots {
            for j in (i + 1)..slots {
                let mut assign = best.slot_server.clone();
                assign.swap(i, j);
                if let Ok(cand) = schedule_rates(wf, assign, servers, model) {
                    candidates.push(cand);
                }
            }
        }
        // score the wave, then scan exactly like the legacy loop did:
        // keep the first candidate strictly better (1e-12 margin) than
        // the current champion
        let scores = backend.score_batch(wf, &candidates, servers, grid, model);
        let mut round_best: Option<(usize, Score)> = None;
        for (idx, score) in scores.into_iter().enumerate() {
            if !score.is_stable() {
                continue;
            }
            let current_key = round_best
                .as_ref()
                .map(|(_, s)| objective.key(s))
                .unwrap_or_else(|| objective.key(&best_score));
            if objective.key(&score) < current_key - 1e-12 {
                round_best = Some((idx, score));
            }
        }
        let mut improved = false;
        if let Some((idx, score)) = round_best {
            if objective.key(&score) < objective.key(&best_score) - 1e-12 {
                best = candidates.swap_remove(idx);
                best_score = score;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    Ok((best, best_score))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::score::score_allocation_with;
    use crate::sched::algorithms::baseline_allocate_split;
    use crate::sched::algorithms::SplitPolicy;
    use crate::sched::optimal::exhaustive;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn refinement_never_hurts() {
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let seed = allocate_with(&wf, &servers, model).unwrap();
        let grid = GridSpec::auto_response(&seed, &servers, model);
        let seed_score = score_allocation_with(&wf, &seed, &servers, &grid, model);
        let (_, refined) =
            refine(&wf, seed, &servers, &grid, model, Objective::Mean, 8).unwrap();
        assert!(refined.mean <= seed_score.mean + 1e-9);
    }

    #[test]
    fn proposed_close_to_optimal_beats_baseline() {
        // the paper's Table-2 ordering: optimal <= ours < baseline
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let (ours_alloc, ours) = propose(&wf, &servers, model, Objective::Mean).unwrap();
        ours_alloc.validate(&wf, servers.len()).unwrap();
        let grid = GridSpec::auto_response(&ours_alloc, &servers, model);
        let (_, opt) = exhaustive(&wf, &servers, &grid, Objective::Mean, model).unwrap();
        let base =
            baseline_allocate_split(&wf, &servers, model, SplitPolicy::Uniform).unwrap();
        let base_s = score_allocation_with(&wf, &base, &servers, &grid, model);
        assert!(opt.mean <= ours.mean + 1e-6, "opt {} ours {}", opt.mean, ours.mean);
        assert!(
            ours.mean <= base_s.mean + 1e-9,
            "ours {} base {}",
            ours.mean,
            base_s.mean
        );
        // little gap from optimal (paper's phrasing)
        assert!(
            ours.mean <= opt.mean * 1.05,
            "gap too large: ours {} opt {}",
            ours.mean,
            opt.mean
        );
    }

    #[test]
    fn sharded_refinement_is_bit_identical() {
        // the refinement engine's swap decisions depend on score order
        // within a wave; sharding must not perturb either
        use crate::compose::backend::ShardedBackend;
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let seed = allocate_with(&wf, &servers, model).unwrap();
        let grid = GridSpec::auto_response(&seed, &servers, model);
        let (serial_alloc, serial_score) = refine(
            &wf,
            seed.clone(),
            &servers,
            &grid,
            model,
            Objective::Mean,
            8,
        )
        .unwrap();
        for shards in [2usize, 8] {
            let backend = ShardedBackend::new(&AnalyticBackend, shards);
            let (alloc, score) = refine_with(
                &wf,
                seed.clone(),
                &servers,
                &grid,
                model,
                Objective::Mean,
                8,
                &backend,
            )
            .unwrap();
            assert_eq!(alloc, serial_alloc, "{shards} shards changed the allocation");
            assert_eq!(score.mean, serial_score.mean);
            assert_eq!(score.var, serial_score.var);
            assert_eq!(score.p99, serial_score.p99);
        }
    }

    #[test]
    fn variance_objective_reduces_variance() {
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let (_, by_mean) = propose(&wf, &servers, model, Objective::Mean).unwrap();
        let (_, by_var) = propose(&wf, &servers, model, Objective::Variance).unwrap();
        assert!(by_var.var <= by_mean.var + 1e-9);
    }
}
