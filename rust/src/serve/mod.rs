//! The live re-planning service: a [`Coordinator`] event loop that
//! re-plans continuously under admission control.
//!
//! The paper's argument (and *Runtime Variation in Big Data Analytics*,
//! PAPERS.md) is that a plan computed once is stale by the time it
//! executes — straggler tails drift, workers join and leave, load
//! shifts. [`Service`] turns the one-shot planner plus the passive
//! coordinator into a living loop:
//!
//! ```text
//!                   clocked event stream
//!   arrivals ───┐  churn (join/leave) ──┐  drift verdicts ──┐
//!               ▼                       ▼                   ▼
//!        ┌─────────────────────────────────────────────────────┐
//!        │                 Service event loop                  │
//!        │  dispatch task → feed monitors → completion metrics │
//!        │        │                                            │
//!        │        ▼ re-plan wanted? (churn / drift / periodic) │
//!        │  ┌───────────────── admission ─────────────────┐    │
//!        │  │ in-flight ≤ cap?  debounce elapsed?  forced? │    │
//!        │  └───────┬──────────────────────────┬──────────┘    │
//!        │    admitted                      shed (counted)     │
//!        │        ▼                                            │
//!        │  Planner::allocate through AsyncScoreBackend        │
//!        │  (chunks pipelined on the scoring fabric)           │
//!        │        ▼                                            │
//!        │  swap allocation if it changed (obs + trace event)  │
//!        └─────────────────────────────────────────────────────┘
//! ```
//!
//! The loop mirrors the capture/replay driver of [`crate::scenario`]
//! **exactly** (same dispatch recursion, same monitor feed, same
//! re-optimization rule), so a run recorded through
//! [`Service::start_recording`] replays bit-identically through
//! [`crate::scenario::Replay`] — the soak tests and the golden corpus
//! build on that. Re-planning goes through an [`AsyncScoreBackend`]
//! wrapping the planner's default analytic backend; because the async
//! adapter is bit-identical to its inner backend, the service's plans
//! are bit-identical to [`Coordinator`]'s own, pipelining included.
//!
//! ## Admission control
//!
//! Re-plan triggers are classified:
//!
//! * **forced** (membership churn) — the old allocation may reference a
//!   departed server, so these always run; shedding them would be a
//!   correctness bug, and they do not occupy planner capacity;
//! * **optimization** (drift verdicts, periodic checks) — subject to
//!   the in-flight cap ([`ServeConfig::max_inflight`], each admitted
//!   re-plan holds a slot for [`ServeConfig::replan_hold`] completions)
//!   and the debounce window ([`ServeConfig::debounce`] completions
//!   since the last admitted re-plan). Shed requests are counted, never
//!   silently dropped: `offered == admitted + shed` always holds
//!   (pinned in `tests/serve_soak.rs`).
//!
//! The default [`ServeConfig`] is *transparent* (cap 1, no debounce, no
//! hold): every optimization re-plan is admitted and the service's
//! decision sequence equals the plain capture/replay driver's — which
//! is exactly what makes its traces replayable. Restrictive settings
//! trade re-plan freshness for planner load, deterministically.
//!
//! Every decision is observable: `serve.replan` / `serve.shed` instant
//! events, a `serve.run` span around the loop, and counters published
//! into the [`crate::obs`] registry when tracing is enabled.

use std::collections::VecDeque;

use crate::compose::backend::{AnalyticBackend, AsyncScoreBackend, ScoreBackend};
use crate::coordinator::{
    Coordinator, CoordinatorConfig, Job, Metrics, Policy, RunReport, Task, WorkerSpec,
};
use crate::flow::Workflow;
use crate::plan::{BaselinePolicy, OptimalPolicy, Planner, ProposedPolicy};
use crate::scenario::record::ExecTrace;
use crate::scenario::zoo::{ChurnAction, ChurnOp, ScenarioSpec};
use crate::sched::server::Server;
use crate::sched::{Allocation, SchedError};
use crate::sim::trace::Trace;

/// Admission-control and scoring knobs for a [`Service`].
///
/// The default is transparent: every optimization re-plan is admitted,
/// so the service's decision sequence is identical to the plain
/// capture/replay driver's (see the [module docs](self)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Maximum optimization re-plans concurrently holding a planner
    /// slot (values `< 1` are treated as 1). Offers beyond the cap are
    /// shed and counted.
    pub max_inflight: usize,
    /// Minimum completions between two *admitted* optimization
    /// re-plans; offers inside the window are shed (0 = no debounce).
    pub debounce: u64,
    /// Completions an admitted re-plan occupies its planner slot for
    /// (0 = released immediately — the transparent default).
    pub replan_hold: u64,
    /// Fabric workers behind the [`AsyncScoreBackend`] the service
    /// plans through.
    pub shards: usize,
    /// In-flight chunk depth of that backend (its pipelining bound).
    pub wave_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_inflight: 1,
            debounce: 0,
            replan_hold: 0,
            shards: 2,
            wave_depth: 2,
        }
    }
}

/// What the admission controller did over one [`Service::run`].
///
/// Invariants (pinned in `tests/serve_soak.rs`): `offered == admitted +
/// shed`, `shed == shed_inflight + shed_debounce`, and `peak_inflight
/// <= max_inflight`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Re-plan opportunities presented to the controller (forced churn
    /// re-plans included).
    pub offered: u64,
    /// Offers that ran the planner (forced re-plans included).
    pub admitted: u64,
    /// Offers rejected by admission control.
    pub shed: u64,
    /// Shed because the in-flight cap was reached.
    pub shed_inflight: u64,
    /// Shed because the debounce window had not elapsed.
    pub shed_debounce: u64,
    /// Forced (churn) re-plans inside `admitted` — never shed.
    pub forced: u64,
    /// High-water mark of concurrently held planner slots.
    pub peak_inflight: usize,
    /// Admitted re-plans whose new allocation differed and was swapped
    /// in.
    pub swaps_applied: u64,
}

/// Outcome of one [`Service::run`]: the coordinator-level run report
/// plus the service-level decision record.
#[derive(Debug)]
pub struct ServeReport {
    /// Metrics, final allocation and swap log — same shape as a plain
    /// coordinator run, bit-comparable via
    /// [`crate::scenario::reports_identical`].
    pub run: RunReport,
    /// Admission-control counters.
    pub admission: AdmissionStats,
    /// Wall-clock seconds of every planner invocation — the initial
    /// plan followed by each admitted re-plan, in order — i.e. the
    /// latency of the *service* itself, reported by the soak harness.
    /// Timings are real time and therefore not deterministic; every
    /// *decision* in `run`/`admission` is.
    pub replan_secs: Vec<f64>,
}

/// The admission controller: a bounded window of held planner slots
/// plus the shed/admit counters.
struct Admission {
    cfg: ServeConfig,
    /// Completion counts at which each held slot expires.
    held: VecDeque<u64>,
    /// Completion count of the last admitted optimization re-plan.
    last_admitted: Option<u64>,
    stats: AdmissionStats,
}

impl Admission {
    fn new(cfg: ServeConfig) -> Admission {
        Admission {
            cfg,
            held: VecDeque::new(),
            last_admitted: None,
            stats: AdmissionStats::default(),
        }
    }

    /// Present one re-plan opportunity; returns whether to run the
    /// planner. Forced offers (churn) always pass and never occupy a
    /// slot — see the [module docs](self).
    fn offer(&mut self, completed: u64, forced: bool, reason: &str) -> bool {
        while self.held.front().is_some_and(|&e| e <= completed) {
            self.held.pop_front();
        }
        self.stats.offered += 1;
        if forced {
            self.stats.admitted += 1;
            self.stats.forced += 1;
            return true;
        }
        if self.held.len() >= self.cfg.max_inflight.max(1) {
            self.stats.shed += 1;
            self.stats.shed_inflight += 1;
            self.shed_event(completed, reason, "inflight");
            return false;
        }
        if let Some(last) = self.last_admitted {
            if self.cfg.debounce > 0 && completed < last + self.cfg.debounce {
                self.stats.shed += 1;
                self.stats.shed_debounce += 1;
                self.shed_event(completed, reason, "debounce");
                return false;
            }
        }
        self.stats.admitted += 1;
        self.last_admitted = Some(completed);
        self.held.push_back(completed + self.cfg.replan_hold);
        self.stats.peak_inflight = self.stats.peak_inflight.max(self.held.len());
        true
    }

    fn shed_event(&self, completed: u64, reason: &str, why: &str) {
        if crate::obs::enabled() {
            crate::obs::event(
                "serve.shed",
                vec![
                    ("reason".to_string(), reason.into()),
                    ("why".to_string(), why.into()),
                    ("completed".to_string(), completed.into()),
                ],
            );
        }
    }
}

/// The live re-planning service: owns a [`Coordinator`] and drives it
/// over a clocked event stream, re-planning through an
/// [`AsyncScoreBackend`] under admission control (see the
/// [module docs](self)).
pub struct Service {
    coord: Coordinator,
    cfg: ServeConfig,
}

impl Service {
    /// Service over a freshly spawned coordinator (one worker per
    /// spec; `initial_view` is the leader's prior belief).
    pub fn new(
        specs: Vec<WorkerSpec>,
        initial_view: Vec<Server>,
        coord_cfg: CoordinatorConfig,
        cfg: ServeConfig,
    ) -> Service {
        Service {
            coord: Coordinator::new(specs, initial_view, coord_cfg),
            cfg,
        }
    }

    /// Service over a workload-zoo scenario's live cluster (same
    /// workers, view and coordinator config as
    /// [`ScenarioSpec::capture`] uses).
    pub fn from_spec(spec: &ScenarioSpec, cfg: ServeConfig) -> Service {
        Service::new(
            spec.live_worker_specs(),
            spec.initial_view(),
            spec.config(),
            cfg,
        )
    }

    /// One-call soak entry point: run `spec`'s full event stream
    /// (arrivals + churn) through a recording service and return the
    /// report plus the captured [`ExecTrace`]. Under the transparent
    /// default [`ServeConfig`] the trace is byte-identical to
    /// [`ScenarioSpec::capture`]'s and replays through
    /// [`crate::scenario::Replay`].
    pub fn run_spec(
        spec: &ScenarioSpec,
        cfg: ServeConfig,
    ) -> Result<(ServeReport, ExecTrace), SchedError> {
        let mut service = Service::from_spec(spec, cfg);
        service.start_recording(&spec.name);
        let job = service.submit(&spec.name, spec.workflow());
        let arrivals = spec.arrival_trace();
        let churn = spec.churn_actions(None);
        let report = service.run(&job, &arrivals, &churn)?;
        let trace = service.take_trace().expect("recording was started");
        service.shutdown();
        Ok((report, trace))
    }

    /// Admission/scoring configuration in force.
    pub fn serve_config(&self) -> ServeConfig {
        self.cfg
    }

    /// The owned coordinator's believed pool.
    pub fn pool_view(&self) -> &[Server] {
        self.coord.pool_view()
    }

    /// Begin capturing an execution trace (see
    /// [`Coordinator::start_recording`]).
    pub fn start_recording(&mut self, scenario: &str) {
        self.coord.start_recording(scenario);
    }

    /// Finish recording and take the trace, if recording was started.
    pub fn take_trace(&mut self) -> Option<ExecTrace> {
        self.coord.take_trace()
    }

    /// Register a job with the owned coordinator.
    pub fn submit(&mut self, name: &str, workflow: Workflow) -> Job {
        self.coord.submit(name, workflow)
    }

    /// Shut the owned coordinator down; returns per-worker task counts.
    pub fn shutdown(self) -> Vec<u64> {
        self.coord.shutdown()
    }

    /// Drive `job` over the clocked event stream: `arrivals` paces
    /// task dispatch, `churn` injects membership events at their task
    /// sequence numbers, and the coordinator's monitors supply drift
    /// verdicts — the re-optimization rule, dispatch recursion and
    /// monitor feed are exactly the capture/replay driver's, with
    /// admission control layered on the optimization re-plans (see the
    /// [module docs](self)).
    pub fn run(
        &mut self,
        job: &Job,
        arrivals: &Trace,
        churn: &[ChurnAction],
    ) -> Result<ServeReport, SchedError> {
        let cfg = self.coord.config();
        let backend = AsyncScoreBackend::new(&AnalyticBackend, self.cfg.shards)
            .in_flight(self.cfg.wave_depth);
        let mut run_span = crate::obs::span("serve.run");
        if run_span.is_recording() {
            run_span.attr("tasks", arrivals.arrivals.len());
            run_span.attr("servers", self.coord.workers_len());
            run_span.attr("max_inflight", self.cfg.max_inflight);
            run_span.attr("debounce", self.cfg.debounce);
        }
        let mut admission = Admission::new(self.cfg);
        let mut replan_secs: Vec<f64> = Vec::new();
        let mut alloc = Self::plan(&self.coord, job, &backend, &mut replan_secs)?;
        let mut metrics = Metrics::new(self.coord.workers_len());
        let mut swaps: Vec<(u64, String)> = Vec::new();
        let mut next_free = vec![0.0f64; self.coord.workers_len()];
        let mut ci = 0usize;

        for (seq, &arrival) in arrivals.arrivals.iter().enumerate() {
            let mut membership_changed = false;
            while ci < churn.len() && churn[ci].at_seq <= seq as u64 {
                match &churn[ci].op {
                    ChurnOp::Join { spec, prior } => {
                        self.coord.add_worker(spec.clone(), prior.clone());
                        next_free.push(0.0);
                        metrics.ensure_servers(self.coord.workers_len());
                    }
                    ChurnOp::Leave => {
                        self.coord.remove_last_worker();
                        next_free.pop();
                    }
                }
                membership_changed = true;
                ci += 1;
            }
            if membership_changed {
                // the old allocation may reference a departed server or
                // ignore a joined one: this re-plan is forced — shedding
                // it would leave a dangling assignment
                admission.offer(metrics.completed, true, "churn");
                let new_alloc = Self::plan(&self.coord, job, &backend, &mut replan_secs)?;
                Self::apply(
                    &mut self.coord,
                    &mut alloc,
                    new_alloc,
                    &mut metrics,
                    &mut swaps,
                    &mut admission.stats,
                    "churn",
                );
            }

            let task = Task {
                job_id: job.id,
                seq: seq as u64,
                arrival,
            };
            self.coord.record_arrival(seq as u64, arrival);
            let finish = self.coord.dispatch(
                job.workflow.root(),
                &alloc,
                arrival,
                1.0,
                &mut next_free,
                &mut metrics,
            );
            metrics.record_completion(finish - task.arrival, finish);

            // Algorithm 3's periodic re-optimization cadence, gated by
            // the admission controller
            if cfg.reopt_every > 0 && metrics.completed % cfg.reopt_every == 0 {
                let drifted = self.coord.monitors().any_drifted(cfg.min_fit_samples / 2);
                if drifted || !cfg.reopt_on_drift_only {
                    let reason = if drifted { "drift" } else { "periodic" };
                    if admission.offer(metrics.completed, false, reason) {
                        self.coord.refresh_pool_view();
                        if let Ok(new_alloc) =
                            Self::plan(&self.coord, job, &backend, &mut replan_secs)
                        {
                            Self::apply(
                                &mut self.coord,
                                &mut alloc,
                                new_alloc,
                                &mut metrics,
                                &mut swaps,
                                &mut admission.stats,
                                reason,
                            );
                        }
                    }
                }
            }
        }

        if crate::obs::enabled() {
            let st = &admission.stats;
            run_span.attr("offered", st.offered);
            run_span.attr("shed", st.shed);
            let reg = crate::obs::registry();
            reg.counter("serve.replans_offered").add(st.offered);
            reg.counter("serve.replans_admitted").add(st.admitted);
            reg.counter("serve.replans_shed").add(st.shed);
            reg.counter("serve.swaps_applied").add(st.swaps_applied);
            metrics.publish(reg);
        }
        Ok(ServeReport {
            run: RunReport {
                metrics,
                final_allocation: alloc,
                swaps,
            },
            admission: admission.stats,
            replan_secs,
        })
    }

    /// One planner invocation through the async backend — the same
    /// planner construction as [`Coordinator`]'s own allocator, so the
    /// result is bit-identical to it (the async adapter is bit-identical
    /// to the analytic backend it wraps). Wall time is appended to
    /// `timings`.
    fn plan(
        coord: &Coordinator,
        job: &Job,
        backend: &AsyncScoreBackend<'_>,
        timings: &mut Vec<f64>,
    ) -> Result<Allocation, SchedError> {
        let cfg = coord.config();
        let mut span = crate::obs::span("serve.replan");
        if span.is_recording() {
            span.attr("backend", backend.name());
        }
        let started = std::time::Instant::now();
        let planner = Planner::new(&job.workflow, coord.pool_view())
            .model(cfg.model)
            .objective(cfg.objective)
            .backend(backend);
        let out = match cfg.policy {
            Policy::Proposed => planner.allocate(&ProposedPolicy::default()),
            Policy::Baseline => planner.allocate(&BaselinePolicy::default()),
            Policy::Optimal => planner.allocate(&OptimalPolicy),
        };
        timings.push(started.elapsed().as_secs_f64());
        out
    }

    /// Swap `new_alloc` in if it differs from the one in force,
    /// recording the re-optimization everywhere a coordinator run
    /// would (metrics, trace recorder, swap log) plus the service's
    /// own counters and instant event.
    #[allow(clippy::too_many_arguments)]
    fn apply(
        coord: &mut Coordinator,
        alloc: &mut Allocation,
        new_alloc: Allocation,
        metrics: &mut Metrics,
        swaps: &mut Vec<(u64, String)>,
        stats: &mut AdmissionStats,
        reason: &str,
    ) {
        if new_alloc == *alloc {
            return;
        }
        *alloc = new_alloc;
        metrics.record_reopt();
        coord.record_reopt(metrics.completed, reason);
        swaps.push((metrics.completed, reason.to_string()));
        stats.swaps_applied += 1;
        if crate::obs::enabled() {
            crate::obs::event(
                "serve.replan",
                vec![
                    ("reason".to_string(), reason.into()),
                    ("completed".to_string(), metrics.completed.into()),
                ],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::reports_identical;

    #[test]
    fn transparent_service_equals_capture() {
        // the keystone: under the transparent default config the
        // service's decisions are the capture/replay driver's, bit for
        // bit — trace and report alike
        let spec = ScenarioSpec::serve_soak_short().with_tasks(120);
        let (captured_report, captured_trace) = spec.capture().expect("capture runs");
        let (served, served_trace) =
            Service::run_spec(&spec, ServeConfig::default()).expect("service runs");
        assert!(reports_identical(&captured_report, &served.run));
        assert_eq!(captured_trace, served_trace);
        assert_eq!(served_trace.to_jsonl(), captured_trace.to_jsonl());
        // transparent admission: nothing shed, invariants hold
        let st = served.admission;
        assert_eq!(st.shed, 0);
        assert_eq!(st.offered, st.admitted + st.shed);
        // planner invocations: the initial plan + every admitted offer
        assert_eq!(st.admitted as usize + 1, served.replan_secs.len());
        assert!(st.peak_inflight <= 1);
    }

    #[test]
    fn forced_churn_replans_survive_zero_capacity() {
        // a config that sheds every optimization re-plan must still
        // re-plan on membership churn (correctness, not optimization)
        let spec = ScenarioSpec::serve_soak_short().with_tasks(120);
        let cfg = ServeConfig {
            debounce: u64::MAX,
            ..ServeConfig::default()
        };
        let (report, _) = Service::run_spec(&spec, cfg).expect("service runs");
        let st = report.admission;
        assert_eq!(st.offered, st.admitted + st.shed);
        assert_eq!(st.admitted, st.forced, "only forced re-plans admitted");
        assert!(st.forced >= 1, "churn scenario must force re-plans");
        // every swap in the log is a churn swap
        assert!(report.run.swaps.iter().all(|(_, r)| r == "churn"));
    }
}
