//! Series–parallel DCC tree nodes.

/// A Data Computing Component: a leaf queue (one server slot) or a
/// serial / parallel composition of child DCCs (paper Fig. 1/4/5).
#[derive(Clone, Debug, PartialEq)]
pub enum Dcc {
    /// A single queue: one server slot, identified by its DFS leaf index.
    Queue {
        /// Leaf slot index (assigned by [`super::Workflow::new`]).
        slot: usize,
    },
    /// Sequential composition (SDCC): data passes through every child in
    /// order — a tandem queue. Each child may sit behind its own DAP with
    /// its own monitored arrival rate.
    Serial {
        /// Children in pipeline order.
        children: Vec<Dcc>,
        /// Per-child DAP arrival rates where monitored (None = inherit).
        rates: Vec<Option<f64>>,
    },
    /// Parallel composition (PDCC): data is partitioned over the branches
    /// at a fork DAP and joined when the **last** branch completes.
    Parallel {
        /// Fork branches.
        children: Vec<Dcc>,
        /// Per-branch split rates where known a priori (None = to be set
        /// by the rate scheduler / equilibrium solver).
        rates: Vec<Option<f64>>,
    },
}

impl Dcc {
    /// Leaf constructor (slot is re-indexed by `Workflow::new`).
    pub fn queue() -> Dcc {
        Dcc::Queue { slot: usize::MAX }
    }

    /// Serial composition with unspecified child DAP rates.
    pub fn serial(children: Vec<Dcc>) -> Dcc {
        let n = children.len();
        Dcc::Serial {
            children,
            rates: vec![None; n],
        }
    }

    /// Serial composition with explicit child DAP rates.
    pub fn serial_with_rates(children: Vec<Dcc>, rates: Vec<Option<f64>>) -> Dcc {
        assert_eq!(children.len(), rates.len());
        Dcc::Serial { children, rates }
    }

    /// Parallel composition with scheduler-decided branch rates.
    pub fn parallel(children: Vec<Dcc>) -> Dcc {
        let n = children.len();
        Dcc::Parallel {
            children,
            rates: vec![None; n],
        }
    }

    /// Number of leaf queues (server slots) under this node.
    pub fn slot_count(&self) -> usize {
        match self {
            Dcc::Queue { .. } => 1,
            Dcc::Serial { children, .. } | Dcc::Parallel { children, .. } => {
                children.iter().map(|c| c.slot_count()).sum()
            }
        }
    }

    /// Depth of the tree (1 for a leaf).
    pub fn depth(&self) -> usize {
        match self {
            Dcc::Queue { .. } => 1,
            Dcc::Serial { children, .. } | Dcc::Parallel { children, .. } => {
                1 + children.iter().map(|c| c.depth()).max().unwrap_or(0)
            }
        }
    }

    /// The *serial depth*: number of queues any single datum traverses on
    /// the longest path (tail-growth driver, paper Fig. 2).
    pub fn serial_depth(&self) -> usize {
        match self {
            Dcc::Queue { .. } => 1,
            Dcc::Serial { children, .. } => children.iter().map(|c| c.serial_depth()).sum(),
            Dcc::Parallel { children, .. } => {
                children.iter().map(|c| c.serial_depth()).max().unwrap_or(0)
            }
        }
    }

    /// Visit leaves in DFS order.
    pub fn for_each_leaf(&self, f: &mut impl FnMut(usize)) {
        match self {
            Dcc::Queue { slot } => f(*slot),
            Dcc::Serial { children, .. } | Dcc::Parallel { children, .. } => {
                for c in children {
                    c.for_each_leaf(f);
                }
            }
        }
    }

    pub(crate) fn assign_slots(&mut self, next: &mut usize) {
        match self {
            Dcc::Queue { slot } => {
                *slot = *next;
                *next += 1;
            }
            Dcc::Serial { children, .. } | Dcc::Parallel { children, .. } => {
                for c in children {
                    c.assign_slots(next);
                }
            }
        }
    }

    /// Flatten directly nested compositions of the same kind
    /// (Serial(Serial(a,b),c) == Serial(a,b,c)); rates of collapsed
    /// children are preserved positionally.
    pub fn canonicalize(self) -> Dcc {
        match self {
            Dcc::Queue { slot } => Dcc::Queue { slot },
            Dcc::Serial { children, rates } => {
                let mut out_c = Vec::new();
                let mut out_r = Vec::new();
                for (c, r) in children.into_iter().zip(rates) {
                    match c.canonicalize() {
                        Dcc::Serial {
                            children: inner_c,
                            rates: inner_r,
                        } => {
                            // the inner chain inherits the outer DAP rate
                            // for its first element unless it had its own
                            for (i, (ic, ir)) in inner_c.into_iter().zip(inner_r).enumerate() {
                                out_c.push(ic);
                                out_r.push(if i == 0 { ir.or(r) } else { ir });
                            }
                        }
                        other => {
                            out_c.push(other);
                            out_r.push(r);
                        }
                    }
                }
                if out_c.len() == 1 {
                    out_c.pop().unwrap()
                } else {
                    Dcc::Serial {
                        children: out_c,
                        rates: out_r,
                    }
                }
            }
            Dcc::Parallel { children, rates } => {
                let mut out_c = Vec::new();
                let mut out_r = Vec::new();
                for (c, r) in children.into_iter().zip(rates) {
                    match c.canonicalize() {
                        Dcc::Parallel {
                            children: inner_c,
                            rates: inner_r,
                        } => {
                            out_c.extend(inner_c);
                            out_r.extend(inner_r);
                        }
                        other => {
                            out_c.push(other);
                            out_r.push(r);
                        }
                    }
                }
                if out_c.len() == 1 {
                    out_c.pop().unwrap()
                } else {
                    Dcc::Parallel {
                        children: out_c,
                        rates: out_r,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_count_and_depth() {
        let d = Dcc::serial(vec![
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::queue(),
        ]);
        assert_eq!(d.slot_count(), 3);
        assert_eq!(d.depth(), 3);
        assert_eq!(d.serial_depth(), 2); // parallel stage + queue
    }

    #[test]
    fn canonicalize_flattens_nested_serial() {
        let d = Dcc::serial(vec![
            Dcc::serial(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::queue(),
        ]);
        match d.canonicalize() {
            Dcc::Serial { children, .. } => assert_eq!(children.len(), 3),
            other => panic!("expected serial, got {other:?}"),
        }
    }

    #[test]
    fn canonicalize_unwraps_singletons() {
        let d = Dcc::serial(vec![Dcc::parallel(vec![Dcc::queue()])]);
        assert_eq!(d.canonicalize(), Dcc::Queue { slot: usize::MAX });
    }

    #[test]
    fn canonicalize_preserves_rates() {
        let inner = Dcc::serial_with_rates(
            vec![Dcc::queue(), Dcc::queue()],
            vec![Some(4.0), Some(2.0)],
        );
        let outer = Dcc::serial_with_rates(vec![inner, Dcc::queue()], vec![Some(8.0), None]);
        match outer.canonicalize() {
            Dcc::Serial { rates, .. } => {
                assert_eq!(rates, vec![Some(4.0), Some(2.0), None]);
            }
            other => panic!("expected serial, got {other:?}"),
        }
    }

    #[test]
    fn serial_depth_through_parallel() {
        let d = Dcc::parallel(vec![
            Dcc::serial(vec![Dcc::queue(), Dcc::queue(), Dcc::queue()]),
            Dcc::queue(),
        ]);
        assert_eq!(d.serial_depth(), 3);
    }
}
