//! Workflow graphs: series–parallel trees of DCCs joined at DAPs.
//!
//! The paper assumes "the logical graph of the job workflow is known
//! using a computational algorithm (out of the scope of this paper)";
//! here workflows arrive either programmatically ([`Workflow::fig6`],
//! builders in [`node`]) or as JSON specs ([`parse`]).

pub mod dag;
pub mod node;
pub mod parse;

pub use node::Dcc;

/// A validated workflow: canonicalized series–parallel tree with leaf
/// slots numbered `0..slots` in DFS order, plus the job arrival rate at
/// the entry DAP.
#[derive(Clone, Debug)]
pub struct Workflow {
    root: Dcc,
    slots: usize,
    /// Task arrival rate at the entry DAP (λ_DAP0).
    pub arrival_rate: f64,
}

/// Validation failure for a workflow spec.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowError(pub String);

impl std::fmt::Display for FlowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "workflow error: {}", self.0)
    }
}

impl std::error::Error for FlowError {}

impl Workflow {
    /// Build, canonicalize and validate a workflow.
    pub fn new(root: Dcc, arrival_rate: f64) -> Result<Workflow, FlowError> {
        if !(arrival_rate > 0.0) {
            return Err(FlowError(format!(
                "arrival rate must be positive (got {arrival_rate})"
            )));
        }
        validate(&root)?; // before canonicalize: singleton unwrapping must
                          // not hide invalid rates from validation
        let mut root = root.canonicalize();
        validate(&root)?;
        let mut next = 0usize;
        root.assign_slots(&mut next);
        Ok(Workflow {
            root,
            slots: next,
            arrival_rate,
        })
    }

    /// Parse a workflow from its JSON spec (the [`parse`] module's
    /// format) — the convenience entry for planning straight from a
    /// spec: `Planner::new(&Workflow::from_json(spec)?, &servers)`.
    pub fn from_json(text: &str) -> Result<Workflow, FlowError> {
        parse::workflow_from_json(text)
    }

    /// The paper's Fig. 6 evaluation workflow:
    /// `PDCC(2) ; SDCC(2) ; PDCC(2)` with DAP rates 8 → 4 → 2.
    pub fn fig6() -> Workflow {
        let root = Dcc::serial_with_rates(
            vec![
                Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
                Dcc::serial(vec![Dcc::queue(), Dcc::queue()]),
                Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
            ],
            vec![Some(8.0), Some(4.0), Some(2.0)],
        );
        Workflow::new(root, 8.0).expect("fig6 is valid")
    }

    /// A linear MapReduce-style chain: `n_stages` serial stages, each a
    /// PDCC with `fanout` branches (Fig. 1's repeated pattern).
    pub fn chain(n_stages: usize, fanout: usize, arrival_rate: f64) -> Workflow {
        let stages: Vec<Dcc> = (0..n_stages)
            .map(|_| {
                if fanout <= 1 {
                    Dcc::queue()
                } else {
                    Dcc::parallel((0..fanout).map(|_| Dcc::queue()).collect())
                }
            })
            .collect();
        Workflow::new(Dcc::serial(stages), arrival_rate).expect("chain is valid")
    }

    /// Pure tandem queue of `n` slots (Fig. 2 / Fig. 4 shape).
    pub fn tandem(n: usize, arrival_rate: f64) -> Workflow {
        Workflow::new(Dcc::serial((0..n).map(|_| Dcc::queue()).collect()), arrival_rate)
            .expect("tandem is valid")
    }

    /// Pure fork–join of `n` branches (Fig. 3 / Fig. 5 shape).
    pub fn forkjoin(n: usize, arrival_rate: f64) -> Workflow {
        Workflow::new(
            Dcc::parallel((0..n).map(|_| Dcc::queue()).collect()),
            arrival_rate,
        )
        .expect("forkjoin is valid")
    }

    /// Root of the tree.
    pub fn root(&self) -> &Dcc {
        &self.root
    }

    /// Number of server slots (leaves).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Longest tandem path length (tail-growth driver).
    pub fn serial_depth(&self) -> usize {
        self.root.serial_depth()
    }
}

fn validate(root: &Dcc) -> Result<(), FlowError> {
    match root {
        Dcc::Queue { .. } => Ok(()),
        Dcc::Serial { children, rates } | Dcc::Parallel { children, rates } => {
            if children.is_empty() {
                return Err(FlowError("composition with no children".into()));
            }
            if children.len() != rates.len() {
                return Err(FlowError("rates/children length mismatch".into()));
            }
            if let Some(r) = rates.iter().flatten().find(|r| !(**r > 0.0)) {
                return Err(FlowError(format!("non-positive DAP rate {r}")));
            }
            children.iter().try_for_each(validate)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape() {
        let wf = Workflow::fig6();
        assert_eq!(wf.slots(), 6);
        assert_eq!(wf.arrival_rate, 8.0);
        assert_eq!(wf.serial_depth(), 4); // par(1) + 2 serial + par(1)
        match wf.root() {
            Dcc::Serial { children, rates } => {
                assert_eq!(children.len(), 4); // canonicalized: inner SDCC flattened
                assert_eq!(rates[0], Some(8.0));
            }
            other => panic!("fig6 root should be serial, got {other:?}"),
        }
    }

    #[test]
    fn slots_are_dfs_ordered() {
        let wf = Workflow::fig6();
        let mut seen = Vec::new();
        wf.root().for_each_leaf(&mut |s| seen.push(s));
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn tandem_and_forkjoin() {
        assert_eq!(Workflow::tandem(10, 1.0).serial_depth(), 10);
        assert_eq!(Workflow::forkjoin(10, 1.0).serial_depth(), 1);
        assert_eq!(Workflow::forkjoin(10, 1.0).slots(), 10);
    }

    #[test]
    fn chain_builder() {
        let wf = Workflow::chain(3, 4, 2.0);
        assert_eq!(wf.slots(), 12);
        assert_eq!(wf.serial_depth(), 3);
    }

    #[test]
    fn from_json_convenience() {
        let wf =
            Workflow::from_json(r#"{"arrival_rate": 2.0, "root": {"type": "queue"}}"#).unwrap();
        assert_eq!(wf.slots(), 1);
        assert_eq!(wf.arrival_rate, 2.0);
        assert!(Workflow::from_json("{nope").is_err());
    }

    #[test]
    fn rejects_bad_rate() {
        assert!(Workflow::new(Dcc::queue(), 0.0).is_err());
        assert!(Workflow::new(Dcc::queue(), -1.0).is_err());
        let bad = Dcc::serial_with_rates(vec![Dcc::queue()], vec![Some(-2.0)]);
        assert!(Workflow::new(bad, 1.0).is_err());
    }
}
