//! General workflow DAGs and series–parallel recognition.
//!
//! The paper models workflows as series–parallel compositions (its
//! citation [17, 18]: "any distributed job can be modeled as series and
//! parallel servers"). Real dataflow graphs arrive as DAGs; this module
//! provides the bridge:
//!
//! * [`FlowDag`] — an arbitrary DAG of stages between a source and a
//!   sink DAP, with validation (acyclicity, reachability);
//! * [`FlowDag::to_series_parallel`] — recognizes two-terminal
//!   series–parallel DAGs by exhaustive series/parallel reduction and
//!   emits the equivalent [`Dcc`] tree (the classic TTSP algorithm:
//!   a DAG is TTSP iff it reduces to a single edge);
//! * non-SP DAGs are rejected with a precise error naming an
//!   irreducible vertex, so callers can fall back to simulation-only
//!   treatment.

use crate::flow::node::Dcc;
use crate::flow::FlowError;
use std::collections::{BTreeMap, BTreeSet};

/// A stage graph: nodes are DAPs, edges are processing stages (each
/// edge will become one leaf queue in the SP tree).
#[derive(Clone, Debug, Default)]
pub struct FlowDag {
    /// Edge list: (from DAP, to DAP, stage label).
    edges: Vec<(usize, usize, String)>,
    n_nodes: usize,
}

impl FlowDag {
    /// Empty DAG.
    pub fn new() -> FlowDag {
        FlowDag::default()
    }

    /// Add a processing stage from DAP `from` to DAP `to`.
    pub fn stage(mut self, from: usize, to: usize, label: &str) -> FlowDag {
        self.n_nodes = self.n_nodes.max(from + 1).max(to + 1);
        self.edges.push((from, to, label.to_string()));
        self
    }

    /// Number of DAP nodes.
    pub fn nodes(&self) -> usize {
        self.n_nodes
    }

    /// Number of stages (edges).
    pub fn stages(&self) -> usize {
        self.edges.len()
    }

    /// Validate: nonempty, no self-loops, acyclic, every node reachable
    /// from `source` and co-reachable from `sink`.
    pub fn validate(&self, source: usize, sink: usize) -> Result<(), FlowError> {
        if self.edges.is_empty() {
            return Err(FlowError("dag has no stages".into()));
        }
        if self.edges.iter().any(|(a, b, _)| a == b) {
            return Err(FlowError("self-loop stage".into()));
        }
        // Kahn topological sort for acyclicity
        let mut indeg = vec![0usize; self.n_nodes];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); self.n_nodes];
        let mut radj: Vec<Vec<usize>> = vec![Vec::new(); self.n_nodes];
        for (a, b, _) in &self.edges {
            indeg[*b] += 1;
            adj[*a].push(*b);
            radj[*b].push(*a);
        }
        let mut queue: Vec<usize> = (0..self.n_nodes).filter(|&v| indeg[v] == 0).collect();
        let mut seen = 0;
        let mut indeg_mut = indeg.clone();
        while let Some(v) = queue.pop() {
            seen += 1;
            for &w in &adj[v] {
                indeg_mut[w] -= 1;
                if indeg_mut[w] == 0 {
                    queue.push(w);
                }
            }
        }
        if seen != self.n_nodes {
            return Err(FlowError("workflow graph has a cycle".into()));
        }
        // reachability from source / co-reachability from sink
        let reach = |start: usize, adj: &Vec<Vec<usize>>| -> BTreeSet<usize> {
            let mut seen = BTreeSet::from([start]);
            let mut stack = vec![start];
            while let Some(v) = stack.pop() {
                for &w in &adj[v] {
                    if seen.insert(w) {
                        stack.push(w);
                    }
                }
            }
            seen
        };
        let fwd = reach(source, &adj);
        let bwd = reach(sink, &radj);
        for v in 0..self.n_nodes {
            let touched = self.edges.iter().any(|(a, b, _)| *a == v || *b == v);
            if touched && (!fwd.contains(&v) || !bwd.contains(&v)) {
                return Err(FlowError(format!(
                    "DAP {v} is not on a source→sink path"
                )));
            }
        }
        Ok(())
    }

    /// Recognize a two-terminal series–parallel DAG and build the
    /// equivalent [`Dcc`] tree.
    ///
    /// Repeatedly applies
    /// * **series reduction**: an interior DAP with in-degree 1 and
    ///   out-degree 1 merges its two stages into one `Serial`;
    /// * **parallel reduction**: multi-edges between the same DAP pair
    ///   merge into one `Parallel`.
    /// The DAG is TTSP iff this terminates with the single edge
    /// (source, sink) (Valdes–Tarjan–Lawler).
    pub fn to_series_parallel(&self, source: usize, sink: usize) -> Result<Dcc, FlowError> {
        self.validate(source, sink)?;
        // working multigraph: edges carry their partial Dcc trees
        let mut edges: Vec<(usize, usize, Dcc)> = self
            .edges
            .iter()
            .map(|(a, b, _)| (*a, *b, Dcc::queue()))
            .collect();

        loop {
            let mut changed = false;

            // ---- parallel reduction: group multi-edges --------------------
            let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
            for (i, (a, b, _)) in edges.iter().enumerate() {
                groups.entry((*a, *b)).or_default().push(i);
            }
            let mut to_merge: Vec<Vec<usize>> =
                groups.into_values().filter(|v| v.len() > 1).collect();
            if let Some(idxs) = to_merge.pop() {
                let (a, b, _) = edges[idxs[0]].clone();
                let children: Vec<Dcc> = idxs.iter().map(|&i| edges[i].2.clone()).collect();
                // remove merged edges (descending index order)
                let mut sorted = idxs.clone();
                sorted.sort_unstable_by(|x, y| y.cmp(x));
                for i in sorted {
                    edges.remove(i);
                }
                edges.push((a, b, Dcc::parallel(children)));
                changed = true;
            }

            // ---- series reduction: interior deg(1,1) DAP -------------------
            if !changed {
                let mut indeg: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                let mut outdeg: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                for (i, (a, b, _)) in edges.iter().enumerate() {
                    outdeg.entry(*a).or_default().push(i);
                    indeg.entry(*b).or_default().push(i);
                }
                let candidate = indeg.iter().find_map(|(v, ins)| {
                    if *v != source
                        && *v != sink
                        && ins.len() == 1
                        && outdeg.get(v).map(|o| o.len()) == Some(1)
                    {
                        Some((ins[0], outdeg[v][0]))
                    } else {
                        None
                    }
                });
                if let Some((e_in, e_out)) = candidate {
                    let (a, _, first) = edges[e_in].clone();
                    let (_, c, second) = edges[e_out].clone();
                    let merged = Dcc::serial(vec![first, second]);
                    let mut rm = [e_in, e_out];
                    rm.sort_unstable_by(|x, y| y.cmp(x));
                    for i in rm {
                        edges.remove(i);
                    }
                    edges.push((a, c, merged));
                    changed = true;
                }
            }

            if !changed {
                break;
            }
        }

        match edges.as_slice() {
            [(a, b, tree)] if *a == source && *b == sink => Ok(tree.clone()),
            _ => {
                // name an irreducible interior DAP for the error
                let stuck = edges
                    .iter()
                    .flat_map(|(a, b, _)| [*a, *b])
                    .find(|v| *v != source && *v != sink);
                Err(FlowError(format!(
                    "workflow DAG is not two-terminal series-parallel \
                     ({} irreducible stages{}); simulate it directly instead",
                    edges.len(),
                    stuck
                        .map(|v| format!(", e.g. around DAP {v}"))
                        .unwrap_or_default()
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::Workflow;

    #[test]
    fn diamond_is_parallel() {
        // 0 -> 1 (two stages), i.e. a 2-branch fork-join as multi-edges
        let dag = FlowDag::new().stage(0, 1, "a").stage(0, 1, "b");
        let tree = dag.to_series_parallel(0, 1).unwrap();
        assert_eq!(tree.slot_count(), 2);
        assert!(matches!(tree, Dcc::Parallel { .. }));
    }

    #[test]
    fn chain_is_serial() {
        let dag = FlowDag::new().stage(0, 1, "a").stage(1, 2, "b").stage(2, 3, "c");
        let tree = dag.to_series_parallel(0, 3).unwrap();
        assert_eq!(tree.slot_count(), 3);
        assert_eq!(tree.clone().canonicalize().serial_depth(), 3);
    }

    #[test]
    fn fig6_like_dag_recognized() {
        // 0 =2⇒ 1 → 2 → 3 =2⇒ 4  (fork; two serial stages; fork)
        let dag = FlowDag::new()
            .stage(0, 1, "map-a")
            .stage(0, 1, "map-b")
            .stage(1, 2, "s1")
            .stage(2, 3, "s2")
            .stage(3, 4, "red-a")
            .stage(3, 4, "red-b");
        let tree = dag.to_series_parallel(0, 4).unwrap();
        assert_eq!(tree.slot_count(), 6);
        let wf = Workflow::new(tree, 8.0).unwrap();
        assert_eq!(wf.serial_depth(), 4);
    }

    #[test]
    fn nested_sp_recognized() {
        // branch 1: 0->1->3 (series of 2); branch 2: 0->3 direct
        let dag = FlowDag::new()
            .stage(0, 1, "x")
            .stage(1, 3, "y")
            .stage(0, 3, "z");
        let tree = dag.to_series_parallel(0, 3).unwrap();
        assert_eq!(tree.slot_count(), 3);
        match tree {
            Dcc::Parallel { children, .. } => {
                assert_eq!(children.len(), 2);
                assert!(children.iter().any(|c| matches!(c, Dcc::Serial { .. })));
            }
            other => panic!("expected parallel, got {other:?}"),
        }
    }

    #[test]
    fn wheatstone_bridge_rejected() {
        // the canonical non-SP graph: 0->1, 0->2, 1->2 (bridge), 1->3, 2->3
        let dag = FlowDag::new()
            .stage(0, 1, "a")
            .stage(0, 2, "b")
            .stage(1, 2, "bridge")
            .stage(1, 3, "c")
            .stage(2, 3, "d");
        let err = dag.to_series_parallel(0, 3).unwrap_err();
        assert!(err.0.contains("not two-terminal series-parallel"), "{err}");
    }

    #[test]
    fn cycle_rejected() {
        let dag = FlowDag::new().stage(0, 1, "a").stage(1, 2, "b").stage(2, 0, "back");
        assert!(dag.validate(0, 2).is_err());
    }

    #[test]
    fn dangling_node_rejected() {
        let dag = FlowDag::new().stage(0, 1, "a").stage(2, 3, "island");
        assert!(dag.validate(0, 1).is_err());
    }

    #[test]
    fn self_loop_rejected() {
        let dag = FlowDag::new().stage(0, 0, "loop");
        assert!(dag.validate(0, 0).is_err());
    }

    #[test]
    fn empty_rejected() {
        assert!(FlowDag::new().validate(0, 0).is_err());
    }
}
