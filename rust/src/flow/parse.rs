//! JSON workflow specs.
//!
//! ```json
//! {
//!   "arrival_rate": 8.0,
//!   "root": {
//!     "type": "serial",
//!     "children": [
//!       {"type": "parallel", "rate": 8.0,
//!        "children": [{"type": "queue"}, {"type": "queue"}]},
//!       {"type": "queue", "rate": 4.0}
//!     ]
//!   }
//! }
//! ```
//!
//! `rate` on a child of a serial node is the DAP arrival rate feeding it
//! (paper: monitored per-DAP); on a child of a parallel node it is an
//! a-priori split rate (otherwise the rate scheduler decides).

use super::{Dcc, FlowError, Workflow};
use crate::util::json::Json;

/// Parse a workflow from JSON text.
pub fn workflow_from_json(text: &str) -> Result<Workflow, FlowError> {
    let v = Json::parse(text).map_err(|e| FlowError(format!("invalid json: {e}")))?;
    let rate = v
        .get("arrival_rate")
        .and_then(Json::as_f64)
        .ok_or_else(|| FlowError("missing numeric 'arrival_rate'".into()))?;
    let root_v = v
        .get("root")
        .ok_or_else(|| FlowError("missing 'root'".into()))?;
    let (root, _) = node_from_json(root_v)?;
    Workflow::new(root, rate)
}

/// Serialize a workflow back to JSON (round-trips through
/// [`workflow_from_json`] up to canonicalization).
pub fn workflow_to_json(wf: &Workflow) -> String {
    fn node(d: &Dcc, my_rate: Option<f64>) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        match d {
            Dcc::Queue { .. } => {
                obj.insert("type".into(), Json::Str("queue".into()));
            }
            Dcc::Serial { children, rates } | Dcc::Parallel { children, rates } => {
                let ty = if matches!(d, Dcc::Serial { .. }) {
                    "serial"
                } else {
                    "parallel"
                };
                obj.insert("type".into(), Json::Str(ty.into()));
                obj.insert(
                    "children".into(),
                    Json::Arr(
                        children
                            .iter()
                            .zip(rates)
                            .map(|(c, r)| node(c, *r))
                            .collect(),
                    ),
                );
            }
        }
        if let Some(r) = my_rate {
            obj.insert("rate".into(), Json::Num(r));
        }
        Json::Obj(obj)
    }
    let mut top = std::collections::BTreeMap::new();
    top.insert("arrival_rate".into(), Json::Num(wf.arrival_rate));
    top.insert("root".into(), node(wf.root(), None));
    Json::Obj(top).to_string()
}

fn node_from_json(v: &Json) -> Result<(Dcc, Option<f64>), FlowError> {
    let ty = v
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| FlowError("node missing 'type'".into()))?;
    let rate = v.get("rate").and_then(Json::as_f64);
    let dcc = match ty {
        "queue" => Dcc::queue(),
        "serial" | "parallel" => {
            let kids = v
                .get("children")
                .and_then(Json::as_arr)
                .ok_or_else(|| FlowError(format!("'{ty}' node missing 'children'")))?;
            let mut children = Vec::with_capacity(kids.len());
            let mut rates = Vec::with_capacity(kids.len());
            for k in kids {
                let (c, r) = node_from_json(k)?;
                children.push(c);
                rates.push(r);
            }
            if ty == "serial" {
                Dcc::serial_with_rates(children, rates)
            } else {
                Dcc::Parallel { children, rates }
            }
        }
        other => return Err(FlowError(format!("unknown node type '{other}'"))),
    };
    Ok((dcc, rate))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG6_JSON: &str = r#"{
        "arrival_rate": 8.0,
        "root": {
            "type": "serial",
            "children": [
                {"type": "parallel", "rate": 8.0,
                 "children": [{"type": "queue"}, {"type": "queue"}]},
                {"type": "serial", "rate": 4.0,
                 "children": [{"type": "queue"}, {"type": "queue"}]},
                {"type": "parallel", "rate": 2.0,
                 "children": [{"type": "queue"}, {"type": "queue"}]}
            ]
        }
    }"#;

    #[test]
    fn parses_fig6_spec() {
        let wf = workflow_from_json(FIG6_JSON).unwrap();
        assert_eq!(wf.slots(), 6);
        assert_eq!(wf.arrival_rate, 8.0);
        assert_eq!(wf.serial_depth(), 4);
    }

    #[test]
    fn json_roundtrip() {
        let wf = workflow_from_json(FIG6_JSON).unwrap();
        let text = workflow_to_json(&wf);
        let wf2 = workflow_from_json(&text).unwrap();
        assert_eq!(wf.slots(), wf2.slots());
        assert_eq!(wf.root(), wf2.root());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(workflow_from_json("{}").is_err());
        assert!(workflow_from_json(r#"{"arrival_rate": 1}"#).is_err());
        assert!(
            workflow_from_json(r#"{"arrival_rate": 1, "root": {"type": "nope"}}"#).is_err()
        );
        assert!(
            workflow_from_json(r#"{"arrival_rate": 1, "root": {"type": "serial"}}"#).is_err()
        );
    }

    #[test]
    fn rejects_invalid_json() {
        assert!(workflow_from_json("{not json").is_err());
    }
}
