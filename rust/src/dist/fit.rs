//! Parametric re-fitting of Table-1 families from observed service
//! times — the estimation half of the paper's Algorithm 3 ("the
//! performance distribution of each server … is gradually updated over
//! the time").
//!
//! * [`fit_delayed_exponential`] / [`fit_delayed_pareto`] — moment / MLE
//!   fits of the single-mode families;
//! * [`fit_multimodal_exp`] — 2-component EM for straggling servers
//!   (returns the estimated straggler fraction);
//! * [`select_family`] — fits every candidate family and picks by
//!   one-sample Kolmogorov–Smirnov distance with a parsimony ladder
//!   (simpler families win unless a richer one is clearly better).

use crate::dist::ServiceDist;

/// Table-1 family identifiers for fitted laws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Single delayed-exponential mode.
    DelayedExp,
    /// Single delayed-pareto (power-tail) mode.
    DelayedPareto,
    /// Two-mode delayed-exponential mixture (straggling server).
    MultiModalExp,
}

fn shift_origin(samples: &[f64]) -> f64 {
    samples.iter().copied().fold(f64::INFINITY, f64::min).max(0.0)
}

/// Fit a delayed exponential: delay = smallest sample, tail rate from
/// the mean excess (`lam = 1 / (mean - delay)` — the MLE for this
/// family). Always reproduces the sample mean exactly.
pub fn fit_delayed_exponential(samples: &[f64]) -> ServiceDist {
    assert!(!samples.is_empty(), "fit needs samples");
    let t0 = shift_origin(samples);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let lam = 1.0 / (mean - t0).max(1e-9);
    ServiceDist::delayed_exponential(lam, t0)
}

/// Fit a delayed pareto: delay = smallest sample, tail exponent by MLE
/// on the log tail clock (`lam = n / Σ ln((1+x)/(1+T))`).
pub fn fit_delayed_pareto(samples: &[f64]) -> ServiceDist {
    assert!(!samples.is_empty(), "fit needs samples");
    let t0 = shift_origin(samples);
    let s: f64 = samples
        .iter()
        .map(|&x| ((1.0 + x.max(t0)) / (1.0 + t0)).ln())
        .sum();
    let lam = (samples.len() as f64 / s.max(1e-12)).clamp(1.0 + 1e-6, 1e9);
    ServiceDist::delayed_pareto(lam, t0)
}

/// Fit a 2-component delayed-exponential mixture by EM (`iters`
/// iterations). Returns the fitted law and the estimated *straggler
/// fraction* — the weight of the slower mode.
pub fn fit_multimodal_exp(samples: &[f64], iters: usize) -> (ServiceDist, f64) {
    assert!(!samples.is_empty(), "fit needs samples");
    let t0 = shift_origin(samples);
    let shifted: Vec<f64> = samples.iter().map(|&x| (x - t0).max(0.0)).collect();
    let n = shifted.len();

    // init: body rate from the lower 90%, straggler rate from the top 5%
    let mut sorted = shifted.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
    let lo_end = ((n as f64 * 0.9) as usize).clamp(1, n);
    let hi_start = ((n as f64 * 0.95) as usize).min(n - 1);
    let mean_of = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let mut lam_fast = 1.0 / mean_of(&sorted[..lo_end]).max(1e-9);
    let mut lam_slow = 1.0 / mean_of(&sorted[hi_start..]).max(1e-9);
    if lam_slow >= lam_fast {
        lam_slow = lam_fast * 0.25; // degenerate init: force separation
    }
    let mut w_slow = 0.05f64;

    for _ in 0..iters.max(1) {
        let (mut r_slow, mut rx_slow, mut r_fast, mut rx_fast) = (0.0, 0.0, 0.0, 0.0);
        for &x in &shifted {
            let pf = (1.0 - w_slow) * lam_fast * (-lam_fast * x).exp();
            let ps = w_slow * lam_slow * (-lam_slow * x).exp();
            let denom = pf + ps;
            let rs = if denom > 1e-300 {
                ps / denom
            } else if lam_slow < lam_fast {
                1.0 // both densities underflow: the heavier tail owns it
            } else {
                0.0
            };
            r_slow += rs;
            rx_slow += rs * x;
            r_fast += 1.0 - rs;
            rx_fast += (1.0 - rs) * x;
        }
        w_slow = (r_slow / n as f64).clamp(1e-6, 1.0 - 1e-6);
        lam_fast = (r_fast / rx_fast.max(1e-300)).clamp(1e-9, 1e12);
        lam_slow = (r_slow / rx_slow.max(1e-300)).clamp(1e-9, 1e12);
    }
    if lam_fast < lam_slow {
        std::mem::swap(&mut lam_fast, &mut lam_slow);
        w_slow = 1.0 - w_slow;
    }

    use crate::dist::{Mode, TailKind};
    let dist = ServiceDist::multimodal(vec![
        (
            1.0 - w_slow,
            Mode::continuous(lam_fast, t0, TailKind::Exponential),
        ),
        (
            w_slow,
            Mode::continuous(lam_slow, t0, TailKind::Exponential),
        ),
    ]);
    (dist, w_slow)
}

/// One-sample Kolmogorov–Smirnov distance between *sorted* samples and
/// a candidate law.
pub fn ks_fit(sorted: &[f64], d: &ServiceDist) -> f64 {
    let n = sorted.len() as f64;
    sorted
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            let f = d.cdf(x);
            let hi = (i as f64 + 1.0) / n;
            let lo = i as f64 / n;
            (f - lo).abs().max((hi - f).abs())
        })
        .fold(0.0, f64::max)
}

/// Fit every candidate family and select by KS distance with a
/// parsimony ladder: the delayed exponential wins unless a richer
/// family is clearly (25% + 0.005 absolute) better; the delayed pareto
/// wins over the mixture on the same rule. Returns `(family, fitted
/// law, its KS distance)`.
pub fn select_family(samples: &[f64]) -> (Family, ServiceDist, f64) {
    assert!(!samples.is_empty(), "fit needs samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));

    let de = fit_delayed_exponential(samples);
    let dp = fit_delayed_pareto(samples);
    let (mm, _) = fit_multimodal_exp(samples, 60);
    let k_de = ks_fit(&sorted, &de);
    let k_dp = ks_fit(&sorted, &dp);
    let k_mm = ks_fit(&sorted, &mm);
    let best = k_de.min(k_dp).min(k_mm);

    if k_de <= best * 1.25 + 0.005 {
        (Family::DelayedExp, de, k_de)
    } else if k_dp <= best * 1.10 + 0.002 {
        (Family::DelayedPareto, dp, k_dp)
    } else {
        (Family::MultiModalExp, mm, k_mm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn draw(d: &ServiceDist, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).collect()
    }

    #[test]
    fn delayed_exponential_recovered() {
        let truth = ServiceDist::delayed_exponential(5.0, 0.2);
        let xs = draw(&truth, 4096, 1);
        let fitted = fit_delayed_exponential(&xs);
        assert!((fitted.mean() - truth.mean()).abs() < 0.02 * truth.mean());
        assert!((fitted.min_time() - 0.2).abs() < 0.01);
        let mut sorted = xs;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(ks_fit(&sorted, &fitted) < 0.04);
    }

    #[test]
    fn plain_exponential_selects_simple_family() {
        let truth = ServiceDist::exponential(4.0);
        let xs = draw(&truth, 4096, 2);
        let (family, fitted, ks) = select_family(&xs);
        assert_eq!(family, Family::DelayedExp, "ks={ks}");
        assert!(ks < 0.05, "ks {ks}");
        assert!((fitted.mean() - 0.25).abs() < 0.02);
    }

    #[test]
    fn straggler_selects_multimodal_and_recovers_fraction() {
        let truth = ServiceDist::straggler(10.0, 0.4, 0.08, 0.0);
        let xs = draw(&truth, 6000, 3);
        let (family, fitted, ks) = select_family(&xs);
        assert_eq!(family, Family::MultiModalExp, "ks={ks}");
        assert!(ks < 0.05, "ks {ks}");
        assert!((fitted.mean() - truth.mean()).abs() < 0.05 * truth.mean());
        let (_, frac) = fit_multimodal_exp(&xs, 100);
        assert!((frac - 0.08).abs() < 0.04, "straggler fraction {frac}");
    }

    #[test]
    fn heavy_tail_rejects_single_exponential() {
        let truth = ServiceDist::delayed_pareto(2.5, 0.0);
        let xs = draw(&truth, 5000, 4);
        let (family, _, ks) = select_family(&xs);
        assert_ne!(family, Family::DelayedExp, "ks={ks}");
        assert!(ks < 0.06, "ks {ks}");
    }

    #[test]
    fn em_handles_degenerate_single_mode_data() {
        // all-identical samples must not NaN/panic
        let xs = vec![0.5; 256];
        let (d, frac) = fit_multimodal_exp(&xs, 20);
        assert!(d.mean().is_finite());
        assert!((0.0..=1.0).contains(&frac));
    }
}
