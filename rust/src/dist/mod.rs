//! Table-1 service-time distribution families.
//!
//! The paper models every server's service time with a *delayed-tail*
//! law. All families share the survival shape
//!
//! ```text
//! S(t) = min(1, alpha * exp(-lam * (m(t) - T)))   for t >= T,   S(t) = 1 below T
//! ```
//!
//! with a monotone "tail clock" `m(t)` selecting the family:
//!
//! * delayed exponential — `m(t) = t`;
//! * delayed pareto      — `m(t) = ln(1 + t)` (power-law tail);
//! * delayed weibull     — `m(t) = t^k` (our generic-`m` instance).
//!
//! `alpha` controls the atom at the delay `T`: the mass `1 - S(T+)`
//! sits exactly at `T`. [`Mode::continuous`] picks the atomless choice
//! `alpha = exp(lam * (m(T) - T))` so `S(T+) = 1`. Multi-modal variants
//! are convex mixtures of modes (the straggler laws of the paper's
//! Table 1 and of [6, 7]).
//!
//! This is the production twin of
//! `python/compile/distributions.py` — identical parameterization and
//! grid conventions (central-difference PDFs of the analytic CDF), so
//! the AOT oracles and the native engine line up in method.

pub mod empirical;
pub mod fit;

use crate::util::rng::Rng;

/// Tail-clock family of one mode (Table 1 row kind).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TailKind {
    /// `m(t) = t`: exponential tail.
    Exponential,
    /// `m(t) = ln(1 + t)`: pareto (power-law) tail.
    Pareto,
    /// `m(t) = t^k`: weibull tail with shape `k`.
    Weibull {
        /// Weibull shape parameter (k > 0).
        k: f64,
    },
}

/// One delayed-tail mode: `S(t) = min(1, alpha * exp(-lam * (m(t) - T)))`
/// beyond the deterministic delay `T`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mode {
    /// Tail rate `lam > 0`.
    pub lam: f64,
    /// Deterministic delay `T >= 0` (minimum service time).
    pub delay: f64,
    /// Atom control: `1 - alpha * exp(-lam*(m(T)-T))` is the probability
    /// mass sitting exactly at `T`. [`Mode::continuous`] makes it 0.
    pub alpha: f64,
    /// Tail clock family.
    pub kind: TailKind,
}

impl Mode {
    /// Atomless mode: `alpha` chosen so `S(T+) = 1` (no mass at the
    /// delay). This is the parameterization every Table-1 constructor
    /// on [`ServiceDist`] uses; for [`TailKind::Exponential`] it yields
    /// `alpha = 1` exactly.
    pub fn continuous(lam: f64, delay: f64, kind: TailKind) -> Mode {
        assert!(lam > 0.0, "mode needs a positive tail rate, got {lam}");
        assert!(delay >= 0.0, "mode needs a non-negative delay, got {delay}");
        let m_t = clock(kind, delay);
        Mode {
            lam,
            delay,
            alpha: (lam * (m_t - delay)).exp(),
            kind,
        }
    }

    /// Mode with an explicit `alpha` (an atom of mass `1 - S(T+)` at the
    /// delay when `alpha` is below the continuous choice).
    pub fn with_atom(lam: f64, delay: f64, kind: TailKind, alpha: f64) -> Mode {
        assert!(lam > 0.0, "mode needs a positive tail rate, got {lam}");
        assert!(delay >= 0.0, "mode needs a non-negative delay, got {delay}");
        assert!(alpha >= 0.0, "alpha must be non-negative, got {alpha}");
        Mode {
            lam,
            delay,
            alpha,
            kind,
        }
    }

    /// Survival function `P(X > t)`.
    pub fn sf(&self, t: f64) -> f64 {
        if t < self.delay {
            return 1.0;
        }
        let e = self.alpha * (-self.lam * (clock(self.kind, t) - self.delay)).exp();
        e.clamp(0.0, 1.0)
    }

    /// CDF `P(X <= t)`.
    pub fn cdf(&self, t: f64) -> f64 {
        1.0 - self.sf(t)
    }

    /// Survival just past the delay (`1 -` the atom mass at `T`).
    fn s0(&self) -> f64 {
        let m_t = clock(self.kind, self.delay);
        (self.alpha * (-self.lam * (m_t - self.delay)).exp()).clamp(0.0, 1.0)
    }

    /// Mean `E[X] = T + ∫_T^∞ S(t) dt` (infinite for pareto tails with
    /// `lam <= 1`).
    pub fn mean(&self) -> f64 {
        let s0 = self.s0();
        let tail = match self.kind {
            TailKind::Exponential => s0 / self.lam,
            TailKind::Pareto => {
                if self.lam <= 1.0 {
                    return f64::INFINITY;
                }
                s0 * (1.0 + self.delay) / (self.lam - 1.0)
            }
            TailKind::Weibull { .. } => self.integrate_tail(|_, s| s),
        };
        self.delay + tail
    }

    /// Second moment `E[X^2] = T^2 + 2 ∫_T^∞ t·S(t) dt`.
    pub fn second_moment(&self) -> f64 {
        let s0 = self.s0();
        let t0 = self.delay;
        let tail = match self.kind {
            TailKind::Exponential => s0 * (t0 / self.lam + 1.0 / (self.lam * self.lam)),
            TailKind::Pareto => {
                if self.lam <= 2.0 {
                    return f64::INFINITY;
                }
                let b = 1.0 + t0;
                s0 * (b * b / (self.lam - 2.0) - b / (self.lam - 1.0))
            }
            TailKind::Weibull { .. } => self.integrate_tail(|t, s| t * s),
        };
        t0 * t0 + 2.0 * tail
    }

    /// Simpson integration of `f(t, S(t))` over the tail support (used
    /// by the clock families without closed-form moments).
    fn integrate_tail(&self, f: impl Fn(f64, f64) -> f64) -> f64 {
        let hi = self.tail_horizon();
        let lo = self.delay;
        if hi <= lo {
            return 0.0;
        }
        let n = 4096usize; // even
        let h = (hi - lo) / n as f64;
        let eval = |k: usize| {
            let t = lo + k as f64 * h;
            f(t, self.sf(t))
        };
        let mut acc = eval(0) + eval(n);
        for k in 1..n {
            acc += eval(k) * if k % 2 == 1 { 4.0 } else { 2.0 };
        }
        acc * h / 3.0
    }

    /// Time beyond which `S(t)` is negligible (`< ~1e-15`, tail-clock
    /// inverted).
    fn tail_horizon(&self) -> f64 {
        let m_end = clock(self.kind, self.delay) + (self.alpha.ln().max(0.0) + 36.0) / self.lam;
        clock_inv(self.kind, m_end)
    }

    /// Draw one service time.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let s0 = self.s0();
        if rng.f64() >= s0 {
            return self.delay; // the atom at T
        }
        // conditional tail: S(t)/S(T+) = exp(-lam * (m(t) - m(T)))
        let e = -rng.f64_open().ln();
        let m_t = clock(self.kind, self.delay) + e / self.lam;
        clock_inv(self.kind, m_t)
    }
}

/// The tail clock `m(t)` of a family.
fn clock(kind: TailKind, t: f64) -> f64 {
    match kind {
        TailKind::Exponential => t,
        TailKind::Pareto => t.max(0.0).ln_1p(),
        TailKind::Weibull { k } => t.max(0.0).powf(k),
    }
}

/// Inverse tail clock `m^{-1}(x)`.
fn clock_inv(kind: TailKind, x: f64) -> f64 {
    match kind {
        TailKind::Exponential => x,
        TailKind::Pareto => x.exp() - 1.0,
        TailKind::Weibull { k } => x.max(0.0).powf(1.0 / k),
    }
}

/// A service-time law: a convex mixture of delayed-tail [`Mode`]s
/// (single-mode for the plain Table-1 rows).
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceDist {
    modes: Vec<(f64, Mode)>,
}

impl ServiceDist {
    /// Plain exponential with rate `mu` (delayed exponential, `T = 0`).
    pub fn exponential(mu: f64) -> ServiceDist {
        ServiceDist::delayed_exponential(mu, 0.0)
    }

    /// Delayed exponential: deterministic `delay` plus an `Exp(lam)`
    /// tail. Mean `delay + 1/lam`.
    pub fn delayed_exponential(lam: f64, delay: f64) -> ServiceDist {
        ServiceDist {
            modes: vec![(1.0, Mode::continuous(lam, delay, TailKind::Exponential))],
        }
    }

    /// Delayed pareto: power-law tail `S(t) ∝ (1+t)^-lam` beyond the
    /// delay. Mean `delay + (1+delay)/(lam-1)` for `lam > 1`; variance
    /// finite only for `lam > 2`.
    pub fn delayed_pareto(lam: f64, delay: f64) -> ServiceDist {
        ServiceDist {
            modes: vec![(1.0, Mode::continuous(lam, delay, TailKind::Pareto))],
        }
    }

    /// Delayed weibull with shape `k`: `S(t) = exp(-lam (t^k - T^k))`
    /// beyond the delay.
    pub fn delayed_weibull(lam: f64, k: f64, delay: f64) -> ServiceDist {
        assert!(k > 0.0, "weibull shape must be positive, got {k}");
        ServiceDist {
            modes: vec![(1.0, Mode::continuous(lam, delay, TailKind::Weibull { k }))],
        }
    }

    /// Straggler mixture (the "100x degradation" shape of the straggler
    /// literature the paper cites): with probability `1 - p_slow` an
    /// `Exp(fast)` draw, with probability `p_slow` an `Exp(slow)` draw,
    /// both delayed by `delay`.
    pub fn straggler(fast: f64, slow: f64, p_slow: f64, delay: f64) -> ServiceDist {
        assert!(
            (0.0..=1.0).contains(&p_slow),
            "straggler fraction must be in [0,1], got {p_slow}"
        );
        ServiceDist::multimodal(vec![
            (
                1.0 - p_slow,
                Mode::continuous(fast, delay, TailKind::Exponential),
            ),
            (
                p_slow,
                Mode::continuous(slow, delay, TailKind::Exponential),
            ),
        ])
    }

    /// General convex mixture of modes. Weights must be non-negative and
    /// sum to 1 (within 1e-6).
    pub fn multimodal(modes: Vec<(f64, Mode)>) -> ServiceDist {
        assert!(!modes.is_empty(), "mixture needs at least one mode");
        let total: f64 = modes.iter().map(|(w, _)| *w).sum();
        assert!(
            (total - 1.0).abs() < 1e-6,
            "mixture weights must sum to 1, got {total}"
        );
        assert!(
            modes.iter().all(|(w, _)| *w >= 0.0),
            "mixture weights must be non-negative"
        );
        ServiceDist { modes }
    }

    /// The weighted modes of the mixture.
    pub fn modes(&self) -> &[(f64, Mode)] {
        &self.modes
    }

    /// Mean service time.
    pub fn mean(&self) -> f64 {
        self.modes.iter().map(|(w, m)| w * m.mean()).sum()
    }

    /// Variance of the service time (infinite for pareto `lam <= 2`).
    pub fn variance(&self) -> f64 {
        let e2: f64 = self.modes.iter().map(|(w, m)| w * m.second_moment()).sum();
        if !e2.is_finite() {
            return f64::INFINITY;
        }
        let mean = self.mean();
        (e2 - mean * mean).max(0.0)
    }

    /// Nominal service rate `1 / mean`.
    pub fn rate(&self) -> f64 {
        1.0 / self.mean()
    }

    /// Minimum possible service time (the smallest mode delay).
    pub fn min_time(&self) -> f64 {
        self.modes
            .iter()
            .filter(|(w, _)| *w > 0.0)
            .map(|(_, m)| m.delay)
            .fold(f64::INFINITY, f64::min)
    }

    /// CDF `P(X <= t)`.
    pub fn cdf(&self, t: f64) -> f64 {
        self.modes.iter().map(|(w, m)| w * m.cdf(t)).sum()
    }

    /// Smallest `t` with `cdf(t) >= p` (bisection; exact up to ~1e-12
    /// relative).
    pub fn quantile(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 1.0 - 1e-12);
        let mut lo = self.min_time();
        if self.cdf(lo) >= p {
            return lo;
        }
        let mut hi = if lo > 0.0 { 2.0 * lo } else { 1.0 };
        let mut grow = 0;
        while self.cdf(hi) < p && grow < 400 {
            hi = hi * 2.0 + 1.0;
            grow += 1;
        }
        for _ in 0..120 {
            let mid = 0.5 * (lo + hi);
            if self.cdf(mid) >= p {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }

    /// Draw one service time.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.f64();
        let mut acc = 0.0;
        for (w, m) in &self.modes {
            acc += w;
            if u < acc {
                return m.sample(rng);
            }
        }
        // weights sum to 1; guard against the last ulp
        self.modes.last().expect("non-empty mixture").1.sample(rng)
    }

    /// CDF evaluated on the uniform grid `t_k = k * dt`, `k = 0..n`.
    pub fn cdf_grid(&self, dt: f64, n: usize) -> Vec<f64> {
        assert!(dt > 0.0 && n >= 2, "grid needs dt>0 and n>=2");
        (0..n).map(|k| self.cdf(k as f64 * dt)).collect()
    }

    /// [`ServiceDist::cdf_grid`] into a caller buffer (`out.len()` is the
    /// grid size) — the same evaluations, bit-identical, no allocation.
    pub fn cdf_grid_into(&self, dt: f64, out: &mut [f64]) {
        assert!(dt > 0.0 && out.len() >= 2, "grid needs dt>0 and n>=2");
        for (k, o) in out.iter_mut().enumerate() {
            *o = self.cdf(k as f64 * dt);
        }
    }

    /// PDF on the uniform grid by central differences of the analytic
    /// CDF — the exact convention of the AOT kernels and
    /// `python/compile/distributions.py::pdf_grid`, so both engines see
    /// the same discretization of delays and atoms.
    pub fn pdf_grid(&self, dt: f64, n: usize) -> Vec<f64> {
        central_diff(&self.cdf_grid(dt, n), dt)
    }
}

/// Central-difference PDF of a CDF grid (forward/backward differences
/// at the endpoints) — the shared convention of the native engine, the
/// AOT kernels, and the python oracles.
pub fn central_diff(cdf: &[f64], dt: f64) -> Vec<f64> {
    assert!(cdf.len() >= 2, "central_diff needs at least 2 points");
    assert!(dt > 0.0, "central_diff needs dt > 0");
    let n = cdf.len();
    let mut out = vec![0.0; n];
    out[0] = (cdf[1] - cdf[0]) / dt;
    for (k, w) in cdf.windows(3).enumerate() {
        out[k + 1] = (w[2] - w[0]) / (2.0 * dt);
    }
    out[n - 1] = (cdf[n - 1] - cdf[n - 2]) / dt;
    out
}

/// [`central_diff`] into a caller buffer of the same length — the same
/// stencils in the same order, bit-identical, no allocation.
pub fn central_diff_into(cdf: &[f64], dt: f64, out: &mut [f64]) {
    assert!(cdf.len() >= 2, "central_diff needs at least 2 points");
    assert!(dt > 0.0, "central_diff needs dt > 0");
    let n = cdf.len();
    assert_eq!(out.len(), n, "output grid must match");
    out[0] = (cdf[1] - cdf[0]) / dt;
    for (k, w) in cdf.windows(3).enumerate() {
        out[k + 1] = (w[2] - w[0]) / (2.0 * dt);
    }
    out[n - 1] = (cdf[n - 1] - cdf[n - 2]) / dt;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_moments_exact() {
        let d = ServiceDist::exponential(4.0);
        assert!((d.mean() - 0.25).abs() < 1e-12);
        assert!((d.variance() - 0.0625).abs() < 1e-12);
        assert!((d.rate() - 4.0).abs() < 1e-9);
        assert_eq!(d.min_time(), 0.0);
    }

    #[test]
    fn delayed_exponential_moments() {
        // mean = T + 1/lam, var = 1/lam^2
        let d = ServiceDist::delayed_exponential(50.0, 0.18);
        assert!((d.mean() - 0.2).abs() < 1e-12, "mean {}", d.mean());
        assert!((d.variance() - 1.0 / 2500.0).abs() < 1e-12);
        assert!((d.min_time() - 0.18).abs() < 1e-15);
    }

    #[test]
    fn delayed_pareto_moments() {
        // mean = T + (1+T)/(lam-1); E[X^2] = T^2 + 2[(1+T)^2/(lam-2) - (1+T)/(lam-1)]
        let d = ServiceDist::delayed_pareto(4.0, 0.3);
        let want_mean = 0.3 + 1.3 / 3.0;
        assert!((d.mean() - want_mean).abs() < 1e-12, "mean {}", d.mean());
        let e2 = 0.09 + 2.0 * (1.3 * 1.3 / 2.0 - 1.3 / 3.0);
        let want_var = e2 - want_mean * want_mean;
        assert!(
            (d.variance() - want_var).abs() < 1e-12,
            "var {} want {want_var}",
            d.variance()
        );
    }

    #[test]
    fn pareto_heavy_tail_infinite_moments() {
        assert!(ServiceDist::delayed_pareto(0.9, 0.0).mean().is_infinite());
        let v = ServiceDist::delayed_pareto(1.5, 0.0).variance();
        assert!(v.is_infinite());
        // lam just above 2: finite but large
        assert!(ServiceDist::delayed_pareto(2.1, 0.0).variance().is_finite());
    }

    #[test]
    fn weibull_numeric_moments_match_closed_form() {
        // k=1 weibull IS the exponential: numeric path must agree
        let w = ServiceDist::delayed_weibull(3.0, 1.0, 0.0);
        assert!((w.mean() - 1.0 / 3.0).abs() < 1e-6, "mean {}", w.mean());
        assert!((w.variance() - 1.0 / 9.0).abs() < 1e-5, "var {}", w.variance());
        // k=2, lam=1: Rayleigh-type, mean = Gamma(1.5) = sqrt(pi)/2
        let r = ServiceDist::delayed_weibull(1.0, 2.0, 0.0);
        let want = std::f64::consts::PI.sqrt() / 2.0;
        assert!((r.mean() - want).abs() < 1e-6, "mean {}", r.mean());
    }

    #[test]
    fn straggler_mixture_moments() {
        let d = ServiceDist::straggler(10.0, 0.4, 0.08, 0.01);
        let want = 0.01 + 0.92 / 10.0 + 0.08 / 0.4;
        assert!((d.mean() - want).abs() < 1e-12, "mean {}", d.mean());
        assert_eq!(d.modes().len(), 2);
        // straggling inflates variance far beyond the fast mode's
        assert!(d.variance() > ServiceDist::exponential(10.0).variance() * 5.0);
    }

    #[test]
    fn cdf_matches_closed_forms() {
        let e = ServiceDist::exponential(2.0);
        for t in [0.0, 0.1, 0.5, 2.0] {
            assert!((e.cdf(t) - (1.0 - (-2.0f64 * t).exp())).abs() < 1e-12);
        }
        let p = ServiceDist::delayed_pareto(3.0, 0.5);
        assert_eq!(p.cdf(0.49), 0.0);
        // S(t) = ((1+T)/(1+t))^lam beyond T
        let want = 1.0 - (1.5f64 / 2.0).powi(3);
        assert!((p.cdf(1.0) - want).abs() < 1e-12, "cdf {}", p.cdf(1.0));
    }

    #[test]
    fn quantile_inverts_cdf() {
        let d = ServiceDist::delayed_exponential(2.0, 0.3);
        for p in [0.1, 0.5, 0.9, 0.99, 0.9999] {
            let q = d.quantile(p);
            assert!((d.cdf(q) - p).abs() < 1e-9, "p={p} q={q}");
            // closed form: T - ln(1-p)/lam
            let want = 0.3 - (1.0 - p).ln() / 2.0;
            assert!((q - want).abs() < 1e-7, "p={p}: {q} vs {want}");
        }
        // below the delay nothing has happened yet
        assert!((d.quantile(0.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn atom_mass_shows_in_cdf_and_sampling() {
        // alpha = 0.6 => 40% of the mass sits exactly at T = 1
        let m = Mode::with_atom(2.0, 1.0, TailKind::Exponential, 0.6);
        let d = ServiceDist::multimodal(vec![(1.0, m)]);
        assert!((d.cdf(1.0) - 0.4).abs() < 1e-12);
        assert_eq!(d.cdf(0.999), 0.0);
        let mut rng = Rng::new(5);
        let n = 20_000;
        let hits = (0..n)
            .map(|_| d.sample(&mut rng))
            .filter(|&x| x == 1.0)
            .count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.4).abs() < 0.02, "atom fraction {frac}");
        // mean = T + alpha/lam
        assert!((d.mean() - (1.0 + 0.3)).abs() < 1e-12);
    }

    #[test]
    fn sampling_matches_moments() {
        let mut rng = Rng::new(11);
        // (law, check_variance): the pareto draw has an infinite 4th
        // moment, so its sample variance fluctuates too much to assert
        let cases = [
            (ServiceDist::exponential(3.0), true),
            (ServiceDist::delayed_exponential(5.0, 0.2), true),
            (ServiceDist::delayed_pareto(4.0, 0.1), false),
            (ServiceDist::straggler(8.0, 0.5, 0.1, 0.0), true),
        ];
        for (d, check_var) in cases {
            let n = 200_000;
            let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            assert!(
                (mean - d.mean()).abs() < 0.02 * d.mean().max(0.1),
                "sample mean {mean} vs analytic {}",
                d.mean()
            );
            if check_var {
                let var =
                    xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
                assert!(
                    (var - d.variance()).abs() < 0.12 * d.variance().max(0.1),
                    "sample var {var} vs analytic {}",
                    d.variance()
                );
            }
        }
    }

    #[test]
    fn grids_follow_the_python_conventions() {
        let d = ServiceDist::exponential(2.0);
        let (dt, n) = (0.01, 1024);
        let cdf = d.cdf_grid(dt, n);
        assert_eq!(cdf.len(), n);
        assert_eq!(cdf[0], 0.0);
        let pdf = d.pdf_grid(dt, n);
        assert_eq!(pdf.len(), n);
        // central difference of the interior: (F(t+dt)-F(t-dt))/(2dt)
        let k = 100;
        let want = (d.cdf((k + 1) as f64 * dt) - d.cdf((k - 1) as f64 * dt)) / (2.0 * dt);
        assert!((pdf[k] - want).abs() < 1e-12);
        // mass on the grid integrates to ~1
        let mass: f64 = pdf.iter().sum::<f64>() * dt;
        assert!((mass - 1.0).abs() < 0.01, "mass {mass}");
    }

    #[test]
    fn central_diff_endpoints() {
        let c = [0.0, 0.1, 0.4, 0.8, 1.0];
        let p = central_diff(&c, 0.5);
        assert!((p[0] - 0.2).abs() < 1e-12);
        assert!((p[1] - 0.4).abs() < 1e-12);
        assert!((p[4] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn into_variants_are_bit_identical() {
        let d = ServiceDist::delayed_exponential(1.5, 0.25);
        let (n, dt) = (96, 0.05);
        let cdf = d.cdf_grid(dt, n);
        let mut cdf_into = vec![f64::NAN; n];
        d.cdf_grid_into(dt, &mut cdf_into);
        for (x, y) in cdf_into.iter().zip(cdf.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let pdf = central_diff(&cdf, dt);
        let mut pdf_into = vec![f64::NAN; n];
        central_diff_into(&cdf, dt, &mut pdf_into);
        for (x, y) in pdf_into.iter().zip(pdf.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "weights must sum to 1")]
    fn bad_mixture_weights_rejected() {
        ServiceDist::multimodal(vec![(
            0.5,
            Mode::continuous(1.0, 0.0, TailKind::Exponential),
        )]);
    }
}
