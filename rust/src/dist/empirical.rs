//! Non-parametric (empirical) service-time estimates — the monitor's
//! raw view of a server before a Table-1 family is fitted.

/// Empirical distribution over a finite sample set (sorted internally).
#[derive(Clone, Debug, PartialEq)]
pub struct Empirical {
    sorted: Vec<f64>,
}

impl Empirical {
    /// Build from observed samples (any order; NaNs rejected).
    pub fn from_samples(samples: &[f64]) -> Empirical {
        assert!(!samples.is_empty(), "empirical law needs samples");
        assert!(
            samples.iter().all(|x| x.is_finite()),
            "empirical law needs finite samples"
        );
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
        Empirical { sorted }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Always false (construction requires samples); included for
    /// clippy's `len_without_is_empty` convention.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.len() as f64
    }

    /// Biased (1/n) sample variance.
    pub fn variance(&self) -> f64 {
        let m = self.mean();
        self.sorted.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.len() as f64
    }

    /// Smallest observed sample.
    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    /// Largest observed sample.
    pub fn max(&self) -> f64 {
        *self.sorted.last().expect("non-empty")
    }

    /// Empirical CDF: fraction of samples `<= t`.
    pub fn cdf(&self, t: f64) -> f64 {
        // first index with sample > t
        let idx = self.sorted.partition_point(|&x| x <= t);
        idx as f64 / self.len() as f64
    }

    /// Order-statistic quantile (nearest-rank).
    pub fn quantile(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 1.0);
        let idx = ((p * self.len() as f64).ceil() as usize)
            .saturating_sub(1)
            .min(self.len() - 1);
        self.sorted[idx]
    }

    /// The sorted sample view (ascending).
    pub fn sorted(&self) -> &[f64] {
        &self.sorted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_statistics() {
        let e = Empirical::from_samples(&[3.0, 1.0, 2.0, 4.0]);
        assert_eq!(e.len(), 4);
        assert!(!e.is_empty());
        assert_eq!(e.min(), 1.0);
        assert_eq!(e.max(), 4.0);
        assert!((e.mean() - 2.5).abs() < 1e-12);
        assert!((e.variance() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn cdf_and_quantile_are_consistent() {
        let e = Empirical::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(e.cdf(0.5), 0.0);
        assert_eq!(e.cdf(2.0), 0.5);
        assert_eq!(e.cdf(10.0), 1.0);
        assert_eq!(e.quantile(0.5), 2.0);
        assert_eq!(e.quantile(1.0), 4.0);
        assert_eq!(e.quantile(0.0), 1.0);
    }
}
