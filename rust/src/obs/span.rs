//! Hierarchical RAII spans on one process-wide monotonic clock.
//!
//! A [`Span`] measures a lexical scope: it opens at construction,
//! closes (and records an [`super::Event::Span`] into the sink) on
//! drop. Parentage is tracked per thread through a thread-local
//! "current span" cell, so nested guards link up automatically;
//! [`span_under`] pins an explicit parent instead, which is how chunk
//! spans executing on fabric worker threads attach to the wave span
//! that dispatched them.
//!
//! When capture is disabled the constructors return an inert guard
//! after a single relaxed atomic load — no ids are burned, no clock is
//! read, nothing allocates.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use super::{enabled, record, AttrValue, Event};

/// Identifier of a span: nonzero and unique within the process.
pub type SpanId = u64;

/// Span ids start at 1; 0 is reserved as "no span" in thread-locals.
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

/// Dense thread ids of our own (std's `ThreadId` has no stable u64
/// accessor), assigned at each thread's first telemetry touch.
static NEXT_TID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Innermost open span on this thread (0 = none).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
    /// This thread's dense telemetry id (u64::MAX = unassigned).
    static TID: Cell<u64> = const { Cell::new(u64::MAX) };
}

/// The process trace epoch: every timestamp in the sink is
/// microseconds since the first clock read, on one monotonic clock, so
/// child windows always nest inside parent windows.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (monotonic).
pub(super) fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// This thread's dense telemetry id.
pub(super) fn tid() -> u64 {
    TID.with(|t| {
        let cur = t.get();
        if cur != u64::MAX {
            return cur;
        }
        let fresh = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(fresh);
        fresh
    })
}

/// Id of the innermost span currently open on this thread, if any.
/// Useful for handing a parent across threads (see [`span_under`]).
pub fn current_span() -> Option<SpanId> {
    let cur = CURRENT.with(Cell::get);
    if cur == 0 {
        None
    } else {
        Some(cur)
    }
}

/// Live state of a recording span (absent on the disabled path).
#[derive(Debug)]
struct SpanData {
    id: SpanId,
    parent: Option<SpanId>,
    /// Thread-local `CURRENT` value to restore on close.
    prev: u64,
    name: &'static str,
    tid: u64,
    start_us: u64,
    attrs: Vec<(String, AttrValue)>,
}

/// RAII guard for one traced scope. Construct with [`span`] or
/// [`span_under`]; the span closes — and its event is recorded — when
/// the guard drops. A guard built while capture is disabled is inert
/// (`is_recording() == false`) and free to drop.
#[derive(Debug)]
pub struct Span {
    inner: Option<SpanData>,
}

/// Open a span named `name` under the innermost span currently open on
/// this thread (a root span if none is). Returns an inert guard after
/// one atomic load when capture is disabled.
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    let parent = current_span();
    open(name, parent)
}

/// Open a span named `name` under an explicit `parent` id instead of
/// the thread-local current span. This is the cross-thread link: the
/// dispatching side captures `wave_span.id()` (a plain `u64`, `Copy`)
/// into the work closure, and the worker thread opens its chunk span
/// under it. Returns an inert guard when capture is disabled.
pub fn span_under(parent: SpanId, name: &'static str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    open(name, Some(parent))
}

fn open(name: &'static str, parent: Option<SpanId>) -> Span {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let prev = CURRENT.with(|c| c.replace(id));
    Span {
        inner: Some(SpanData {
            id,
            parent,
            prev,
            name,
            tid: tid(),
            start_us: now_us(),
            attrs: Vec::new(),
        }),
    }
}

impl Span {
    /// Whether this guard is actually recording. Call sites gate
    /// attribute computation on this (or on [`super::enabled`]) so the
    /// disabled path never allocates.
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }

    /// This span's id, or 0 for an inert guard. Ids are nonzero, so 0
    /// is unambiguous; [`span_under`] with a 0 parent would produce a
    /// dangling edge, but an inert guard only arises when capture is
    /// off — in which case the child guard is inert too.
    pub fn id(&self) -> SpanId {
        self.inner.as_ref().map_or(0, |d| d.id)
    }

    /// Attach a `key=value` attribute (kept in insertion order). No-op
    /// on an inert guard, but prefer gating the *value computation* on
    /// [`Span::is_recording`] when it formats or allocates.
    pub fn attr(&mut self, key: &str, value: impl Into<AttrValue>) {
        if let Some(data) = self.inner.as_mut() {
            data.attrs.push((key.to_string(), value.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(data) = self.inner.take() else {
            return;
        };
        // Restore the previous innermost span even if the guard is
        // dropped out of order; well-nested guards make this exact.
        CURRENT.with(|c| c.set(data.prev));
        let end_us = now_us();
        record(Event::Span {
            id: data.id,
            parent: data.parent,
            name: data.name.to_string(),
            tid: data.tid,
            start_us: data.start_us,
            dur_us: end_us.saturating_sub(data.start_us),
            attrs: data.attrs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{drain, set_enabled, tests::lock};

    fn span_by_name(evs: &[Event], want: &str) -> (u64, Option<u64>) {
        evs.iter()
            .find_map(|e| match e {
                Event::Span {
                    id, parent, name, ..
                } if name == want => Some((*id, *parent)),
                _ => None,
            })
            .unwrap_or_else(|| panic!("span {want} not captured"))
    }

    #[test]
    fn nested_spans_link_to_their_parent() {
        let _g = lock();
        set_enabled(true);
        {
            let root = span("obs.span.root");
            assert!(root.is_recording() && root.id() != 0);
            {
                let _mid = span("obs.span.mid");
                let leaf = span("obs.span.leaf");
                assert_eq!(current_span(), Some(leaf.id()));
            }
            assert_eq!(current_span(), Some(root.id()));
        }
        let evs = drain();
        set_enabled(false);
        let (root_id, root_parent) = span_by_name(&evs, "obs.span.root");
        let (mid_id, mid_parent) = span_by_name(&evs, "obs.span.mid");
        let (leaf_id, leaf_parent) = span_by_name(&evs, "obs.span.leaf");
        assert_eq!(root_parent, None);
        assert_eq!(mid_parent, Some(root_id));
        assert_eq!(leaf_parent, Some(mid_id));
        assert!(leaf_id != mid_id && mid_id != root_id);
    }

    #[test]
    fn span_under_links_across_an_explicit_parent() {
        let _g = lock();
        set_enabled(true);
        let parent_id;
        {
            let parent = span("obs.span.wave");
            parent_id = parent.id();
            let handle = std::thread::spawn(move || {
                let mut child = span_under(parent_id, "obs.span.chunk");
                child.attr("len", 3usize);
            });
            handle.join().expect("worker thread");
        }
        let evs = drain();
        set_enabled(false);
        let (wave_id, _) = span_by_name(&evs, "obs.span.wave");
        let (_, chunk_parent) = span_by_name(&evs, "obs.span.chunk");
        assert_eq!(wave_id, parent_id);
        assert_eq!(chunk_parent, Some(parent_id));
    }

    #[test]
    fn disabled_guard_is_inert() {
        let _g = lock();
        set_enabled(false);
        let mut s = span("obs.span.inert");
        assert!(!s.is_recording());
        assert_eq!(s.id(), 0);
        s.attr("ignored", true);
        drop(s);
        assert!(!drain()
            .iter()
            .any(|e| matches!(e, Event::Span { name, .. } if name == "obs.span.inert")));
    }
}
