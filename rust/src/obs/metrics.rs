//! Named metrics: counters, gauges, and fixed-bucket histograms.
//!
//! The [`Registry`] is the crate's single metrics namespace. The
//! existing one-off stat structs publish into it when tracing is
//! enabled — `SwapStats` and `FabricStats` from
//! `sched::multijob_allocate_report`, `coordinator::Metrics` via
//! [`crate::coordinator::Metrics::publish`] — so one
//! [`Registry::snapshot`] covers the whole pipeline and lands in
//! `BENCH_multijob.json`'s `telemetry` object.
//!
//! Handles are `Arc`-shared: look one up once ([`Registry::counter`],
//! [`Registry::gauge`], [`Registry::histogram`]) and update it lock-free
//! (counters/gauges are atomics; histograms take a short internal lock
//! per `record`). Nothing here is on the disabled hot path — call sites
//! gate publication on [`super::enabled`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic named counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins named gauge (stores `f64` bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value (0.0 until first set).
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistState {
    /// Per-bucket counts (`bins` uniform buckets over `[lo, hi)`).
    buckets: Vec<u64>,
    /// Samples at or above `hi`, plus every non-finite sample.
    overflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Fixed-bucket histogram over `[lo, hi)` with an overflow bucket.
///
/// Quantiles come from the bucket CDF ([`HistogramSnapshot::quantile`]),
/// so they are accurate to one bucket width — `tests/telemetry.rs`
/// pins this against the exact [`crate::util::stats::quantile`].
/// Samples below `lo` clamp into the first bucket (matching
/// [`crate::util::stats::Histogram`]); non-finite samples count as
/// overflow.
#[derive(Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    state: Mutex<HistState>,
}

impl Histogram {
    fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        let bins = bins.max(1);
        let hi = if hi > lo { hi } else { lo + 1.0 };
        Histogram {
            lo,
            width: (hi - lo) / bins as f64,
            state: Mutex::new(HistState {
                buckets: vec![0; bins],
                overflow: 0,
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }),
        }
    }

    /// Record one sample.
    pub fn record(&self, x: f64) {
        let mut st = self.state.lock().expect("histogram lock");
        st.count += 1;
        if !x.is_finite() {
            st.overflow += 1;
            return;
        }
        st.sum += x;
        if x < st.min {
            st.min = x;
        }
        if x > st.max {
            st.max = x;
        }
        let idx = ((x - self.lo) / self.width).floor();
        if idx < 0.0 {
            st.buckets[0] += 1;
        } else if (idx as usize) < st.buckets.len() {
            st.buckets[idx as usize] += 1;
        } else {
            st.overflow += 1;
        }
    }

    /// Point-in-time copy of the full histogram state.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let st = self.state.lock().expect("histogram lock");
        HistogramSnapshot {
            lo: self.lo,
            width: self.width,
            buckets: st.buckets.clone(),
            overflow: st.overflow,
            count: st.count,
            sum: st.sum,
            min: if st.min.is_finite() { st.min } else { 0.0 },
            max: if st.max.is_finite() { st.max } else { 0.0 },
        }
    }
}

/// Frozen copy of a [`Histogram`], carrying the bucket CDF so
/// quantiles can be computed without holding any lock.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Left edge of the first bucket.
    pub lo: f64,
    /// Uniform bucket width.
    pub width: f64,
    /// Per-bucket counts.
    pub buckets: Vec<u64>,
    /// Samples at/above the range (and non-finite samples).
    pub overflow: u64,
    /// Total samples recorded.
    pub count: u64,
    /// Sum of finite samples.
    pub sum: f64,
    /// Smallest finite sample (0.0 if none).
    pub min: f64,
    /// Largest finite sample (0.0 if none).
    pub max: f64,
}

impl HistogramSnapshot {
    /// Mean: sum of finite samples over the total sample count
    /// (0.0 when empty; non-finite samples dilute rather than poison).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Bucket-CDF quantile for `q` in `[0, 1]`: the right edge of the
    /// first bucket whose cumulative count reaches `ceil(q * count)`
    /// (the observed max for samples that landed in overflow). Accurate
    /// to one bucket width. Returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return self.lo + self.width * (i + 1) as f64;
            }
        }
        self.max
    }

    /// Median, to one bucket width.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 99th percentile, to one bucket width.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[derive(Debug)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Thread-safe namespace of metrics, keyed by dotted names
/// (`sched.swap.rounds`, `coordinator.latency`, ...). Lookups
/// get-or-create; a name keeps the kind of its first registration
/// (a mismatched re-lookup panics — it is a programming error).
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().expect("registry lock");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().expect("registry lock");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Get or create the histogram `name` with `bins` uniform buckets
    /// over `[lo, hi)`. The shape is fixed by the first registration;
    /// later lookups ignore their `lo`/`hi`/`bins` arguments.
    pub fn histogram(&self, name: &str, lo: f64, hi: f64, bins: usize) -> Arc<Histogram> {
        let mut m = self.metrics.lock().expect("registry lock");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(lo, hi, bins))))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Point-in-time copy of every metric, names sorted.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let m = self.metrics.lock().expect("registry lock");
        let mut snap = RegistrySnapshot::default();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Metric::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
                Metric::Histogram(h) => snap.histograms.push((name.clone(), h.snapshot())),
            }
        }
        snap
    }

    /// Drop every metric (tests and benches isolate runs with this).
    pub fn reset(&self) {
        self.metrics.lock().expect("registry lock").clear();
    }
}

/// Frozen copy of a [`Registry`], each kind sorted by name.
#[derive(Clone, Debug, Default)]
pub struct RegistrySnapshot {
    /// `(name, value)` for every counter.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` for every gauge.
    pub gauges: Vec<(String, f64)>,
    /// `(name, snapshot)` for every histogram.
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

/// The process-wide registry all crate instrumentation publishes into.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let r = Registry::default();
        let c = r.counter("t.count");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("t.count").get(), 5);
        let g = r.gauge("t.gauge");
        g.set(2.5);
        assert_eq!(r.gauge("t.gauge").get(), 2.5);
        let snap = r.snapshot();
        assert_eq!(snap.counters, vec![("t.count".to_string(), 5)]);
        assert_eq!(snap.gauges, vec![("t.gauge".to_string(), 2.5)]);
        r.reset();
        assert!(r.snapshot().counters.is_empty());
    }

    #[test]
    fn histogram_quantiles_track_the_bucket_cdf() {
        let r = Registry::default();
        let h = r.histogram("t.hist", 0.0, 10.0, 10);
        for i in 0..100 {
            h.record(f64::from(i) / 10.0); // 10 samples per bucket
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 100);
        assert_eq!(snap.overflow, 0);
        // ceil(0.5*100)=50th sample sits in bucket 4 → right edge 5.0
        assert!((snap.p50() - 5.0).abs() < 1e-12);
        assert!((snap.p99() - 10.0).abs() < 1e-12);
        assert!((snap.mean() - 4.95).abs() < 1e-9);
        assert_eq!(snap.max, 9.9);
    }

    #[test]
    fn histogram_handles_overflow_clamp_and_nonfinite() {
        let h = Histogram::new(0.0, 1.0, 4);
        h.record(-5.0); // clamps into bucket 0
        h.record(0.5);
        h.record(42.0); // overflow, finite → max tracks it
        h.record(f64::NAN); // overflow, not in sum/min/max
        let snap = h.snapshot();
        assert_eq!(snap.count, 4);
        assert_eq!(snap.overflow, 2);
        assert_eq!(snap.buckets[0], 1);
        assert_eq!(snap.min, -5.0);
        assert_eq!(snap.max, 42.0);
        // q=1.0 walks past every bucket → observed max
        assert_eq!(snap.quantile(1.0), 42.0);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::default();
        let _ = r.counter("t.kind");
        let _ = r.gauge("t.kind");
    }
}
