//! Telemetry exporters: versioned JSONL, Chrome trace-event output,
//! and a structural validator.
//!
//! The JSONL wire format follows the same discipline as
//! [`crate::scenario::record`]: line-oriented JSON with a versioned
//! header line first ([`OBS_FORMAT_VERSION`]), deterministic
//! serialization through [`crate::util::json`], and readers that
//! reject unknown versions with a precise error instead of
//! misinterpreting them. Field additions within a version are allowed;
//! renames/removals bump it.
//!
//! [`to_chrome_trace`] renders the same events in the Chrome
//! trace-event format — open the file in `chrome://tracing` or
//! Perfetto and the span tree appears as nested slices per thread,
//! with instants (re-plans, churn, drift, warnings) as markers.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{AttrValue, Event, Level};

/// Version stamp written into every telemetry JSONL header
/// (`"version"` field).
///
/// Version 1 lines: `obs_header`, `span`, `instant`.
pub const OBS_FORMAT_VERSION: u64 = 1;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn attrs_to_json(attrs: &[(String, AttrValue)]) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in attrs {
        let jv = match v {
            AttrValue::U64(x) => Json::Num(*x as f64),
            AttrValue::F64(x) => Json::Num(*x),
            AttrValue::Str(s) => Json::Str(s.clone()),
            AttrValue::Bool(b) => Json::Bool(*b),
        };
        m.insert(k.clone(), jv);
    }
    Json::Obj(m)
}

/// JSON numbers don't distinguish `U64` from integral `F64`; map
/// non-negative integers in the exact range back to `U64` (the writer
/// prints those without a fraction, so serialize→parse→serialize is a
/// fixed point even though the `AttrValue` variant may change).
fn attr_from_json(v: &Json) -> Result<AttrValue, String> {
    match v {
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && x.abs() < 9e15 => {
            Ok(AttrValue::U64(*x as u64))
        }
        Json::Num(x) => Ok(AttrValue::F64(*x)),
        Json::Str(s) => Ok(AttrValue::Str(s.clone())),
        Json::Bool(b) => Ok(AttrValue::Bool(*b)),
        other => Err(format!("unsupported attribute value {other:?}")),
    }
}

fn attrs_from_json(v: Option<&Json>) -> Result<Vec<(String, AttrValue)>, String> {
    let Some(v) = v else {
        return Ok(Vec::new());
    };
    let m = v.as_obj().ok_or("'attrs' must be an object")?;
    let mut out = Vec::with_capacity(m.len());
    for (k, jv) in m {
        out.push((k.clone(), attr_from_json(jv)?));
    }
    Ok(out)
}

fn field_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as u64)
        .ok_or_else(|| format!("missing/invalid integer field '{key}'"))
}

fn field_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing/invalid string field '{key}'"))
}

/// Serialize events to the JSONL wire format: header line, then one
/// event per line in capture order, trailing newline.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    let header = obj(vec![
        ("kind", Json::Str("obs_header".into())),
        ("version", Json::Num(OBS_FORMAT_VERSION as f64)),
    ]);
    out.push_str(&header.to_string());
    out.push('\n');
    for ev in events {
        let line = match ev {
            Event::Span {
                id,
                parent,
                name,
                tid,
                start_us,
                dur_us,
                attrs,
            } => obj(vec![
                ("attrs", attrs_to_json(attrs)),
                ("dur_us", Json::Num(*dur_us as f64)),
                ("id", Json::Num(*id as f64)),
                ("kind", Json::Str("span".into())),
                ("name", Json::Str(name.clone())),
                (
                    "parent",
                    parent.map_or(Json::Null, |p| Json::Num(p as f64)),
                ),
                ("start_us", Json::Num(*start_us as f64)),
                ("tid", Json::Num(*tid as f64)),
            ]),
            Event::Instant {
                name,
                tid,
                at_us,
                level,
                attrs,
            } => obj(vec![
                ("at_us", Json::Num(*at_us as f64)),
                ("attrs", attrs_to_json(attrs)),
                ("kind", Json::Str("instant".into())),
                (
                    "level",
                    Json::Str(
                        match level {
                            Level::Info => "info",
                            Level::Warn => "warn",
                        }
                        .into(),
                    ),
                ),
                ("name", Json::Str(name.clone())),
                ("tid", Json::Num(*tid as f64)),
            ]),
        };
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Parse events back from their JSONL form. Rejects unknown format
/// versions, unknown line kinds and malformed lines with an error
/// naming the offending line. Integral attribute values come back as
/// [`AttrValue::U64`] (see the format note on [`to_jsonl`]).
pub fn parse_jsonl(text: &str) -> Result<Vec<Event>, String> {
    let mut lines = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty());
    let (hno, hline) = lines.next().ok_or("empty telemetry trace")?;
    let hv = Json::parse(hline).map_err(|e| format!("telemetry line {}: {e}", hno + 1))?;
    if field_str(&hv, "kind")? != "obs_header" {
        return Err(format!(
            "telemetry line {}: first line must be the obs_header",
            hno + 1
        ));
    }
    let version = field_u64(&hv, "version")?;
    if version != OBS_FORMAT_VERSION {
        return Err(format!(
            "unsupported telemetry format version {version} (this build reads \
             version {OBS_FORMAT_VERSION})"
        ));
    }
    let mut events = Vec::new();
    for (no, line) in lines {
        let v = Json::parse(line).map_err(|e| format!("telemetry line {}: {e}", no + 1))?;
        let ev = match field_str(&v, "kind")? {
            "span" => Event::Span {
                id: field_u64(&v, "id")?,
                parent: match v.get("parent") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(
                        p.as_f64()
                            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                            .map(|x| x as u64)
                            .ok_or_else(|| {
                                format!("telemetry line {}: invalid 'parent'", no + 1)
                            })?,
                    ),
                },
                name: field_str(&v, "name")?.to_string(),
                tid: field_u64(&v, "tid")?,
                start_us: field_u64(&v, "start_us")?,
                dur_us: field_u64(&v, "dur_us")?,
                attrs: attrs_from_json(v.get("attrs"))
                    .map_err(|e| format!("telemetry line {}: {e}", no + 1))?,
            },
            "instant" => Event::Instant {
                name: field_str(&v, "name")?.to_string(),
                tid: field_u64(&v, "tid")?,
                at_us: field_u64(&v, "at_us")?,
                level: match field_str(&v, "level")? {
                    "info" => Level::Info,
                    "warn" => Level::Warn,
                    other => {
                        return Err(format!(
                            "telemetry line {}: unknown level '{other}'",
                            no + 1
                        ))
                    }
                },
                attrs: attrs_from_json(v.get("attrs"))
                    .map_err(|e| format!("telemetry line {}: {e}", no + 1))?,
            },
            other => {
                return Err(format!(
                    "telemetry line {}: unknown line kind '{other}'",
                    no + 1
                ))
            }
        };
        events.push(ev);
    }
    Ok(events)
}

/// What [`validate`] found in a structurally sound trace.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// Closed spans in the trace.
    pub spans: usize,
    /// Instant events in the trace.
    pub instants: usize,
    /// `level=warn` instants among them.
    pub warns: usize,
    /// Spans with no parent.
    pub roots: usize,
    /// Deepest nesting (a root span is depth 1; 0 for an empty trace).
    pub max_depth: usize,
}

/// Check the structural invariants every capture must satisfy:
///
/// * span ids are nonzero and unique;
/// * every `parent` references a span present in the trace (spans are
///   only emitted at close, so presence also means "closed"), with no
///   parent cycles;
/// * every child's `[start, start+dur]` window nests inside its
///   parent's — guaranteed by the shared monotonic epoch and RAII
///   drop order, so a violation means corrupted data.
///
/// Returns a [`TraceSummary`] on success and a message naming the
/// first offending span otherwise.
pub fn validate(events: &[Event]) -> Result<TraceSummary, String> {
    let mut spans: BTreeMap<u64, (Option<u64>, u64, u64, &str)> = BTreeMap::new();
    let mut summary = TraceSummary::default();
    for ev in events {
        match ev {
            Event::Span {
                id,
                parent,
                name,
                start_us,
                dur_us,
                ..
            } => {
                if *id == 0 {
                    return Err(format!("span '{name}' has reserved id 0"));
                }
                if spans
                    .insert(*id, (*parent, *start_us, *start_us + *dur_us, name.as_str()))
                    .is_some()
                {
                    return Err(format!("duplicate span id {id} ('{name}')"));
                }
                summary.spans += 1;
            }
            Event::Instant { level, .. } => {
                summary.instants += 1;
                if *level == Level::Warn {
                    summary.warns += 1;
                }
            }
        }
    }
    for (id, (parent, start, end, name)) in &spans {
        let Some(pid) = parent else {
            summary.roots += 1;
            continue;
        };
        let Some((_, pstart, pend, pname)) = spans.get(pid) else {
            return Err(format!(
                "span {id} ('{name}') references missing parent {pid}"
            ));
        };
        if start < pstart || end > pend {
            return Err(format!(
                "span {id} ('{name}') window [{start}, {end}]us escapes parent \
                 {pid} ('{pname}') window [{pstart}, {pend}]us"
            ));
        }
    }
    for (id, entry) in &spans {
        let name = entry.3;
        let mut parent = entry.0;
        let mut depth = 1usize;
        while let Some(pid) = parent {
            if depth > spans.len() {
                return Err(format!("parent cycle reaching span {id} ('{name}')"));
            }
            depth += 1;
            parent = spans[&pid].0;
        }
        summary.max_depth = summary.max_depth.max(depth);
    }
    Ok(summary)
}

/// Render events in the Chrome trace-event format (one JSON document,
/// loadable in `chrome://tracing` / Perfetto). Spans become `"X"`
/// complete events, instants become thread-scoped `"i"` markers;
/// attributes (plus span `id`/`parent`) land in `args`.
pub fn to_chrome_trace(events: &[Event]) -> String {
    let mut trace_events = Vec::with_capacity(events.len());
    for ev in events {
        match ev {
            Event::Span {
                id,
                parent,
                name,
                tid,
                start_us,
                dur_us,
                attrs,
            } => {
                let mut args = match attrs_to_json(attrs) {
                    Json::Obj(m) => m,
                    _ => unreachable!("attrs_to_json returns an object"),
                };
                args.insert("span_id".to_string(), Json::Num(*id as f64));
                if let Some(p) = parent {
                    args.insert("span_parent".to_string(), Json::Num(*p as f64));
                }
                trace_events.push(obj(vec![
                    ("args", Json::Obj(args)),
                    ("cat", Json::Str("dcflow".into())),
                    ("dur", Json::Num(*dur_us as f64)),
                    ("name", Json::Str(name.clone())),
                    ("ph", Json::Str("X".into())),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(*tid as f64)),
                    ("ts", Json::Num(*start_us as f64)),
                ]));
            }
            Event::Instant {
                name,
                tid,
                at_us,
                level,
                attrs,
            } => {
                trace_events.push(obj(vec![
                    ("args", attrs_to_json(attrs)),
                    (
                        "cat",
                        Json::Str(
                            match level {
                                Level::Info => "dcflow",
                                Level::Warn => "dcflow.warn",
                            }
                            .into(),
                        ),
                    ),
                    ("name", Json::Str(name.clone())),
                    ("ph", Json::Str("i".into())),
                    ("pid", Json::Num(1.0)),
                    ("s", Json::Str("t".into())),
                    ("tid", Json::Num(*tid as f64)),
                    ("ts", Json::Num(*at_us as f64)),
                ]));
            }
        }
    }
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(trace_events)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::Span {
                id: 1,
                parent: None,
                name: "plan_jobs".to_string(),
                tid: 0,
                start_us: 10,
                dur_us: 100,
                attrs: vec![("jobs".to_string(), AttrValue::U64(3))],
            },
            Event::Span {
                id: 2,
                parent: Some(1),
                name: "multijob.swap_round".to_string(),
                tid: 0,
                start_us: 20,
                dur_us: 50,
                attrs: vec![
                    ("round".to_string(), AttrValue::U64(0)),
                    ("inline".to_string(), AttrValue::Bool(false)),
                    ("mass".to_string(), AttrValue::F64(0.25)),
                    ("engine".to_string(), AttrValue::Str("Wave".to_string())),
                ],
            },
            Event::Instant {
                name: "warn".to_string(),
                tid: 1,
                at_us: 30,
                level: Level::Warn,
                attrs: vec![("msg".to_string(), AttrValue::Str("careful".to_string()))],
            },
        ]
    }

    #[test]
    fn jsonl_serialization_is_a_fixed_point() {
        let evs = sample_events();
        let text = to_jsonl(&evs);
        assert!(text.lines().next().unwrap().contains("\"version\":1"));
        let back = parse_jsonl(&text).unwrap();
        // integral F64 attrs may come back as U64; the *serialized*
        // form is the stable identity
        assert_eq!(text, to_jsonl(&back));
        assert_eq!(back.len(), evs.len());
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse_jsonl("").is_err());
        let future = to_jsonl(&[]).replacen("\"version\":1", "\"version\":999", 1);
        assert!(parse_jsonl(&future).unwrap_err().contains("version 999"));
        let noheader = "{\"kind\":\"span\"}\n";
        assert!(parse_jsonl(noheader).unwrap_err().contains("obs_header"));
        let badline = to_jsonl(&[]) + "{\"kind\":\"mystery\"}\n";
        assert!(parse_jsonl(&badline).unwrap_err().contains("mystery"));
        let badlevel = to_jsonl(&[])
            + "{\"at_us\":1,\"kind\":\"instant\",\"level\":\"loud\",\"name\":\"x\",\"tid\":0}\n";
        assert!(parse_jsonl(&badlevel).unwrap_err().contains("loud"));
    }

    #[test]
    fn validate_accepts_well_formed_and_summarizes() {
        let s = validate(&sample_events()).unwrap();
        assert_eq!(s.spans, 2);
        assert_eq!(s.instants, 1);
        assert_eq!(s.warns, 1);
        assert_eq!(s.roots, 1);
        assert_eq!(s.max_depth, 2);
    }

    #[test]
    fn validate_rejects_structural_corruption() {
        let mut evs = sample_events();
        // dangling parent
        if let Event::Span { parent, .. } = &mut evs[1] {
            *parent = Some(99);
        }
        assert!(validate(&evs).unwrap_err().contains("missing parent"));
        // duplicate id
        let mut evs = sample_events();
        if let Event::Span { id, parent, .. } = &mut evs[1] {
            *id = 1;
            *parent = None;
        }
        assert!(validate(&evs).unwrap_err().contains("duplicate"));
        // child escaping the parent window
        let mut evs = sample_events();
        if let Event::Span { dur_us, .. } = &mut evs[1] {
            *dur_us = 10_000;
        }
        assert!(validate(&evs).unwrap_err().contains("escapes parent"));
        // reserved id
        let mut evs = sample_events();
        if let Event::Span { id, .. } = &mut evs[0] {
            *id = 0;
        }
        assert!(validate(&evs).unwrap_err().contains("reserved id 0"));
    }

    #[test]
    fn chrome_trace_contains_nested_slices_and_instants() {
        let text = to_chrome_trace(&sample_events());
        let doc = Json::parse(&text).unwrap();
        let tes = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(tes.len(), 3);
        assert_eq!(tes[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(tes[1].get("name").unwrap().as_str(), Some("multijob.swap_round"));
        assert_eq!(
            tes[1].get("args").unwrap().get("span_parent").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(tes[2].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(tes[2].get("cat").unwrap().as_str(), Some("dcflow.warn"));
    }
}
