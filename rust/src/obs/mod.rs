//! Crate-wide telemetry: structured spans, a metrics registry, and
//! trace exporters for the whole planning pipeline.
//!
//! The paper's argument is about the *tail* of job execution time under
//! stochastic servers — and diagnosing tails requires structured
//! runtime telemetry, not ad-hoc counters. This module is the one
//! observability layer for the crate:
//!
//! * **Spans** ([`span`], [`span_under`], [`Span`]) — RAII guards with
//!   parent linkage, wall-clock duration (microseconds on one
//!   process-wide monotonic epoch) and `key=value` attributes. The hot
//!   path is instrumented end to end: `Planner::plan_jobs` phases, each
//!   swap round in `sched::multijob`, per-wave dispatch and per-chunk
//!   execution in `ShardedBackend`/`ScoringPool` (chunk spans are
//!   parent-linked *across threads* to their wave), and drift / churn /
//!   re-plan instants in the coordinator and monitor layers.
//! * **Metrics registry** ([`Registry`], [`registry`]) — named
//!   counters, gauges and fixed-bucket histograms with p50/p99/max
//!   snapshots. The existing stat structs (`SwapStats`, `FabricStats`,
//!   `coordinator::Metrics`) publish into it when tracing is enabled,
//!   so one snapshot covers the whole pipeline.
//! * **Exporters** ([`export`]) — a versioned JSONL event sink (same
//!   versioning discipline as `scenario::record`) and Chrome
//!   trace-event-format output loadable in `chrome://tracing` /
//!   Perfetto, plus a structural validator (unique ids, existing
//!   parents, child-within-parent windows).
//!
//! ## Gating
//!
//! Everything hangs off one process-wide switch, mirroring
//! [`crate::util::warn`]: unset until the first query, then decided by
//! the `DCFLOW_TRACE` environment variable (`1`/`true`) and cached;
//! [`set_enabled`] always wins over the env var. **When disabled,
//! instrumentation costs a few relaxed atomic loads** — no allocation,
//! no locking, no clock reads — so plans stay bit-identical and the
//! scoring fabric's warm-scratch zero-allocation discipline is
//! untouched (`tests/telemetry.rs`, `tests/fabric_equivalence.rs`).
//!
//! Captured events buffer in an in-process sink until [`drain`]ed
//! (long traced runs should drain periodically; nothing is written to
//! disk unless the caller exports).
//!
//! ```
//! use dcflow::obs;
//!
//! obs::set_enabled(true);
//! {
//!     let mut outer = obs::span("doc.outer");
//!     outer.attr("answer", 42u64);
//!     let _inner = obs::span("doc.inner");
//! } // guards close innermost-first
//! let events = obs::drain();
//! obs::set_enabled(false);
//! let summary = obs::export::validate(&events).expect("well-formed trace");
//! assert_eq!(summary.spans, 2);
//! assert!(obs::export::to_chrome_trace(&events).contains("doc.inner"));
//! ```

pub mod export;
pub mod metrics;
pub mod span;

pub use export::{parse_jsonl, to_chrome_trace, to_jsonl, validate, TraceSummary, OBS_FORMAT_VERSION};
pub use metrics::{registry, Counter, Gauge, Histogram, HistogramSnapshot, Registry, RegistrySnapshot};
pub use span::{current_span, span, span_under, Span, SpanId};

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// Mode not yet decided: the first [`enabled`] call consults
/// `DCFLOW_TRACE` (same tri-state discipline as [`crate::util::warn`]).
const UNSET: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(UNSET);

/// Turn telemetry capture on (`true`) or off (`false`) process-wide.
/// Overrides the `DCFLOW_TRACE` environment variable.
pub fn set_enabled(on: bool) {
    MODE.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

/// Whether telemetry capture is currently enabled. On the first call
/// with no prior [`set_enabled`], the `DCFLOW_TRACE` env var (`1` /
/// `true`, case-insensitive) decides and is cached. This is the whole
/// cost of disabled instrumentation: one relaxed atomic load.
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => {
            let env_on = std::env::var("DCFLOW_TRACE")
                .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                .unwrap_or(false);
            let desired = if env_on { ON } else { OFF };
            // compare_exchange so a concurrent set_enabled() is never
            // overwritten by the env default (set_enabled always wins)
            match MODE.compare_exchange(UNSET, desired, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => env_on,
                Err(current) => current == ON,
            }
        }
    }
}

/// One attribute value attached to a span or instant event.
#[derive(Clone, Debug, PartialEq)]
pub enum AttrValue {
    /// Unsigned counter-like value (serialized as a JSON number).
    U64(u64),
    /// Floating-point value.
    F64(f64),
    /// Free-form string.
    Str(String),
    /// Boolean flag.
    Bool(bool),
}

impl From<u64> for AttrValue {
    fn from(v: u64) -> AttrValue {
        AttrValue::U64(v)
    }
}

impl From<usize> for AttrValue {
    fn from(v: usize) -> AttrValue {
        AttrValue::U64(v as u64)
    }
}

impl From<f64> for AttrValue {
    fn from(v: f64) -> AttrValue {
        AttrValue::F64(v)
    }
}

impl From<bool> for AttrValue {
    fn from(v: bool) -> AttrValue {
        AttrValue::Bool(v)
    }
}

impl From<&str> for AttrValue {
    fn from(v: &str) -> AttrValue {
        AttrValue::Str(v.to_string())
    }
}

impl From<String> for AttrValue {
    fn from(v: String) -> AttrValue {
        AttrValue::Str(v)
    }
}

/// Severity of an instant event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Ordinary pipeline event (re-plan, churn, drift verdict, ...).
    Info,
    /// A [`crate::util::warn`] diagnostic routed into the trace.
    Warn,
}

/// One captured telemetry event. Spans are emitted at close time (a
/// span event in the sink is by construction a *closed* span), instants
/// the moment they happen.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A closed span: `[start_us, start_us + dur_us]` on the process
    /// epoch, with its parent linkage and attributes.
    Span {
        /// Unique nonzero span id (process-wide).
        id: u64,
        /// Enclosing span's id (`None` for a root span).
        parent: Option<u64>,
        /// Span name (static at the instrumentation site).
        name: String,
        /// Capture-thread id (dense, assigned at first use).
        tid: u64,
        /// Open time, microseconds since the process trace epoch.
        start_us: u64,
        /// Wall-clock duration in microseconds.
        dur_us: u64,
        /// `key=value` attributes, in insertion order.
        attrs: Vec<(String, AttrValue)>,
    },
    /// A point-in-time event (re-plan, churn, drift, warning).
    Instant {
        /// Event name.
        name: String,
        /// Capture-thread id.
        tid: u64,
        /// Event time, microseconds since the process trace epoch.
        at_us: u64,
        /// Severity.
        level: Level,
        /// `key=value` attributes, in insertion order.
        attrs: Vec<(String, AttrValue)>,
    },
}

/// The in-process event sink. Bounded only by [`drain`] calls.
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());

/// Append one event to the sink (crate instrumentation entry point).
pub(crate) fn record(ev: Event) {
    SINK.lock().expect("obs sink lock").push(ev);
}

/// Take every buffered event out of the sink, oldest first.
pub fn drain() -> Vec<Event> {
    std::mem::take(&mut *SINK.lock().expect("obs sink lock"))
}

/// Number of events currently buffered.
pub fn pending() -> usize {
    SINK.lock().expect("obs sink lock").len()
}

/// Record an instant info event. No-op when capture is disabled — but
/// call sites that build an attribute vector should still gate on
/// [`enabled`] so the vector is never allocated on the disabled path.
pub fn event(name: &str, attrs: Vec<(String, AttrValue)>) {
    if !enabled() {
        return;
    }
    record(Event::Instant {
        name: name.to_string(),
        tid: span::tid(),
        at_us: span::now_us(),
        level: Level::Info,
        attrs,
    });
}

/// Record a `level=warn` instant event carrying one diagnostic message.
/// This is [`crate::util::warn::warn`]'s hook into the trace: warnings
/// appear next to the spans that produced them, regardless of the
/// `DCFLOW_QUIET` stderr gate.
pub fn warn_event(msg: &str) {
    if !enabled() {
        return;
    }
    record(Event::Instant {
        name: "warn".to_string(),
        tid: span::tid(),
        at_us: span::now_us(),
        level: Level::Warn,
        attrs: vec![("msg".to_string(), AttrValue::Str(msg.to_string()))],
    });
}

/// Handle to the process-wide telemetry pipeline: a zero-sized,
/// copyable facade over the [`enabled`]/[`drain`] switchboard, so call
/// sites (and the [`crate::plan::Planner::recorder`] builder knob) can
/// pass "the recorder" around as a value.
#[derive(Clone, Copy, Debug, Default)]
pub struct Recorder;

impl Recorder {
    /// The process-wide recorder.
    pub fn global() -> Recorder {
        Recorder
    }

    /// Enable capture (see [`set_enabled`]).
    pub fn enable(self) {
        set_enabled(true);
    }

    /// Disable capture (see [`set_enabled`]).
    pub fn disable(self) {
        set_enabled(false);
    }

    /// Whether capture is currently enabled (see [`enabled`]).
    pub fn is_enabled(self) -> bool {
        enabled()
    }

    /// Enable capture for a lexical scope: returns a guard that
    /// restores the *exact* previous mode (including "not yet decided")
    /// on drop. This is what [`crate::plan::Planner::recorder`] uses to
    /// trace one planning call without flipping the global switch for
    /// the rest of the process.
    #[must_use = "capture stays enabled only while the guard lives"]
    pub fn activate(self) -> ActiveRecorder {
        let prev = MODE.swap(ON, Ordering::Relaxed);
        ActiveRecorder { prev }
    }

    /// Take every buffered event (see [`drain`]).
    pub fn drain(self) -> Vec<Event> {
        drain()
    }
}

/// Guard returned by [`Recorder::activate`]: capture is enabled while
/// it lives and the previous mode is restored on drop.
#[derive(Debug)]
pub struct ActiveRecorder {
    prev: u8,
}

impl Drop for ActiveRecorder {
    fn drop(&mut self) {
        MODE.store(self.prev, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // obs unit tests share one process-global pipeline with the rest of
    // the lib test binary; serialize them so drains never race each
    // other (foreign events from concurrently running planner tests are
    // tolerated by filtering on names unique to this module).
    pub(super) fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn disabled_event_is_dropped_and_enable_round_trips() {
        let _g = lock();
        set_enabled(false);
        event("obs.mod.dropped", Vec::new());
        assert!(!drain()
            .iter()
            .any(|e| matches!(e, Event::Instant { name, .. } if name == "obs.mod.dropped")));
        set_enabled(true);
        event(
            "obs.mod.kept",
            vec![("k".to_string(), AttrValue::from(7u64))],
        );
        warn_event("obs.mod.warning");
        let evs = drain();
        set_enabled(false);
        let kept = evs
            .iter()
            .find(|e| matches!(e, Event::Instant { name, .. } if name == "obs.mod.kept"))
            .expect("info event captured");
        if let Event::Instant { level, attrs, .. } = kept {
            assert_eq!(*level, Level::Info);
            assert_eq!(attrs[0], ("k".to_string(), AttrValue::U64(7)));
        }
        assert!(evs.iter().any(|e| matches!(
            e,
            Event::Instant { name, level: Level::Warn, .. } if name == "warn"
        )));
    }

    #[test]
    fn activate_guard_restores_previous_mode() {
        let _g = lock();
        set_enabled(false);
        {
            let _active = Recorder::global().activate();
            assert!(enabled());
        }
        assert!(!enabled());
        let _ = drain();
    }
}
