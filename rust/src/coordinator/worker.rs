//! Worker threads: the simulated heterogeneous servers.
//!
//! Each worker owns a *hidden* service-time law the coordinator never
//! sees directly — the leader only observes per-task service times, the
//! way a real cluster only exposes measurements. Workers run as real OS
//! threads answering draw requests over channels (the leader/worker
//! message-passing topology of a real deployment), while *time itself is
//! virtual*: the leader keeps per-server clocks, so runs are fast and
//! deterministic (DESIGN.md §substitutions).
//!
//! Failure injection: a worker can be configured to switch to a second
//! law after `drift_after` draws (degradation / straggler onset), which
//! is what the monitor + re-optimization loop must catch.

use crate::dist::ServiceDist;
use crate::util::rng::Rng;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Worker behavior specification.
#[derive(Clone, Debug)]
pub struct WorkerSpec {
    /// Server id this worker impersonates.
    pub server_id: usize,
    /// Hidden service-time law.
    pub dist: ServiceDist,
    /// Optional drift: after this many draws, switch to `drift_to`.
    pub drift_after: Option<u64>,
    /// Law after the drift point.
    pub drift_to: Option<ServiceDist>,
    /// Optional replay script: the worker answers draw *k* with
    /// `script[k]` instead of sampling (`scenario::Replay` feeds
    /// captured service times back verbatim). Draws past the end of the
    /// script fall back to sampling `dist` — deterministic, since the
    /// scripted draws never consumed RNG state.
    pub script: Option<Arc<Vec<f64>>>,
}

impl WorkerSpec {
    /// Stationary worker.
    pub fn stable(server_id: usize, dist: ServiceDist) -> WorkerSpec {
        WorkerSpec {
            server_id,
            dist,
            drift_after: None,
            drift_to: None,
            script: None,
        }
    }

    /// Worker that degrades to `drift_to` after `after` tasks.
    pub fn drifting(server_id: usize, dist: ServiceDist, after: u64, drift_to: ServiceDist) -> WorkerSpec {
        WorkerSpec {
            server_id,
            dist,
            drift_after: Some(after),
            drift_to: Some(drift_to),
            script: None,
        }
    }

    /// Worker that replays `script` verbatim (draw *k* returns
    /// `script[k]`), falling back to sampling `fallback` when the script
    /// is exhausted. Used by `scenario::Replay`.
    pub fn scripted(server_id: usize, fallback: ServiceDist, script: Vec<f64>) -> WorkerSpec {
        WorkerSpec {
            server_id,
            dist: fallback,
            drift_after: None,
            drift_to: None,
            script: Some(Arc::new(script)),
        }
    }
}

enum Request {
    Draw(Sender<f64>),
    Shutdown,
}

/// Handle to a running worker thread.
pub struct WorkerHandle {
    tx: Sender<Request>,
    join: Option<JoinHandle<u64>>,
    /// Server id.
    pub server_id: usize,
}

impl WorkerHandle {
    /// Spawn the worker thread.
    pub fn spawn(spec: WorkerSpec, seed: u64) -> WorkerHandle {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let server_id = spec.server_id;
        let join = std::thread::Builder::new()
            .name(format!("dcflow-worker-{server_id}"))
            .spawn(move || worker_main(spec, seed, rx))
            .expect("spawn worker");
        WorkerHandle {
            tx,
            join: Some(join),
            server_id,
        }
    }

    /// Synchronously draw one service time (blocking round-trip —
    /// the "execute task" RPC of the simulated cluster).
    pub fn draw(&self) -> f64 {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request::Draw(reply_tx))
            .expect("worker alive");
        reply_rx.recv().expect("worker replies")
    }

    /// Stop the worker; returns the number of tasks it served.
    pub fn shutdown(mut self) -> u64 {
        let _ = self.tx.send(Request::Shutdown);
        self.join
            .take()
            .expect("not yet joined")
            .join()
            .expect("worker thread exits cleanly")
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(spec: WorkerSpec, seed: u64, rx: Receiver<Request>) -> u64 {
    let mut rng = Rng::new(seed ^ (spec.server_id as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut served = 0u64;
    loop {
        match rx.recv() {
            Ok(Request::Draw(reply)) => {
                let scripted = spec
                    .script
                    .as_ref()
                    .and_then(|s| s.get(served as usize))
                    .copied();
                let sample = match scripted {
                    Some(v) => v,
                    None => {
                        let drifted = spec
                            .drift_after
                            .map(|after| served >= after)
                            .unwrap_or(false);
                        let dist = if drifted {
                            spec.drift_to.as_ref().unwrap_or(&spec.dist)
                        } else {
                            &spec.dist
                        };
                        dist.sample(&mut rng)
                    }
                };
                served += 1;
                // ignore send failure: leader may have moved on
                let _ = reply.send(sample);
            }
            Ok(Request::Shutdown) | Err(_) => return served,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_match_hidden_law() {
        let spec = WorkerSpec::stable(0, ServiceDist::exponential(4.0));
        let w = WorkerHandle::spawn(spec, 1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| w.draw()).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
        assert_eq!(w.shutdown(), n);
    }

    #[test]
    fn drift_switches_law() {
        let spec = WorkerSpec::drifting(
            1,
            ServiceDist::exponential(10.0),
            1000,
            ServiceDist::exponential(1.0),
        );
        let w = WorkerHandle::spawn(spec, 2);
        let before: f64 = (0..1000).map(|_| w.draw()).sum::<f64>() / 1000.0;
        let after: f64 = (0..1000).map(|_| w.draw()).sum::<f64>() / 1000.0;
        assert!(before < 0.15, "before {before}");
        assert!(after > 0.7, "after {after}");
        w.shutdown();
    }

    #[test]
    fn deterministic_per_seed() {
        let mk = || {
            let w = WorkerHandle::spawn(WorkerSpec::stable(3, ServiceDist::exponential(2.0)), 9);
            let v: Vec<f64> = (0..50).map(|_| w.draw()).collect();
            w.shutdown();
            v
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn scripted_worker_replays_then_falls_back() {
        let script = vec![0.5, 0.25, 0.125];
        let w = WorkerHandle::spawn(
            WorkerSpec::scripted(0, ServiceDist::exponential(2.0), script.clone()),
            77,
        );
        let replayed: Vec<f64> = (0..3).map(|_| w.draw()).collect();
        assert_eq!(replayed, script);
        // past the script: sampled from the fallback law, bitwise equal
        // to a fresh stable worker on the same seed (scripted draws did
        // not consume RNG state)
        let tail: Vec<f64> = (0..5).map(|_| w.draw()).collect();
        w.shutdown();
        let fresh = WorkerHandle::spawn(WorkerSpec::stable(0, ServiceDist::exponential(2.0)), 77);
        let expect: Vec<f64> = (0..5).map(|_| fresh.draw()).collect();
        fresh.shutdown();
        assert_eq!(tail, expect);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let w = WorkerHandle::spawn(WorkerSpec::stable(4, ServiceDist::exponential(1.0)), 5);
        w.draw();
        drop(w); // must not hang or panic
    }
}
