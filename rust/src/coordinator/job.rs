//! Jobs and tasks flowing through the coordinator.

use crate::flow::Workflow;

/// A submitted job: a workflow plus bookkeeping identity.
#[derive(Clone, Debug)]
pub struct Job {
    /// Job id (coordinator-assigned).
    pub id: u64,
    /// Human-readable name.
    pub name: String,
    /// The workflow to run.
    pub workflow: Workflow,
}

/// One datum traversing a job's workflow.
#[derive(Clone, Copy, Debug)]
pub struct Task {
    /// Owning job.
    pub job_id: u64,
    /// Sequence number within the job.
    pub seq: u64,
    /// Arrival time (virtual clock).
    pub arrival: f64,
}

/// Completion record for one task.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    /// The task.
    pub task: Task,
    /// Completion time (virtual clock).
    pub finish: f64,
}

impl Completion {
    /// End-to-end latency.
    pub fn latency(&self) -> f64 {
        self.finish - self.task.arrival
    }
}
