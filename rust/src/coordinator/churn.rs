//! Cluster membership churn: servers joining and leaving at runtime.
//!
//! The paper's setting assumes a fixed pool; real clusters autoscale and
//! fail. This extension lets the coordinator add/remove workers between
//! jobs (or between re-optimization epochs), with the monitors and the
//! believed pool kept consistent — the Alg. 3 loop then simply
//! re-allocates against the new membership.

use crate::coordinator::leader::Coordinator;
use crate::coordinator::worker::{WorkerHandle, WorkerSpec};
use crate::monitor::MonitorRegistry;
use crate::scenario::record::ChurnKind;
use crate::sched::server::Server;

/// Membership operations (implemented on [`Coordinator`]).
impl Coordinator {
    /// Add a server: spawns its worker, registers a fresh monitor, and
    /// extends the believed pool with `prior` (the operator's initial
    /// estimate of the new machine's law). Returns the new server id.
    pub fn add_worker(&mut self, spec: WorkerSpec, prior: Server) -> usize {
        assert_eq!(
            spec.server_id, prior.id,
            "spec and prior must agree on the server id"
        );
        let id = spec.server_id;
        assert_eq!(
            id,
            self.workers_len(),
            "server ids must stay dense (next id = {})",
            self.workers_len()
        );
        self.push_worker(WorkerHandle::spawn(spec, self.seed()), prior);
        self.record_churn(ChurnKind::Join, id);
        if crate::obs::enabled() {
            crate::obs::event(
                "coordinator.churn",
                vec![
                    ("op".to_string(), "join".into()),
                    ("server".to_string(), id.into()),
                ],
            );
        }
        id
    }

    /// Remove (decommission) the *last* server. Dense ids keep every
    /// slot↔server index valid for ongoing jobs; removing an interior
    /// server requires draining jobs first, which the coordinator
    /// rejects by construction. Returns tasks served by that worker.
    pub fn remove_last_worker(&mut self) -> Option<u64> {
        let served = self.pop_worker().map(|w| w.shutdown());
        if served.is_some() {
            self.record_churn(ChurnKind::Leave, self.workers_len());
            if crate::obs::enabled() {
                crate::obs::event(
                    "coordinator.churn",
                    vec![
                        ("op".to_string(), "leave".into()),
                        ("server".to_string(), self.workers_len().into()),
                    ],
                );
            }
        }
        served
    }

    /// Rebuild the monitor registry after membership changes (keeps
    /// windows of surviving servers when `preserve` is true is not
    /// possible without history export, so this resets cleanly).
    pub fn reset_monitors(&mut self, window: usize, min_fit: usize) {
        let n = self.workers_len();
        *self.monitors_mut() = MonitorRegistry::new(n, window, min_fit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::dist::ServiceDist;
    use crate::flow::Workflow;
    use crate::sim::trace::{ArrivalProcess, Trace};
    use crate::util::rng::Rng;

    fn poisson(rate: f64, n: usize, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        Trace::generate(ArrivalProcess::Poisson { rate }, n, &mut rng)
    }

    #[test]
    fn scale_up_enables_bigger_workflows() {
        let servers = Server::pool_exponential(&[8.0, 7.0]);
        let cfg = CoordinatorConfig {
            reopt_every: 0,
            ..Default::default()
        };
        let mut coord = Coordinator::with_truthful_priors(servers, cfg);
        // fig6 needs 6 servers: must fail with 2
        let job6 = coord.submit("fig6", Workflow::fig6());
        assert!(coord.run_job(&job6, &poisson(1.0, 10, 1)).is_err());
        // scale up to 6
        for id in 2..6 {
            let mu = 9.0 - id as f64;
            coord.add_worker(
                WorkerSpec::stable(id, ServiceDist::exponential(mu)),
                Server::new(id, ServiceDist::exponential(mu)),
            );
        }
        let r = coord.run_job(&job6, &poisson(1.0, 2_000, 2)).unwrap();
        assert_eq!(r.metrics.completed, 2_000);
        coord.shutdown();
    }

    #[test]
    fn scale_down_then_reallocate() {
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0]);
        let cfg = CoordinatorConfig {
            reopt_every: 0,
            ..Default::default()
        };
        let mut coord = Coordinator::with_truthful_priors(servers, cfg);
        let job = coord.submit("tandem", Workflow::tandem(3, 1.0));
        let r1 = coord.run_job(&job, &poisson(1.0, 2_000, 3)).unwrap();
        // decommission the last server; job still fits on 3
        let served = coord.remove_last_worker().unwrap();
        assert!(served == 0 || served > 0); // may or may not have been used
        let r2 = coord.run_job(&job, &poisson(1.0, 2_000, 4)).unwrap();
        assert_eq!(r2.metrics.completed, 2_000);
        // with one fewer (slowest) server, latency shouldn't collapse
        assert!(r2.metrics.mean_latency() < r1.metrics.mean_latency() * 3.0);
        coord.shutdown();
    }

    #[test]
    fn monitor_reset_follows_membership() {
        let servers = Server::pool_exponential(&[5.0, 5.0]);
        let cfg = CoordinatorConfig::default();
        let mut coord = Coordinator::with_truthful_priors(servers, cfg);
        coord.add_worker(
            WorkerSpec::stable(2, ServiceDist::exponential(4.0)),
            Server::new(2, ServiceDist::exponential(4.0)),
        );
        coord.reset_monitors(512, 128);
        assert_eq!(coord.monitors().len(), 3);
        coord.shutdown();
    }

    #[test]
    #[should_panic(expected = "ids must stay dense")]
    fn sparse_ids_rejected() {
        let servers = Server::pool_exponential(&[5.0]);
        let mut coord =
            Coordinator::with_truthful_priors(servers, CoordinatorConfig::default());
        coord.add_worker(
            WorkerSpec::stable(7, ServiceDist::exponential(1.0)),
            Server::new(7, ServiceDist::exponential(1.0)),
        );
    }
}
