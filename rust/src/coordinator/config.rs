//! Coordinator configuration (programmatic + JSON).

use crate::sched::multijob::SwapEngine;
use crate::sched::{Objective, ResponseModel};
use crate::util::json::Json;

/// Allocation policy the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's scheme (Alg. 1–3).
    Proposed,
    /// §3 heuristic baseline.
    Baseline,
    /// Exhaustive optimal (small pools only).
    Optimal,
}

/// Coordinator knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// RNG seed (workers fork from it).
    pub seed: u64,
    /// Sliding-window length per server monitor.
    pub monitor_window: usize,
    /// Samples required before a parametric re-fit is trusted.
    pub min_fit_samples: usize,
    /// Re-optimization check cadence in completed tasks (0 = never).
    pub reopt_every: u64,
    /// Only swap allocations when drift is detected (vs every check).
    pub reopt_on_drift_only: bool,
    /// Allocation policy.
    pub policy: Policy,
    /// Queueing model for scoring/scheduling.
    pub model: ResponseModel,
    /// Objective for the optimal policy.
    pub objective: Objective,
    /// Swap engine multi-job planning (`run_multi`) refines with. All
    /// engines produce bit-identical plans; the knob trades raw wave
    /// throughput ([`SwapEngine::Wave`]) against memoized incremental
    /// rounds ([`SwapEngine::Incremental`]).
    pub swap_engine: SwapEngine,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            seed: 0xC0FFEE,
            monitor_window: 2048,
            min_fit_samples: 256,
            reopt_every: 1000,
            reopt_on_drift_only: true,
            policy: Policy::Proposed,
            model: ResponseModel::Mm1,
            objective: Objective::Mean,
            swap_engine: SwapEngine::Wave,
        }
    }
}

impl CoordinatorConfig {
    /// Parse from JSON (missing fields keep defaults):
    /// `{"seed": 1, "policy": "proposed", "reopt_every": 500, ...}`.
    pub fn from_json(text: &str) -> Result<CoordinatorConfig, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let mut c = CoordinatorConfig::default();
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            c.seed = x as u64;
        }
        if let Some(x) = v.get("monitor_window").and_then(Json::as_usize) {
            c.monitor_window = x;
        }
        if let Some(x) = v.get("min_fit_samples").and_then(Json::as_usize) {
            c.min_fit_samples = x;
        }
        if let Some(x) = v.get("reopt_every").and_then(Json::as_f64) {
            c.reopt_every = x as u64;
        }
        if let Some(x) = v.get("reopt_on_drift_only").and_then(Json::as_bool) {
            c.reopt_on_drift_only = x;
        }
        if let Some(p) = v.get("policy").and_then(Json::as_str) {
            c.policy = match p {
                "proposed" | "ours" => Policy::Proposed,
                "baseline" => Policy::Baseline,
                "optimal" => Policy::Optimal,
                other => return Err(format!("unknown policy '{other}'")),
            };
        }
        if let Some(m) = v.get("model").and_then(Json::as_str) {
            c.model = match m {
                "service_only" => ResponseModel::ServiceOnly,
                "mm1" => ResponseModel::Mm1,
                "mg1" => ResponseModel::Mg1,
                other => return Err(format!("unknown model '{other}'")),
            };
        }
        if let Some(o) = v.get("objective").and_then(Json::as_str) {
            c.objective = match o {
                "mean" => Objective::Mean,
                "variance" | "var" => Objective::Variance,
                "p99" => Objective::P99,
                other => return Err(format!("unknown objective '{other}'")),
            };
        }
        if let Some(e) = v.get("swap_engine").and_then(Json::as_str) {
            c.swap_engine = match e {
                "wave" => SwapEngine::Wave,
                "serial" => SwapEngine::Serial,
                "incremental" => SwapEngine::Incremental,
                other => return Err(format!("unknown swap_engine '{other}'")),
            };
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.policy, Policy::Proposed);
        assert_eq!(c.swap_engine, SwapEngine::Wave);
        assert!(c.monitor_window >= c.min_fit_samples);
    }

    #[test]
    fn json_overrides() {
        let c = CoordinatorConfig::from_json(
            r#"{"seed": 7, "policy": "baseline", "model": "mg1",
                "objective": "p99", "reopt_every": 250,
                "reopt_on_drift_only": false, "swap_engine": "incremental"}"#,
        )
        .unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.policy, Policy::Baseline);
        assert_eq!(c.model, ResponseModel::Mg1);
        assert_eq!(c.objective, Objective::P99);
        assert_eq!(c.reopt_every, 250);
        assert!(!c.reopt_on_drift_only);
        assert_eq!(c.swap_engine, SwapEngine::Incremental);
    }

    #[test]
    fn every_swap_engine_name_parses() {
        for (name, engine) in [
            ("wave", SwapEngine::Wave),
            ("serial", SwapEngine::Serial),
            ("incremental", SwapEngine::Incremental),
        ] {
            let c =
                CoordinatorConfig::from_json(&format!(r#"{{"swap_engine": "{name}"}}"#)).unwrap();
            assert_eq!(c.swap_engine, engine);
        }
    }

    #[test]
    fn bad_policy_rejected() {
        assert!(CoordinatorConfig::from_json(r#"{"policy": "nope"}"#).is_err());
        assert!(CoordinatorConfig::from_json(r#"{"swap_engine": "turbo"}"#).is_err());
        assert!(CoordinatorConfig::from_json("{bad").is_err());
    }
}
