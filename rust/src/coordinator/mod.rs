//! The L3 coordinator: the paper's Algorithm 3 as a running system.
//!
//! A leader thread owns the allocation loop; worker threads simulate the
//! heterogeneous servers (hidden service laws, real message passing,
//! virtual time — see [`worker`] for the model). The leader monitors
//! observed service times ([`crate::monitor`]), periodically re-fits the
//! believed pool, re-runs the allocator ([`crate::sched`]) and swaps
//! allocations when the cluster drifts.

pub mod api;
pub mod churn;
pub mod config;
pub mod job;
pub mod leader;
pub mod metrics;
pub mod worker;

pub use api::ApiServer;
pub use config::{CoordinatorConfig, Policy};
pub use job::{Completion, Job, Task};
pub use leader::{Coordinator, RunReport};
pub use metrics::Metrics;
pub use worker::{WorkerHandle, WorkerSpec};
