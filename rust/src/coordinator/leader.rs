//! The leader: Algorithm 3 as a running system.
//!
//! ```text
//! loop per arriving task:
//!     dispatch through the workflow tree using the current allocation
//!     (virtual per-server clocks; real worker threads draw services)
//! every reopt_every completions:
//!     refresh the believed pool from the monitors (dist::fit)
//!     if drift detected (or always, per config):
//!         re-run the allocator; swap allocations if changed
//! ```
//!
//! The leader never sees a worker's hidden law — only observed service
//! times, exactly the information the paper's Alg. 3 assumes.

use crate::coordinator::config::{CoordinatorConfig, Policy};
use crate::coordinator::job::{Completion, Job, Task};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker::{WorkerHandle, WorkerSpec};
use crate::flow::Dcc;
use crate::monitor::MonitorRegistry;
use crate::plan::{BaselinePolicy, OptimalPolicy, Planner, ProposedPolicy};
use crate::scenario::record::{ChurnKind, ExecTrace, Recorder};
use crate::sched::server::Server;
use crate::sched::{Allocation, SchedError};
use crate::sim::trace::Trace;

/// Outcome of a coordinator run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Aggregated metrics.
    pub metrics: Metrics,
    /// Allocation in force at the end of the run.
    pub final_allocation: Allocation,
    /// Allocation swaps performed (time, reason).
    pub swaps: Vec<(u64, String)>,
}

/// The coordinator: owns workers, monitors and the allocation loop.
pub struct Coordinator {
    workers: Vec<WorkerHandle>,
    /// The leader's *believed* server laws (refreshed from monitors).
    pool_view: Vec<Server>,
    monitors: MonitorRegistry,
    cfg: CoordinatorConfig,
    next_job_id: u64,
    /// Trace capture (None = recording off). See `scenario::record`.
    recorder: Option<Recorder>,
}

impl Coordinator {
    /// Spawn one worker per spec. `initial_view` is the leader's prior
    /// belief about each server's law (ids must match specs).
    pub fn new(
        specs: Vec<WorkerSpec>,
        initial_view: Vec<Server>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        assert_eq!(specs.len(), initial_view.len());
        let n = specs.len();
        let workers = specs
            .into_iter()
            .map(|s| WorkerHandle::spawn(s, cfg.seed))
            .collect();
        Coordinator {
            workers,
            pool_view: initial_view,
            monitors: MonitorRegistry::new(n, cfg.monitor_window, cfg.min_fit_samples),
            cfg,
            next_job_id: 1,
            recorder: None,
        }
    }

    /// Start capturing an execution trace ([`ExecTrace`]) for the runs
    /// that follow. `scenario` names the capture in the trace header.
    /// Replaces any capture in progress.
    pub fn start_recording(&mut self, scenario: &str) {
        self.recorder = Some(Recorder::new(scenario, self.cfg.seed, self.workers.len()));
    }

    /// Stop recording and return the captured trace (None if recording
    /// was never started).
    pub fn take_trace(&mut self) -> Option<ExecTrace> {
        self.recorder.take().map(Recorder::finish)
    }

    pub(crate) fn record_arrival(&mut self, seq: u64, at: f64) {
        if let Some(r) = self.recorder.as_mut() {
            r.arrival(seq, at);
        }
    }

    pub(crate) fn record_reopt(&mut self, completed: u64, reason: &str) {
        if let Some(r) = self.recorder.as_mut() {
            r.reopt(completed, reason);
        }
    }

    pub(crate) fn record_churn(&mut self, op: ChurnKind, server: usize) {
        if let Some(r) = self.recorder.as_mut() {
            r.churn(op, server);
        }
    }

    /// Convenience: workers that exactly match the leader's prior.
    pub fn with_truthful_priors(servers: Vec<Server>, cfg: CoordinatorConfig) -> Coordinator {
        let specs = servers
            .iter()
            .map(|s| WorkerSpec::stable(s.id, s.dist.clone()))
            .collect();
        Coordinator::new(specs, servers, cfg)
    }

    /// Create a job handle.
    pub fn submit(&mut self, name: &str, workflow: crate::flow::Workflow) -> Job {
        let id = self.next_job_id;
        self.next_job_id += 1;
        Job {
            id,
            name: name.to_string(),
            workflow,
        }
    }

    /// The leader's current believed pool.
    pub fn pool_view(&self) -> &[Server] {
        &self.pool_view
    }

    /// Monitor registry (read access for reporting).
    pub fn monitors(&self) -> &MonitorRegistry {
        &self.monitors
    }

    pub(crate) fn allocate(&self, job: &Job) -> Result<Allocation, SchedError> {
        // the dispatch loop only needs the assignment, so use the
        // planner's unscored path. NOTE: the optimal policy now searches
        // on the planner's default seed-derived *response* grid rather
        // than the old service-law auto_pool grid — a longer horizon
        // that captures queueing tails the old grid truncated, so its
        // shortlist ranking (and occasionally its winner) can differ
        // from the pre-Planner coordinator.
        let planner = Planner::new(&job.workflow, &self.pool_view)
            .model(self.cfg.model)
            .objective(self.cfg.objective);
        match self.cfg.policy {
            Policy::Proposed => planner.allocate(&ProposedPolicy::default()),
            Policy::Baseline => planner.allocate(&BaselinePolicy::default()),
            Policy::Optimal => planner.allocate(&OptimalPolicy),
        }
    }

    /// Run a job over an arrival trace to completion.
    pub fn run_job(&mut self, job: &Job, trace: &Trace) -> Result<RunReport, SchedError> {
        let mut run_span = crate::obs::span("coordinator.run_job");
        if run_span.is_recording() {
            run_span.attr("tasks", trace.arrivals.len());
            run_span.attr("servers", self.workers.len());
        }
        let mut alloc = self.allocate(job)?;
        let mut metrics = Metrics::new(self.workers.len());
        let mut swaps = Vec::new();
        let mut next_free = vec![0.0f64; self.workers.len()];

        for (seq, &arrival) in trace.arrivals.iter().enumerate() {
            let task = Task {
                job_id: job.id,
                seq: seq as u64,
                arrival,
            };
            self.record_arrival(seq as u64, arrival);
            let finish =
                self.dispatch(job.workflow.root(), &alloc, arrival, 1.0, &mut next_free, &mut metrics);
            let completion = Completion { task, finish };
            metrics.record_completion(completion.latency(), finish);

            // Algorithm 3's periodic re-optimization
            if self.cfg.reopt_every > 0 && metrics.completed % self.cfg.reopt_every == 0 {
                let drifted = self.monitors.any_drifted(self.cfg.min_fit_samples / 2);
                if drifted || !self.cfg.reopt_on_drift_only {
                    self.monitors.refresh_pool(&mut self.pool_view);
                    if let Ok(new_alloc) = self.allocate(job) {
                        if new_alloc != alloc {
                            alloc = new_alloc;
                            metrics.record_reopt();
                            let reason = if drifted { "drift" } else { "periodic" };
                            self.record_reopt(metrics.completed, reason);
                            if crate::obs::enabled() {
                                crate::obs::event(
                                    "coordinator.reopt",
                                    vec![
                                        ("completed".to_string(), metrics.completed.into()),
                                        ("reason".to_string(), reason.into()),
                                    ],
                                );
                            }
                            swaps.push((metrics.completed, reason.to_string()));
                        }
                    }
                }
            }
        }

        if crate::obs::enabled() {
            metrics.publish(crate::obs::registry());
        }
        Ok(RunReport {
            metrics,
            final_allocation: alloc,
            swaps,
        })
    }

    /// Recursive dispatch of one datum through the tree at virtual time
    /// `start`; returns the completion time.
    ///
    /// Parallel DCCs use *partitioned-data* fork–join semantics (the
    /// paper's "data is partitioned and sent through a set of DCCs in
    /// parallel"): every branch is visited, and a branch holding a
    /// fraction w_i of the DAP's scheduled rate processes w_i·n of the
    /// datum — its drawn service time is scaled by w_i·n (uniform split
    /// ⇒ scale 1). This is what makes Algorithm 2's rate schedule
    /// meaningful on the live path: equilibrium splits balance branch
    /// completion times, uniform splits let the slowest branch dominate
    /// the join. (The steady-state DES in `sim::network` instead models
    /// rate-split stations, matching the Eq. 1–3 analytics; the two
    /// semantics are cross-compared in EXPERIMENTS.md.)
    pub(crate) fn dispatch(
        &mut self,
        node: &Dcc,
        alloc: &Allocation,
        start: f64,
        scale: f64,
        next_free: &mut [f64],
        metrics: &mut Metrics,
    ) -> f64 {
        match node {
            Dcc::Queue { slot } => {
                let sid = alloc.server_for(*slot);
                let drawn = self.workers[sid].draw();
                if let Some(r) = self.recorder.as_mut() {
                    // capture the *raw* draw: replay re-applies scaling
                    r.service(sid, drawn);
                }
                let service = drawn * scale;
                let begin = start.max(next_free[sid]);
                let finish = begin + service;
                next_free[sid] = finish;
                // monitors see the *unit* service time (the server's own
                // speed), not the data-share-scaled one
                self.monitors.observe(sid, service / scale.max(1e-12));
                metrics.record_service(sid, service);
                finish
            }
            Dcc::Serial { children, .. } => {
                let mut t = start;
                for c in children {
                    t = self.dispatch(c, alloc, t, scale, next_free, metrics);
                }
                t
            }
            Dcc::Parallel { children, .. } => {
                // partitioned fork–join: branch i gets data share w_i
                let rates: Vec<f64> = children
                    .iter()
                    .map(|c| Self::entry_rate(c, alloc))
                    .collect();
                let total: f64 = rates.iter().sum();
                let n = children.len() as f64;
                children
                    .iter()
                    .zip(&rates)
                    .map(|(c, &r)| {
                        let w = if total > 0.0 { r * n / total } else { 1.0 };
                        self.dispatch(c, alloc, start, scale * w, next_free, metrics)
                    })
                    .fold(start, f64::max)
            }
        }
    }

    /// Scheduled arrival rate at a branch's entry DAP (its first leaf).
    fn entry_rate(node: &Dcc, alloc: &Allocation) -> f64 {
        match node {
            Dcc::Queue { slot } => alloc.rate_for(*slot),
            Dcc::Serial { children, .. } | Dcc::Parallel { children, .. } => children
                .first()
                .map(|c| Self::entry_rate(c, alloc))
                .unwrap_or(0.0),
        }
    }

    /// Shut all workers down; returns per-worker served counts.
    pub fn shutdown(self) -> Vec<u64> {
        self.workers.into_iter().map(|w| w.shutdown()).collect()
    }

    // ---- membership plumbing (see coordinator::churn) -------------------

    pub(crate) fn workers_len(&self) -> usize {
        self.workers.len()
    }

    pub(crate) fn seed(&self) -> u64 {
        self.cfg.seed
    }

    pub(crate) fn push_worker(&mut self, w: crate::coordinator::worker::WorkerHandle, prior: Server) {
        self.workers.push(w);
        self.pool_view.push(prior);
        let window = self.cfg.monitor_window;
        let min_fit = self.cfg.min_fit_samples;
        let n = self.workers.len();
        // extend the registry by rebuilding (windows restart for all —
        // acceptable at membership-change epochs)
        self.monitors = crate::monitor::MonitorRegistry::new(n, window, min_fit);
    }

    pub(crate) fn pop_worker(&mut self) -> Option<crate::coordinator::worker::WorkerHandle> {
        let w = self.workers.pop();
        if w.is_some() {
            self.pool_view.pop();
            let n = self.workers.len();
            self.monitors = crate::monitor::MonitorRegistry::new(
                n,
                self.cfg.monitor_window,
                self.cfg.min_fit_samples,
            );
        }
        w
    }

    pub(crate) fn monitors_mut(&mut self) -> &mut crate::monitor::MonitorRegistry {
        &mut self.monitors
    }

    pub(crate) fn config(&self) -> CoordinatorConfig {
        self.cfg
    }

    /// Refresh the believed pool from the monitors' fitted laws;
    /// returns the number of servers whose belief changed. (Exposed for
    /// the `scenario::Replay` driver, which re-implements the
    /// dispatch/re-optimization loop outside this module.)
    pub(crate) fn refresh_pool_view(&mut self) -> usize {
        self.monitors.refresh_pool(&mut self.pool_view)
    }

    /// Run several jobs concurrently over one shared cluster: the pool is
    /// partitioned with [`Planner::plan_jobs`], then arrivals from all
    /// traces are interleaved in time order and dispatched against each
    /// job's own allocation (server clocks are shared — a slow cluster
    /// shows up in every job's tail).
    pub fn run_multi(
        &mut self,
        jobs: &[(Job, Trace)],
        objective: crate::sched::Objective,
    ) -> Result<Vec<RunReport>, SchedError> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let mut run_span = crate::obs::span("coordinator.run_multi");
        if run_span.is_recording() {
            run_span.attr("jobs", jobs.len());
        }
        let wfs: Vec<&crate::flow::Workflow> =
            jobs.iter().map(|(j, _)| &j.workflow).collect();
        let plans = Planner::new(wfs[0], &self.pool_view)
            .model(self.cfg.model)
            .objective(objective)
            .swap_engine(self.cfg.swap_engine)
            .plan_jobs(&wfs)?;

        // merge arrivals: (time, job index, seq)
        let mut events: Vec<(f64, usize, u64)> = Vec::new();
        for (ji, (_, trace)) in jobs.iter().enumerate() {
            for (seq, &t) in trace.arrivals.iter().enumerate() {
                events.push((t, ji, seq as u64));
            }
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let mut next_free = vec![0.0f64; self.workers.len()];
        let mut metrics: Vec<Metrics> = jobs
            .iter()
            .map(|_| Metrics::new(self.workers.len()))
            .collect();
        for (t, ji, _seq) in events {
            let alloc = &plans[ji].alloc;
            let root = jobs[ji].0.workflow.root().clone();
            let finish = self.dispatch(&root, alloc, t, 1.0, &mut next_free, &mut metrics[ji]);
            metrics[ji].record_completion(finish - t, finish);
        }
        Ok(plans
            .into_iter()
            .zip(metrics)
            .map(|(plan, m)| RunReport {
                metrics: m,
                final_allocation: plan.alloc,
                swaps: Vec::new(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;
    use crate::flow::Workflow;
    use crate::sim::trace::{ArrivalProcess, Trace};
    use crate::util::rng::Rng;

    fn poisson_trace(rate: f64, n: usize, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        Trace::generate(ArrivalProcess::Poisson { rate }, n, &mut rng)
    }

    fn quiet_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            reopt_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn runs_fig6_end_to_end() {
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let mut coord = Coordinator::with_truthful_priors(servers, quiet_cfg());
        let job = coord.submit("fig6", Workflow::fig6());
        let trace = poisson_trace(2.0, 5_000, 11);
        let report = coord.run_job(&job, &trace).unwrap();
        assert_eq!(report.metrics.completed, 5_000);
        assert!(report.metrics.mean_latency() > 0.0);
        assert!(report.metrics.latency_quantile(0.99) > report.metrics.mean_latency());
        let served = coord.shutdown();
        // every dispatch hits all 6 slots (fork-join counts each branch)
        assert_eq!(served.iter().sum::<u64>(), 5_000 * 6);
    }

    #[test]
    fn proposed_beats_baseline_latency() {
        let run = |policy: Policy| {
            let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
            let cfg = CoordinatorConfig {
                policy,
                reopt_every: 0,
                ..Default::default()
            };
            let mut coord = Coordinator::with_truthful_priors(servers, cfg);
            let job = coord.submit("fig6", Workflow::fig6());
            let trace = poisson_trace(3.0, 30_000, 13);
            let r = coord.run_job(&job, &trace).unwrap();
            coord.shutdown();
            r.metrics.mean_latency()
        };
        let ours = run(Policy::Proposed);
        let base = run(Policy::Baseline);
        assert!(
            ours < base,
            "proposed {ours} should beat baseline {base}"
        );
    }

    #[test]
    fn drift_triggers_reallocation() {
        // server 0 starts fast, degrades badly; the monitor must catch it
        // and the coordinator must swap the allocation
        let mut specs: Vec<WorkerSpec> = (0..6)
            .map(|i| {
                WorkerSpec::stable(i, ServiceDist::exponential([9.0, 8.0, 7.0, 6.0, 5.0, 4.0][i]))
            })
            .collect();
        specs[0] = WorkerSpec::drifting(
            0,
            ServiceDist::exponential(9.0),
            4_000,
            ServiceDist::exponential(1.5),
        );
        let view = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let cfg = CoordinatorConfig {
            reopt_every: 500,
            min_fit_samples: 256,
            monitor_window: 1024,
            ..Default::default()
        };
        let mut coord = Coordinator::new(specs, view, cfg);
        let job = coord.submit("fig6", Workflow::fig6());
        let trace = poisson_trace(2.0, 20_000, 17);
        let report = coord.run_job(&job, &trace).unwrap();
        coord.shutdown();
        assert!(
            report.metrics.reoptimizations >= 1,
            "expected at least one swap, got {:?}",
            report.swaps
        );
        // after refresh, the leader's belief about server 0 must be slow
        // (lam near 1.5, i.e. mean near 0.67)
    }

    #[test]
    fn static_run_never_swaps() {
        let servers = Server::pool_exponential(&[5.0, 5.0, 4.0]);
        let mut coord = Coordinator::with_truthful_priors(servers, quiet_cfg());
        let job = coord.submit("tandem", Workflow::tandem(3, 1.0));
        let trace = poisson_trace(1.0, 2_000, 19);
        let report = coord.run_job(&job, &trace).unwrap();
        coord.shutdown();
        assert_eq!(report.metrics.reoptimizations, 0);
        assert!(report.swaps.is_empty());
    }

    #[test]
    fn utilization_accounting_consistent() {
        let servers = Server::pool_exponential(&[4.0, 4.0]);
        let mut coord = Coordinator::with_truthful_priors(servers, quiet_cfg());
        let job = coord.submit("fj", Workflow::forkjoin(2, 1.0));
        let trace = poisson_trace(1.0, 5_000, 23);
        let report = coord.run_job(&job, &trace).unwrap();
        coord.shutdown();
        for sid in 0..2 {
            let u = report.metrics.utilization(sid);
            assert!(u > 0.0 && u < 1.0, "utilization {u}");
            assert_eq!(report.metrics.tasks_per_server[sid], 5_000);
        }
    }
}
