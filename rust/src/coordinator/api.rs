//! JSON-over-TCP service API: the deployment face of the coordinator.
//!
//! A thin line-delimited JSON protocol (one request object per line, one
//! response object per line) so operators and sidecars can drive the
//! scheduler without linking rust:
//!
//! ```text
//! -> {"cmd":"score",    "workflow": {...}, "servers":[9,8,7], "model":"mm1"}
//! <- {"ok":true, "policies": {"proposed": {"mean":..,"var":..,"p99":..}, ...}}
//! -> {"cmd":"allocate", "workflow": {...}, "servers":[...]}
//! <- {"ok":true, "slots":[2,0,1], "rates":[4.0,4.0,2.0], "mean":...}
//! -> {"cmd":"capacity", "workflow": {...}, "servers":[...], "sla_mean": 2.0}
//! <- {"ok":true, "max_throughput":.., "sla_throughput":..}
//! -> {"cmd":"ping"}            <- {"ok":true,"service":"dcflow"}
//! -> {"cmd":"shutdown"}        <- {"ok":true}   (server exits)
//! ```
//!
//! Implementation: std TCP listener + one thread per connection (the
//! scheduler calls are CPU-bound and short; no async runtime exists in
//! the vendored crate set, and none is needed at this request scale).

use crate::flow::parse::workflow_from_json;
use crate::flow::Workflow;
use crate::plan::{BaselinePolicy, Planner, ProposedPolicy};
use crate::sched::capacity::{max_throughput, max_throughput_under_sla, Sla};
use crate::sched::server::Server;
use crate::sched::ResponseModel;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Handle to a running API server.
pub struct ApiServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ApiServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(addr: &str) -> std::io::Result<ApiServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("dcflow-api".into())
            .spawn(move || serve(listener, stop2))
            .expect("spawn api server");
        Ok(ApiServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop the server and join its thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ApiServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve(listener: TcpListener, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let stop = stop.clone();
                std::thread::spawn(move || handle_conn(stream, stop));
            }
            Err(_) => break,
        }
    }
}

fn handle_conn(stream: TcpStream, stop: Arc<AtomicBool>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let Ok(mut writer) = peer else { return };
    for line in reader.lines() {
        let Ok(line) = line else { return };
        if line.trim().is_empty() {
            continue;
        }
        let resp = dispatch(&line, &stop);
        let _ = writeln!(writer, "{}", resp.to_string());
        if stop.load(Ordering::SeqCst) {
            return;
        }
    }
}

fn err(msg: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Json::Bool(false));
    m.insert("error".into(), Json::Str(msg.into()));
    Json::Obj(m)
}

fn parse_pool(v: &Json) -> Result<Vec<Server>, String> {
    let arr = v
        .get("servers")
        .and_then(Json::as_arr)
        .ok_or("missing 'servers' array")?;
    let rates: Vec<f64> = arr
        .iter()
        .map(|x| x.as_f64().ok_or("non-numeric server rate".to_string()))
        .collect::<Result<_, _>>()?;
    if rates.is_empty() {
        return Err("empty server pool".into());
    }
    Ok(Server::pool_exponential(&rates))
}

fn parse_model(v: &Json) -> Result<ResponseModel, String> {
    match v.get("model").and_then(Json::as_str).unwrap_or("mm1") {
        "service_only" => Ok(ResponseModel::ServiceOnly),
        "mm1" => Ok(ResponseModel::Mm1),
        "mg1" => Ok(ResponseModel::Mg1),
        other => Err(format!("unknown model '{other}'")),
    }
}

fn parse_workflow(v: &Json) -> Result<Workflow, String> {
    let wf_v = v.get("workflow").ok_or("missing 'workflow'")?;
    workflow_from_json(&wf_v.to_string()).map_err(|e| e.to_string())
}

fn score_obj(mean: f64, var: f64, p99: f64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mean".into(), Json::Num(mean));
    m.insert("var".into(), Json::Num(var));
    m.insert("p99".into(), Json::Num(p99));
    Json::Obj(m)
}

fn dispatch(line: &str, stop: &AtomicBool) -> Json {
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("");
    match cmd {
        "ping" => {
            let mut m = BTreeMap::new();
            m.insert("ok".into(), Json::Bool(true));
            m.insert("service".into(), Json::Str("dcflow".into()));
            m.insert(
                "version".into(),
                Json::Str(env!("CARGO_PKG_VERSION").into()),
            );
            Json::Obj(m)
        }
        "shutdown" => {
            stop.store(true, Ordering::SeqCst);
            let mut m = BTreeMap::new();
            m.insert("ok".into(), Json::Bool(true));
            Json::Obj(m)
        }
        "score" => match cmd_score(&req) {
            Ok(v) => v,
            Err(e) => err(&e),
        },
        "allocate" => match cmd_allocate(&req) {
            Ok(v) => v,
            Err(e) => err(&e),
        },
        "capacity" => match cmd_capacity(&req) {
            Ok(v) => v,
            Err(e) => err(&e),
        },
        other => err(&format!("unknown cmd '{other}'")),
    }
}

fn cmd_score(req: &Json) -> Result<Json, String> {
    let wf = parse_workflow(req)?;
    let servers = parse_pool(req)?;
    let model = parse_model(req)?;
    let planner = Planner::new(&wf, &servers).model(model);
    let mut results = planner
        .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default()])
        .into_iter();
    // the documented response shape requires "proposed"; it failing is
    // a request-level error (as it was pre-Planner). "baseline" stays
    // best-effort.
    let proposed = results
        .next()
        .expect("two policies submitted")
        .map_err(|e| e.to_string())?;
    let mut policies = BTreeMap::new();
    policies.insert(
        proposed.policy_name,
        score_obj(proposed.score.mean, proposed.score.var, proposed.score.p99),
    );
    if let Some(Ok(plan)) = results.next() {
        policies.insert(
            plan.policy_name,
            score_obj(plan.score.mean, plan.score.var, plan.score.p99),
        );
    }
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Json::Bool(true));
    m.insert("policies".into(), Json::Obj(policies));
    Ok(Json::Obj(m))
}

fn cmd_allocate(req: &Json) -> Result<Json, String> {
    let wf = parse_workflow(req)?;
    let servers = parse_pool(req)?;
    let model = parse_model(req)?;
    let plan = Planner::new(&wf, &servers)
        .model(model)
        .plan(&ProposedPolicy::default())
        .map_err(|e| e.to_string())?;
    let (alloc, score) = (plan.allocation, plan.score);
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Json::Bool(true));
    m.insert(
        "slots".into(),
        Json::Arr(
            alloc
                .slot_server
                .iter()
                .map(|&s| Json::Num(s as f64))
                .collect(),
        ),
    );
    m.insert(
        "rates".into(),
        Json::Arr(alloc.slot_rate.iter().map(|&r| Json::Num(r)).collect()),
    );
    m.insert("score".into(), score_obj(score.mean, score.var, score.p99));
    Ok(Json::Obj(m))
}

fn cmd_capacity(req: &Json) -> Result<Json, String> {
    let wf = parse_workflow(req)?;
    let servers = parse_pool(req)?;
    let model = parse_model(req)?;
    let raw = max_throughput(&wf, &servers, model).map_err(|e| e.to_string())?;
    let mut m = BTreeMap::new();
    m.insert("ok".into(), Json::Bool(true));
    m.insert("max_throughput".into(), Json::Num(raw));
    if let Some(b) = req.get("sla_mean").and_then(Json::as_f64) {
        let t = max_throughput_under_sla(&wf, &servers, model, Sla::Mean(b))
            .map_err(|e| e.to_string())?;
        m.insert("sla_throughput".into(), Json::Num(t));
    }
    if let Some(b) = req.get("sla_p99").and_then(Json::as_f64) {
        let t = max_throughput_under_sla(&wf, &servers, model, Sla::P99(b))
            .map_err(|e| e.to_string())?;
        m.insert("sla_p99_throughput".into(), Json::Num(t));
    }
    Ok(Json::Obj(m))
}

/// Blocking one-shot client for the line protocol (used by the CLI and
/// tests).
pub fn request(addr: std::net::SocketAddr, req: &str) -> std::io::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| std::io::Error::other(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: single-line (the wire protocol is line-delimited)
    const FIG6ISH: &str = r#"{"type":"serial","children":[{"type":"parallel","rate":8.0,"children":[{"type":"queue"},{"type":"queue"}]},{"type":"queue","rate":4.0}]}"#;

    fn req_with_workflow(cmd: &str, extra: &str) -> String {
        format!(
            r#"{{"cmd":"{cmd}","workflow":{{"arrival_rate":8.0,"root":{FIG6ISH}}},"servers":[9,8,7]{extra}}}"#
        )
    }

    #[test]
    fn ping_roundtrip() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let resp = request(srv.addr(), r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("service").and_then(Json::as_str), Some("dcflow"));
        srv.stop();
    }

    #[test]
    fn allocate_over_the_wire() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let resp = request(srv.addr(), &req_with_workflow("allocate", "")).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let slots = resp.get("slots").and_then(Json::as_arr).unwrap();
        assert_eq!(slots.len(), 3);
        let mean = resp
            .get("score")
            .and_then(|s| s.get("mean"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(mean > 0.0 && mean.is_finite());
        srv.stop();
    }

    #[test]
    fn score_compares_policies() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let resp = request(srv.addr(), &req_with_workflow("score", "")).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let pol = resp.get("policies").unwrap();
        assert!(pol.get("proposed").is_some());
        assert!(pol.get("baseline").is_some());
        srv.stop();
    }

    #[test]
    fn capacity_with_sla() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let resp =
            request(srv.addr(), &req_with_workflow("capacity", r#","sla_mean":1.0"#)).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let raw = resp.get("max_throughput").and_then(Json::as_f64).unwrap();
        let sla = resp.get("sla_throughput").and_then(Json::as_f64).unwrap();
        assert!(sla <= raw && sla > 0.0);
        srv.stop();
    }

    #[test]
    fn bad_requests_get_errors_not_disconnects() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        for bad in [
            "{not json",
            r#"{"cmd":"nope"}"#,
            r#"{"cmd":"allocate"}"#,
            r#"{"cmd":"allocate","workflow":{"arrival_rate":1,"root":{"type":"queue"}},"servers":[]}"#,
        ] {
            let resp = request(srv.addr(), bad).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "req {bad}");
            assert!(resp.get("error").is_some());
        }
        srv.stop();
    }

    #[test]
    fn shutdown_stops_server() {
        let srv = ApiServer::start("127.0.0.1:0").unwrap();
        let addr = srv.addr();
        let resp = request(addr, r#"{"cmd":"shutdown"}"#).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        srv.stop();
        // subsequent connections should fail (listener gone)
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(request(addr, r#"{"cmd":"ping"}"#).is_err());
    }
}
