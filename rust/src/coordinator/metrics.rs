//! Coordinator metrics: latency, throughput, utilization, re-planning.

use crate::util::stats::{quantile, Welford};

/// Aggregated run metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latency: Welford,
    latencies: Vec<f64>,
    /// Busy time accumulated per server (virtual seconds).
    pub busy_time: Vec<f64>,
    /// Number of tasks dispatched to each server.
    pub tasks_per_server: Vec<u64>,
    /// Tasks completed end-to-end.
    pub completed: u64,
    /// Re-optimization events (allocation swaps).
    pub reoptimizations: u64,
    /// Virtual time of the last completion.
    pub makespan: f64,
}

impl Metrics {
    /// Metrics for `n_servers` servers.
    pub fn new(n_servers: usize) -> Metrics {
        Metrics {
            busy_time: vec![0.0; n_servers],
            tasks_per_server: vec![0; n_servers],
            ..Default::default()
        }
    }

    /// Record a completed task.
    pub fn record_completion(&mut self, latency: f64, finish: f64) {
        self.latency.push(latency);
        self.latencies.push(latency);
        self.completed += 1;
        self.makespan = self.makespan.max(finish);
    }

    /// Grow the per-server vectors to cover at least `n` servers
    /// (mid-run membership churn adds servers; shrinking is never done
    /// so decommissioned servers keep their accumulated counters).
    pub fn ensure_servers(&mut self, n: usize) {
        if self.busy_time.len() < n {
            self.busy_time.resize(n, 0.0);
            self.tasks_per_server.resize(n, 0);
        }
    }

    /// Record one server-side service interval.
    pub fn record_service(&mut self, server_id: usize, service_time: f64) {
        self.busy_time[server_id] += service_time;
        self.tasks_per_server[server_id] += 1;
    }

    /// Record an allocation swap.
    pub fn record_reopt(&mut self) {
        self.reoptimizations += 1;
    }

    /// Mean end-to-end latency.
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// Latency variance.
    pub fn var_latency(&self) -> f64 {
        self.latency.variance()
    }

    /// Latency quantile (q in [0,1]).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quantile(&v, q)
    }

    /// Completed tasks per virtual second.
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.makespan
    }

    /// Utilization of a server over the run.
    pub fn utilization(&self, server_id: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.busy_time[server_id] / self.makespan
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "tasks={} mean={:.4} var={:.4} p50={:.4} p99={:.4} thru={:.3}/s reopt={}",
            self.completed,
            self.mean_latency(),
            self.var_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.throughput(),
            self.reoptimizations
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new(2);
        m.record_completion(1.0, 10.0);
        m.record_completion(3.0, 12.0);
        m.record_service(0, 0.5);
        m.record_service(1, 2.0);
        m.record_service(1, 1.0);
        m.record_reopt();
        assert_eq!(m.completed, 2);
        assert!((m.mean_latency() - 2.0).abs() < 1e-12);
        assert!((m.var_latency() - 1.0).abs() < 1e-12);
        assert_eq!(m.tasks_per_server, vec![1, 2]);
        assert!((m.utilization(1) - 3.0 / 12.0).abs() < 1e-12);
        assert!((m.throughput() - 2.0 / 12.0).abs() < 1e-12);
        assert!(m.summary().contains("tasks=2"));
    }

    #[test]
    fn ensure_servers_grows_but_never_shrinks() {
        let mut m = Metrics::new(2);
        m.record_service(1, 1.5);
        m.ensure_servers(4);
        assert_eq!(m.busy_time.len(), 4);
        assert_eq!(m.tasks_per_server, vec![0, 1, 0, 0]);
        m.record_service(3, 0.5);
        m.ensure_servers(1); // no-op
        assert_eq!(m.busy_time.len(), 4);
        assert!((m.busy_time[3] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new(1);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.latency_quantile(0.99), 0.0);
    }
}
