//! Coordinator metrics: latency, throughput, utilization, re-planning.

use std::sync::Mutex;

use crate::util::stats::{quantile, Welford};

/// Aggregated run metrics.
///
/// Latency keeps two accumulators on purpose: the streaming [`Welford`]
/// for mean/variance and the raw sample vector for exact quantiles.
/// The golden-trace corpus (`scenario::golden`) pins the *bits* of
/// `mean_latency`/`var_latency`/`latency_quantile` across versions, so
/// neither side can be rederived from the other without perturbing
/// float results. The coarse registry histogram published by
/// [`Metrics::publish`] is a lossy *view* for dashboards, not a
/// replacement for either.
#[derive(Debug, Default)]
pub struct Metrics {
    latency: Welford,
    latencies: Vec<f64>,
    /// Sorted copy of `latencies`, rebuilt lazily: `latencies` is
    /// append-only, so the cache is stale exactly when the lengths
    /// differ. Interior-mutable so `latency_quantile(&self)` keeps its
    /// signature.
    sorted_cache: Mutex<Vec<f64>>,
    /// Busy time accumulated per server (virtual seconds).
    pub busy_time: Vec<f64>,
    /// Number of tasks dispatched to each server.
    pub tasks_per_server: Vec<u64>,
    /// Tasks completed end-to-end.
    pub completed: u64,
    /// Re-optimization events (allocation swaps).
    pub reoptimizations: u64,
    /// Virtual time of the last completion.
    pub makespan: f64,
}

impl Clone for Metrics {
    fn clone(&self) -> Metrics {
        Metrics {
            latency: self.latency.clone(),
            latencies: self.latencies.clone(),
            // the clone revalidates lazily on its first quantile call
            sorted_cache: Mutex::new(Vec::new()),
            busy_time: self.busy_time.clone(),
            tasks_per_server: self.tasks_per_server.clone(),
            completed: self.completed,
            reoptimizations: self.reoptimizations,
            makespan: self.makespan,
        }
    }
}

impl Metrics {
    /// Metrics for `n_servers` servers.
    pub fn new(n_servers: usize) -> Metrics {
        Metrics {
            busy_time: vec![0.0; n_servers],
            tasks_per_server: vec![0; n_servers],
            ..Default::default()
        }
    }

    /// Record a completed task.
    pub fn record_completion(&mut self, latency: f64, finish: f64) {
        self.latency.push(latency);
        self.latencies.push(latency);
        self.completed += 1;
        self.makespan = self.makespan.max(finish);
    }

    /// Grow the per-server vectors to cover at least `n` servers
    /// (mid-run membership churn adds servers; shrinking is never done
    /// so decommissioned servers keep their accumulated counters).
    pub fn ensure_servers(&mut self, n: usize) {
        if self.busy_time.len() < n {
            self.busy_time.resize(n, 0.0);
            self.tasks_per_server.resize(n, 0);
        }
    }

    /// Record one server-side service interval.
    pub fn record_service(&mut self, server_id: usize, service_time: f64) {
        self.busy_time[server_id] += service_time;
        self.tasks_per_server[server_id] += 1;
    }

    /// Record an allocation swap.
    pub fn record_reopt(&mut self) {
        self.reoptimizations += 1;
    }

    /// Mean end-to-end latency.
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// Latency variance.
    pub fn var_latency(&self) -> f64 {
        self.latency.variance()
    }

    /// Latency quantile (q in [0,1]). Exact (type-7 interpolated over
    /// every sample). The sort is cached and only redone after new
    /// completions, so `summary()`-style repeated calls sort once; NaN
    /// samples order last via `total_cmp` instead of panicking.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut cache = self.sorted_cache.lock().expect("latency cache lock");
        if cache.len() != self.latencies.len() {
            cache.clear();
            cache.extend_from_slice(&self.latencies);
            cache.sort_by(f64::total_cmp);
        }
        quantile(cache.as_slice(), q)
    }

    /// Completed tasks per virtual second.
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.makespan
    }

    /// Utilization of a server over the run.
    pub fn utilization(&self, server_id: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.busy_time[server_id] / self.makespan
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "tasks={} mean={:.4} var={:.4} p50={:.4} p99={:.4} thru={:.3}/s reopt={}",
            self.completed,
            self.mean_latency(),
            self.var_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.throughput(),
            self.reoptimizations
        )
    }

    /// Publish this run's totals into a telemetry [`Registry`]
    /// (`coordinator.*` namespace): completion/re-plan counters, the
    /// makespan/mean/throughput gauges, and a fixed-bucket
    /// `coordinator.latency` histogram spanning the observed range.
    ///
    /// [`Registry`]: crate::obs::Registry
    pub fn publish(&self, registry: &crate::obs::Registry) {
        registry.counter("coordinator.completed").add(self.completed);
        registry
            .counter("coordinator.reoptimizations")
            .add(self.reoptimizations);
        registry.gauge("coordinator.makespan").set(self.makespan);
        registry
            .gauge("coordinator.mean_latency")
            .set(self.mean_latency());
        registry
            .gauge("coordinator.throughput")
            .set(self.throughput());
        let hi = self
            .latencies
            .iter()
            .copied()
            .filter(|x| x.is_finite())
            .fold(0.0_f64, f64::max);
        let hist =
            registry.histogram("coordinator.latency", 0.0, if hi > 0.0 { hi } else { 1.0 }, 64);
        for &x in &self.latencies {
            hist.record(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new(2);
        m.record_completion(1.0, 10.0);
        m.record_completion(3.0, 12.0);
        m.record_service(0, 0.5);
        m.record_service(1, 2.0);
        m.record_service(1, 1.0);
        m.record_reopt();
        assert_eq!(m.completed, 2);
        assert!((m.mean_latency() - 2.0).abs() < 1e-12);
        assert!((m.var_latency() - 1.0).abs() < 1e-12);
        assert_eq!(m.tasks_per_server, vec![1, 2]);
        assert!((m.utilization(1) - 3.0 / 12.0).abs() < 1e-12);
        assert!((m.throughput() - 2.0 / 12.0).abs() < 1e-12);
        assert!(m.summary().contains("tasks=2"));
    }

    #[test]
    fn ensure_servers_grows_but_never_shrinks() {
        let mut m = Metrics::new(2);
        m.record_service(1, 1.5);
        m.ensure_servers(4);
        assert_eq!(m.busy_time.len(), 4);
        assert_eq!(m.tasks_per_server, vec![0, 1, 0, 0]);
        m.record_service(3, 0.5);
        m.ensure_servers(1); // no-op
        assert_eq!(m.busy_time.len(), 4);
        assert!((m.busy_time[3] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new(1);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.latency_quantile(0.99), 0.0);
    }

    #[test]
    fn nan_latency_does_not_panic_quantiles() {
        // regression: partial_cmp().unwrap() used to panic here
        let mut m = Metrics::new(1);
        m.record_completion(1.0, 1.0);
        m.record_completion(f64::NAN, 2.0);
        m.record_completion(2.0, 3.0);
        // total_cmp orders NaN after every finite sample, so the median
        // of [1.0, 2.0, NaN] is exactly 2.0 (type-7: h = 1.0)
        assert_eq!(m.latency_quantile(0.5), 2.0);
        assert_eq!(m.latency_quantile(0.0), 1.0);
        assert!(m.latency_quantile(1.0).is_nan());
    }

    #[test]
    fn quantile_cache_tracks_new_completions() {
        let mut m = Metrics::new(1);
        m.record_completion(5.0, 1.0);
        assert_eq!(m.latency_quantile(1.0), 5.0);
        // a second call reuses the cache; a new sample invalidates it
        assert_eq!(m.latency_quantile(0.0), 5.0);
        m.record_completion(1.0, 2.0);
        assert_eq!(m.latency_quantile(0.0), 1.0);
        assert_eq!(m.latency_quantile(1.0), 5.0);
        // clones start with a cold cache but agree
        let c = m.clone();
        assert_eq!(c.latency_quantile(0.5), m.latency_quantile(0.5));
    }

    #[test]
    fn publish_exports_registry_views() {
        let mut m = Metrics::new(1);
        m.record_completion(1.0, 2.0);
        m.record_completion(3.0, 4.0);
        m.record_reopt();
        let r = crate::obs::Registry::default();
        m.publish(&r);
        assert_eq!(r.counter("coordinator.completed").get(), 2);
        assert_eq!(r.counter("coordinator.reoptimizations").get(), 1);
        assert_eq!(r.gauge("coordinator.makespan").get(), 4.0);
        assert!((r.gauge("coordinator.mean_latency").get() - 2.0).abs() < 1e-12);
        let snap = r.snapshot();
        let (_, hist) = snap
            .histograms
            .iter()
            .find(|(n, _)| n == "coordinator.latency")
            .expect("latency histogram published");
        assert_eq!(hist.count, 2);
        assert_eq!(hist.max, 3.0);
    }
}
