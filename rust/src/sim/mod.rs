//! Discrete-event simulation of data computing flows.
//!
//! Validates the analytic engine and regenerates the paper's figures:
//! exact Lindley-recursion station dynamics ([`queueing`]), recursive
//! series/parallel composition over workflows ([`network`]), and
//! synthetic arrival traces ([`trace`]).

pub mod network;
pub mod queueing;
pub mod trace;
