//! Whole-workflow simulation: the Monte-Carlo twin of the analytic
//! composition engine.
//!
//! Semantics (matching the paper's model and our analytic engine):
//! every station runs at its *scheduled* steady-state load — leaf slot i
//! receives Poisson(λ_i) arrivals of its own — and the end-to-end
//! response of a virtual datum is
//!
//! * serial DCC:   sum of per-stage response samples (Eq. 1's
//!   independence),
//! * parallel DCC: max over branch response samples (Eq. 3's fork–join).
//!
//! Because each station is simulated with the exact Lindley recursion,
//! the simulator captures true M/G/1 queueing that the analytic M/M/1 /
//! P-K models only approximate — this gap is part of what Table 2's
//! "our approach vs optimal" columns measure.

use crate::flow::{Dcc, Workflow};
use crate::sched::server::Server;
use crate::sched::Allocation;
use crate::sim::queueing::{sample_service, simulate_station};
use crate::util::rng::Rng;
use crate::util::stats::{quantile, Welford};

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Post-warmup samples per station (= end-to-end samples produced).
    pub n_tasks: usize,
    /// Warmup tasks discarded per station.
    pub warmup: usize,
    /// RNG seed (every run is reproducible).
    pub seed: u64,
    /// true: stations queue (Lindley); false: response = service draw
    /// (the Fig. 2/3 setting).
    pub queueing: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_tasks: 100_000,
            warmup: 5_000,
            seed: 0xDCF10,
            queueing: true,
        }
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Mean end-to-end response time.
    pub mean: f64,
    /// Variance.
    pub var: f64,
    /// Median.
    pub p50: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Sorted end-to-end samples (for CDF plots / KS tests).
    pub samples: Vec<f64>,
}

impl SimResult {
    fn from_samples(mut samples: Vec<f64>) -> SimResult {
        let mut w = Welford::new();
        samples.iter().for_each(|&x| w.push(x));
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        SimResult {
            mean: w.mean(),
            var: w.variance(),
            p50: quantile(&samples, 0.5),
            p99: quantile(&samples, 0.99),
            samples,
        }
    }

    /// Empirical CDF of the samples evaluated at `t`.
    ///
    /// Edge behavior is pinned: queries below the first sample return
    /// exactly `0.0`, queries at or above the last sample exactly
    /// `1.0`, and an empty sample set is `0.0` everywhere (it used to
    /// divide `0/0` into NaN). `NaN` queries sort below every sample
    /// and yield `0.0`.
    pub fn cdf_at(&self, t: f64) -> f64 {
        let idx = self.samples.partition_point(|&x| x <= t);
        if idx == 0 {
            return 0.0; // empty set, below-first query, or NaN query
        }
        if idx == self.samples.len() {
            return 1.0;
        }
        idx as f64 / self.samples.len() as f64
    }
}

/// Simulate a workflow under an allocation.
pub fn simulate(
    wf: &Workflow,
    alloc: &Allocation,
    servers: &[Server],
    cfg: &SimConfig,
) -> SimResult {
    let mut rng = Rng::new(cfg.seed);
    let samples = node_samples(wf.root(), alloc, servers, cfg, &mut rng);
    SimResult::from_samples(samples)
}

fn node_samples(
    node: &Dcc,
    alloc: &Allocation,
    servers: &[Server],
    cfg: &SimConfig,
    rng: &mut Rng,
) -> Vec<f64> {
    match node {
        Dcc::Queue { slot } => {
            let service = &servers[alloc.server_for(*slot)].dist;
            let mut local = rng.fork();
            if cfg.queueing {
                simulate_station(
                    service,
                    alloc.rate_for(*slot),
                    cfg.n_tasks,
                    cfg.warmup,
                    &mut local,
                )
            } else {
                sample_service(service, cfg.n_tasks, &mut local)
            }
        }
        Dcc::Serial { children, .. } => {
            let mut acc = vec![0.0; cfg.n_tasks];
            for c in children {
                let s = node_samples(c, alloc, servers, cfg, rng);
                for (a, x) in acc.iter_mut().zip(s) {
                    *a += x;
                }
            }
            acc
        }
        Dcc::Parallel { children, .. } => {
            let mut acc = vec![0.0f64; cfg.n_tasks];
            for c in children {
                let s = node_samples(c, alloc, servers, cfg, rng);
                for (a, x) in acc.iter_mut().zip(s) {
                    *a = a.max(x);
                }
            }
            acc
        }
    }
}

/// Convenience: simulate n iid service draws composed serially
/// (the paper's Fig. 2 experiment).
pub fn simulate_serial_iid(dist_rate: f64, n_servers: usize, cfg: &SimConfig) -> SimResult {
    let wf = Workflow::tandem(n_servers, 1.0);
    let servers = Server::pool_exponential(&vec![dist_rate; n_servers]);
    let assign: Vec<usize> = (0..n_servers).collect();
    let alloc = Allocation {
        slot_server: assign,
        slot_rate: vec![1.0; n_servers],
    };
    let mut c = *cfg;
    c.queueing = false;
    simulate(&wf, &alloc, &servers, &c)
}

/// Convenience: n iid parallel branches (the paper's Fig. 3 experiment).
pub fn simulate_parallel_iid(dist_rate: f64, n_servers: usize, cfg: &SimConfig) -> SimResult {
    let wf = Workflow::forkjoin(n_servers, 1.0);
    let servers = Server::pool_exponential(&vec![dist_rate; n_servers]);
    let assign: Vec<usize> = (0..n_servers).collect();
    let alloc = Allocation {
        slot_server: assign,
        slot_rate: vec![1.0; n_servers],
    };
    let mut c = *cfg;
    c.queueing = false;
    simulate(&wf, &alloc, &servers, &c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::analytic;

    fn cfg(n: usize) -> SimConfig {
        SimConfig {
            n_tasks: n,
            warmup: n / 20,
            seed: 77,
            queueing: true,
        }
    }

    #[test]
    fn serial_iid_matches_erlang() {
        // Fig. 2 ground truth: n iid Exp(1) in series = Erlang(n, 1)
        let r = simulate_serial_iid(1.0, 10, &cfg(200_000));
        assert!((r.mean - 10.0).abs() < 0.1, "mean {}", r.mean);
        assert!((r.var - 10.0).abs() < 0.4, "var {}", r.var);
        // CDF spot check
        for t in [5.0, 10.0, 15.0] {
            let want = analytic::erlang_cdf(t, 10, 1.0);
            assert!((r.cdf_at(t) - want).abs() < 0.01, "t={t}");
        }
    }

    #[test]
    fn parallel_iid_matches_harmonic() {
        // Fig. 3 ground truth: E[max of n Exp(1)] = H_n
        let r = simulate_parallel_iid(1.0, 20, &cfg(200_000));
        let want = analytic::max_iid_exp_mean(20, 1.0);
        assert!((r.mean - want).abs() < 0.05, "mean {} want {want}", r.mean);
        let vwant = analytic::max_iid_exp_var(20, 1.0);
        assert!((r.var - vwant).abs() < 0.1, "var {} want {vwant}", r.var);
    }

    #[test]
    fn serial_tail_grows_faster_than_parallel() {
        // the paper's central observation (Figs. 2-3): serial growth in
        // mean is linear, parallel is logarithmic
        let s10 = simulate_serial_iid(1.0, 10, &cfg(50_000));
        let s50 = simulate_serial_iid(1.0, 50, &cfg(50_000));
        let p10 = simulate_parallel_iid(1.0, 10, &cfg(50_000));
        let p50 = simulate_parallel_iid(1.0, 50, &cfg(50_000));
        let serial_growth = s50.mean / s10.mean; // ~5
        let parallel_growth = p50.mean / p10.mean; // ~H50/H10 ~ 1.54
        assert!(serial_growth > 4.5);
        assert!(parallel_growth < 2.0);
        assert!(serial_growth > 2.0 * parallel_growth);
    }

    #[test]
    fn fig6_sim_close_to_analytic_score() {
        use crate::compose::grid::GridSpec;
        use crate::compose::score::score_allocation;
        use crate::sched::{allocate_with, ResponseModel};

        let wf = Workflow::fig6();
        let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto(&alloc, &servers);
        let analytic_score = score_allocation(&wf, &alloc, &servers, &grid);
        let sim = simulate(&wf, &alloc, &servers, &cfg(300_000));
        // all-exponential service => M/M/1 model is exact; sim and
        // analytics must agree within MC noise
        assert!(
            (sim.mean - analytic_score.mean).abs() < 0.05 * analytic_score.mean,
            "sim {} vs analytic {}",
            sim.mean,
            analytic_score.mean
        );
        assert!(
            (sim.var - analytic_score.var).abs() < 0.15 * analytic_score.var,
            "sim var {} vs analytic var {}",
            sim.var,
            analytic_score.var
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = simulate_serial_iid(2.0, 5, &cfg(10_000));
        let r2 = simulate_serial_iid(2.0, 5, &cfg(10_000));
        assert_eq!(r1.mean, r2.mean);
        assert_eq!(r1.samples, r2.samples);
    }

    #[test]
    fn cdf_edges_are_exact() {
        // regression: queries outside the sample range must hit the
        // exact 0.0 / 1.0 bounds, not whatever idx/n rounds to
        let r = SimResult::from_samples(vec![3.0, 5.0, 2.0]);
        assert_eq!(r.samples, vec![2.0, 3.0, 5.0]); // sorted on entry
        assert_eq!(r.cdf_at(1.9), 0.0);
        assert_eq!(r.cdf_at(f64::NEG_INFINITY), 0.0);
        assert_eq!(r.cdf_at(2.0), 1.0 / 3.0);
        assert_eq!(r.cdf_at(4.0), 2.0 / 3.0);
        assert_eq!(r.cdf_at(5.0), 1.0); // at the last sample
        assert_eq!(r.cdf_at(100.0), 1.0); // above it
        assert_eq!(r.cdf_at(f64::INFINITY), 1.0);
        // NaN queries sort below every sample: CDF 0, never NaN
        assert_eq!(r.cdf_at(f64::NAN), 0.0);
    }

    #[test]
    fn cdf_of_empty_sample_set_is_zero_not_nan() {
        // regression: the empty set used to divide 0/0 into NaN
        let r = SimResult {
            mean: 0.0,
            var: 0.0,
            p50: 0.0,
            p99: 0.0,
            samples: Vec::new(),
        };
        assert_eq!(r.cdf_at(0.0), 0.0);
        assert_eq!(r.cdf_at(10.0), 0.0);
        assert_eq!(r.cdf_at(f64::NEG_INFINITY), 0.0);
    }
}
