//! Synthetic workload traces for the coordinator and the end-to-end
//! examples: arrival processes with controllable burstiness and drift,
//! standing in for the production traces the paper's setting assumes
//! (DESIGN.md §substitutions).

use crate::util::rng::Rng;

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Poisson with constant rate.
    Poisson {
        /// Arrival rate.
        rate: f64,
    },
    /// Markov-modulated Poisson: alternates between a base and a burst
    /// rate with exponential dwell times — the bursty ingest pattern of
    /// log/analytics pipelines.
    Mmpp {
        /// Base arrival rate.
        base_rate: f64,
        /// Burst arrival rate.
        burst_rate: f64,
        /// Mean dwell time in the base state.
        base_dwell: f64,
        /// Mean dwell time in the burst state.
        burst_dwell: f64,
    },
    /// Deterministic (paced) arrivals.
    Paced {
        /// Fixed inter-arrival gap.
        interval: f64,
    },
}

/// A generated trace: absolute arrival times.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Monotone arrival timestamps.
    pub arrivals: Vec<f64>,
}

impl Trace {
    /// Generate `n` arrivals.
    pub fn generate(process: ArrivalProcess, n: usize, rng: &mut Rng) -> Trace {
        let mut arrivals = Vec::with_capacity(n);
        let mut t = 0.0;
        match process {
            ArrivalProcess::Poisson { rate } => {
                for _ in 0..n {
                    t += rng.exponential(rate);
                    arrivals.push(t);
                }
            }
            ArrivalProcess::Paced { interval } => {
                for _ in 0..n {
                    t += interval;
                    arrivals.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                base_rate,
                burst_rate,
                base_dwell,
                burst_dwell,
            } => {
                let mut in_burst = false;
                let mut switch_at = rng.exponential(1.0 / base_dwell);
                for _ in 0..n {
                    let rate = if in_burst { burst_rate } else { base_rate };
                    t += rng.exponential(rate);
                    while t > switch_at {
                        in_burst = !in_burst;
                        let dwell = if in_burst { burst_dwell } else { base_dwell };
                        switch_at += rng.exponential(1.0 / dwell);
                    }
                    arrivals.push(t);
                }
            }
        }
        Trace { arrivals }
    }

    /// Observed mean arrival rate.
    pub fn mean_rate(&self) -> f64 {
        if self.arrivals.len() < 2 {
            return 0.0;
        }
        (self.arrivals.len() - 1) as f64 / (self.arrivals.last().unwrap() - self.arrivals[0])
    }

    /// Squared coefficient of variation of inter-arrival gaps
    /// (1 = Poisson, > 1 = bursty, 0 = paced).
    pub fn cv2(&self) -> f64 {
        let gaps: Vec<f64> = self.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        if gaps.is_empty() {
            return 0.0;
        }
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        var / (mean * mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_and_cv2() {
        let mut rng = Rng::new(1);
        let t = Trace::generate(ArrivalProcess::Poisson { rate: 4.0 }, 100_000, &mut rng);
        assert!((t.mean_rate() - 4.0).abs() < 0.1);
        assert!((t.cv2() - 1.0).abs() < 0.05);
    }

    #[test]
    fn paced_has_zero_cv2() {
        let mut rng = Rng::new(2);
        let t = Trace::generate(ArrivalProcess::Paced { interval: 0.25 }, 1_000, &mut rng);
        assert!((t.mean_rate() - 4.0).abs() < 0.01);
        assert!(t.cv2() < 1e-20);
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let mut rng = Rng::new(3);
        let t = Trace::generate(
            ArrivalProcess::Mmpp {
                base_rate: 2.0,
                burst_rate: 20.0,
                base_dwell: 5.0,
                burst_dwell: 1.0,
            },
            100_000,
            &mut rng,
        );
        assert!(t.cv2() > 1.5, "cv2 {}", t.cv2());
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(4);
        for p in [
            ArrivalProcess::Poisson { rate: 1.0 },
            ArrivalProcess::Paced { interval: 1.0 },
            ArrivalProcess::Mmpp {
                base_rate: 1.0,
                burst_rate: 5.0,
                base_dwell: 2.0,
                burst_dwell: 0.5,
            },
        ] {
            let t = Trace::generate(p, 5_000, &mut rng);
            assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
        }
    }
}
