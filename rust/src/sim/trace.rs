//! Synthetic workload traces for the coordinator and the end-to-end
//! examples: arrival processes with controllable burstiness and drift,
//! standing in for the production traces the paper's setting assumes
//! (DESIGN.md §substitutions).

use crate::util::rng::Rng;

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Poisson with constant rate.
    Poisson {
        /// Arrival rate.
        rate: f64,
    },
    /// Markov-modulated Poisson: alternates between a base and a burst
    /// rate with exponential dwell times — the bursty ingest pattern of
    /// log/analytics pipelines.
    Mmpp {
        /// Base arrival rate.
        base_rate: f64,
        /// Burst arrival rate.
        burst_rate: f64,
        /// Mean dwell time in the base state.
        base_dwell: f64,
        /// Mean dwell time in the burst state.
        burst_dwell: f64,
    },
    /// Deterministic (paced) arrivals.
    Paced {
        /// Fixed inter-arrival gap.
        interval: f64,
    },
}

/// A generated trace: absolute arrival times.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Monotone arrival timestamps.
    pub arrivals: Vec<f64>,
}

impl Trace {
    /// Generate `n` arrivals.
    pub fn generate(process: ArrivalProcess, n: usize, rng: &mut Rng) -> Trace {
        let mut arrivals = Vec::with_capacity(n);
        let mut t = 0.0;
        match process {
            ArrivalProcess::Poisson { rate } => {
                for _ in 0..n {
                    t += rng.exponential(rate);
                    arrivals.push(t);
                }
            }
            ArrivalProcess::Paced { interval } => {
                for _ in 0..n {
                    t += interval;
                    arrivals.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                base_rate,
                burst_rate,
                base_dwell,
                burst_dwell,
            } => {
                let mut in_burst = false;
                let mut switch_at = rng.exponential(1.0 / base_dwell);
                for _ in 0..n {
                    let rate = if in_burst { burst_rate } else { base_rate };
                    t += rng.exponential(rate);
                    while t > switch_at {
                        in_burst = !in_burst;
                        let dwell = if in_burst { burst_dwell } else { base_dwell };
                        switch_at += rng.exponential(1.0 / dwell);
                    }
                    arrivals.push(t);
                }
            }
        }
        Trace { arrivals }
    }

    /// Observed mean arrival rate.
    pub fn mean_rate(&self) -> f64 {
        if self.arrivals.len() < 2 {
            return 0.0;
        }
        (self.arrivals.len() - 1) as f64 / (self.arrivals.last().unwrap() - self.arrivals[0])
    }

    /// Merge two traces into one time-sorted stream (the superposition
    /// of the two arrival processes). Sorting is `total_cmp`-stable, so
    /// merging is deterministic even for tied timestamps.
    pub fn merge(&self, other: &Trace) -> Trace {
        let mut arrivals = Vec::with_capacity(self.arrivals.len() + other.arrivals.len());
        arrivals.extend_from_slice(&self.arrivals);
        arrivals.extend_from_slice(&other.arrivals);
        arrivals.sort_by(f64::total_cmp);
        Trace { arrivals }
    }

    /// Multiply every timestamp by `factor` (> 0): `factor < 1`
    /// compresses the trace (rate scales by `1/factor`), `factor > 1`
    /// stretches it.
    pub fn scale_time(&self, factor: f64) -> Trace {
        assert!(factor > 0.0, "time-scale factor must be positive, got {factor}");
        Trace {
            arrivals: self.arrivals.iter().map(|&t| t * factor).collect(),
        }
    }

    /// Keep only the arrivals at or before `horizon`.
    pub fn truncate(&self, horizon: f64) -> Trace {
        Trace {
            arrivals: self
                .arrivals
                .iter()
                .copied()
                .take_while(|&t| t <= horizon)
                .collect(),
        }
    }

    /// Squared coefficient of variation of inter-arrival gaps
    /// (1 = Poisson, > 1 = bursty, 0 = paced).
    pub fn cv2(&self) -> f64 {
        let gaps: Vec<f64> = self.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        if gaps.is_empty() {
            return 0.0;
        }
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        var / (mean * mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_and_cv2() {
        let mut rng = Rng::new(1);
        let t = Trace::generate(ArrivalProcess::Poisson { rate: 4.0 }, 100_000, &mut rng);
        assert!((t.mean_rate() - 4.0).abs() < 0.1);
        assert!((t.cv2() - 1.0).abs() < 0.05);
    }

    #[test]
    fn paced_has_zero_cv2() {
        let mut rng = Rng::new(2);
        let t = Trace::generate(ArrivalProcess::Paced { interval: 0.25 }, 1_000, &mut rng);
        assert!((t.mean_rate() - 4.0).abs() < 0.01);
        assert!(t.cv2() < 1e-20);
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let mut rng = Rng::new(3);
        let t = Trace::generate(
            ArrivalProcess::Mmpp {
                base_rate: 2.0,
                burst_rate: 20.0,
                base_dwell: 5.0,
                burst_dwell: 1.0,
            },
            100_000,
            &mut rng,
        );
        assert!(t.cv2() > 1.5, "cv2 {}", t.cv2());
    }

    #[test]
    fn merge_interleaves_and_stays_sorted() {
        let a = Trace {
            arrivals: vec![1.0, 3.0, 5.0],
        };
        let b = Trace {
            arrivals: vec![2.0, 3.0, 6.0],
        };
        let m = a.merge(&b);
        assert_eq!(m.arrivals, vec![1.0, 2.0, 3.0, 3.0, 5.0, 6.0]);
        // merging an empty trace is the identity
        let e = Trace { arrivals: vec![] };
        assert_eq!(a.merge(&e).arrivals, a.arrivals);
        // superposed Poisson streams add their rates
        let mut rng = Rng::new(5);
        let p1 = Trace::generate(ArrivalProcess::Poisson { rate: 2.0 }, 50_000, &mut rng);
        let p2 = Trace::generate(ArrivalProcess::Poisson { rate: 3.0 }, 50_000, &mut rng);
        let sup = p1.truncate(1_000.0).merge(&p2.truncate(1_000.0));
        assert!((sup.mean_rate() - 5.0).abs() < 0.2, "rate {}", sup.mean_rate());
    }

    #[test]
    fn scale_time_rescales_rate() {
        let t = Trace {
            arrivals: vec![1.0, 2.0, 4.0],
        };
        let s = t.scale_time(0.5);
        assert_eq!(s.arrivals, vec![0.5, 1.0, 2.0]);
        assert!((s.mean_rate() - 2.0 * t.mean_rate()).abs() < 1e-12);
        // cv2 is scale-invariant
        let mut rng = Rng::new(6);
        let p = Trace::generate(ArrivalProcess::Poisson { rate: 1.0 }, 10_000, &mut rng);
        assert!((p.scale_time(3.0).cv2() - p.cv2()).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn scale_time_rejects_nonpositive() {
        let t = Trace {
            arrivals: vec![1.0],
        };
        let _ = t.scale_time(0.0);
    }

    #[test]
    fn truncate_clips_to_horizon() {
        let t = Trace {
            arrivals: vec![0.5, 1.0, 1.5, 2.0, 9.0],
        };
        assert_eq!(t.truncate(1.5).arrivals, vec![0.5, 1.0, 1.5]); // inclusive
        assert_eq!(t.truncate(0.0).arrivals, Vec::<f64>::new());
        assert_eq!(t.truncate(100.0).arrivals.len(), 5);
    }

    #[test]
    fn compose_burst_onto_base() {
        // the zoo's churn-scenario composition: base stream + a
        // compressed burst clipped to the first half of the run
        let mut rng = Rng::new(7);
        let base = Trace::generate(ArrivalProcess::Poisson { rate: 1.0 }, 3_000, &mut rng);
        let horizon = *base.arrivals.last().unwrap();
        let burst = Trace::generate(ArrivalProcess::Poisson { rate: 1.0 }, 1_000, &mut rng)
            .scale_time(0.25)
            .truncate(horizon * 0.5);
        let composed = base.merge(&burst);
        assert!(composed.arrivals.len() > base.arrivals.len());
        assert!(composed.arrivals.windows(2).all(|w| w[1] >= w[0]));
        assert!(composed.cv2() > base.cv2(), "burst must add burstiness");
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(4);
        for p in [
            ArrivalProcess::Poisson { rate: 1.0 },
            ArrivalProcess::Paced { interval: 1.0 },
            ArrivalProcess::Mmpp {
                base_rate: 1.0,
                burst_rate: 5.0,
                base_dwell: 2.0,
                burst_dwell: 0.5,
            },
        ] {
            let t = Trace::generate(p, 5_000, &mut rng);
            assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
        }
    }
}
