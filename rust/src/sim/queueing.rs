//! Single-station FCFS queue simulation (Lindley recursion).
//!
//! Each leaf slot of a workflow is one station receiving a Poisson task
//! stream at its scheduled rate. For a single FCFS server the full
//! event-calendar machinery reduces to the Lindley recursion
//!
//! ```text
//! depart[i]   = max(arrive[i], depart[i-1]) + service[i]
//! response[i] = depart[i] - arrive[i]
//! ```
//!
//! which gives the *exact* M/G/1-FCFS sample path — the ground truth the
//! analytic response models (`sched::response`) approximate.

use crate::dist::ServiceDist;
use crate::util::rng::Rng;

/// Simulate one FCFS station: Poisson(λ) arrivals, iid service draws.
///
/// Returns `n` post-warmup response-time samples (the first `warmup`
/// tasks are simulated but discarded so the queue reaches steady state).
pub fn simulate_station(
    service: &ServiceDist,
    lambda: f64,
    n: usize,
    warmup: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    assert!(lambda > 0.0 && n > 0);
    let total = n + warmup;
    let mut out = Vec::with_capacity(n);
    let mut arrive = 0.0f64;
    let mut depart_prev = 0.0f64;
    for i in 0..total {
        arrive += rng.exponential(lambda);
        let start = arrive.max(depart_prev);
        let depart = start + service.sample(rng);
        if i >= warmup {
            out.push(depart - arrive);
        }
        depart_prev = depart;
    }
    out
}

/// Service-only samples (no queueing): the Fig. 2/3 setting.
pub fn sample_service(service: &ServiceDist, n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n).map(|_| service.sample(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    fn mean_of(xs: &[f64]) -> f64 {
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    #[test]
    fn mm1_mean_response_matches_formula() {
        // M/M/1: E[R] = 1/(mu - lambda)
        let (mu, lambda) = (5.0, 3.0);
        let mut rng = Rng::new(42);
        let samples = simulate_station(
            &ServiceDist::exponential(mu),
            lambda,
            400_000,
            20_000,
            &mut rng,
        );
        let want = 1.0 / (mu - lambda);
        let got = mean_of(&samples);
        assert!((got - want).abs() < 0.03 * want, "got {got} want {want}");
    }

    #[test]
    fn mg1_mean_matches_pollaczek_khinchine() {
        // deterministic-ish service (delayed exp with tiny tail) ≈ M/D/1
        let service = ServiceDist::delayed_exponential(50.0, 0.18); // mean 0.2
        let lambda = 3.0;
        let es = service.mean();
        let es2 = service.variance() + es * es;
        let rho = lambda * es;
        let want = es + lambda * es2 / (2.0 * (1.0 - rho));
        let mut rng = Rng::new(7);
        let samples = simulate_station(&service, lambda, 400_000, 20_000, &mut rng);
        let got = mean_of(&samples);
        assert!((got - want).abs() < 0.05 * want, "got {got} want {want}");
    }

    #[test]
    fn low_load_response_is_service() {
        // lambda -> 0: response ≈ service
        let service = ServiceDist::delayed_pareto(4.0, 0.3);
        let mut rng = Rng::new(9);
        let samples = simulate_station(&service, 0.01, 100_000, 1_000, &mut rng);
        let got = mean_of(&samples);
        let want = service.mean();
        assert!((got - want).abs() < 0.05 * want, "got {got} want {want}");
    }

    #[test]
    fn utilization_grows_variance() {
        let service = ServiceDist::exponential(5.0);
        let mut rng = Rng::new(11);
        let mut prev_var = 0.0;
        for lambda in [1.0, 3.0, 4.5] {
            let samples = simulate_station(&service, lambda, 200_000, 10_000, &mut rng);
            let mut w = Welford::new();
            samples.iter().for_each(|&x| w.push(x));
            assert!(w.variance() > prev_var, "lambda {lambda}");
            prev_var = w.variance();
        }
    }

    #[test]
    fn warmup_discarded() {
        let mut rng = Rng::new(13);
        let s = simulate_station(&ServiceDist::exponential(2.0), 1.0, 100, 50, &mut rng);
        assert_eq!(s.len(), 100);
        assert!(s.iter().all(|&x| x > 0.0));
    }
}
