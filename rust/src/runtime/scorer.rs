//! Batched allocation scoring — the optimizer's hot path.
//!
//! The exhaustive/heuristic searches need to score many candidate
//! allocations. For the Fig. 6 template the whole composition is one AOT
//! artifact (`score_fig6_b{B}_g{G}`): rust builds the per-slot
//! response-law grids, packs a `[B, 6, G]` wavefront, and one PJRT
//! execute returns `[B, 3]` score triples (+ total PDFs). Arbitrary
//! topologies and artifact-less environments fall back to the native
//! engine — same math (`compose::score`), cross-checked in tests.

use crate::compose::grid::GridSpec;
use crate::compose::score::{score_allocation_with, Score};
use crate::dist::central_diff;
use crate::flow::{Dcc, Workflow};
use crate::runtime::executable::{ArtifactRegistry, RuntimeError};
use crate::sched::response::{response_dist, Response, ResponseModel};
use crate::sched::server::Server;
use crate::sched::Allocation;

/// Score triple for one candidate (mean, variance, p99).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Triple {
    /// Mean end-to-end response time.
    pub mean: f64,
    /// Variance.
    pub var: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Triple {
    const UNSTABLE: Triple = Triple {
        mean: f64::INFINITY,
        var: f64::INFINITY,
        p99: f64::INFINITY,
    };

    /// From a native Score.
    pub fn from_score(s: &Score) -> Triple {
        Triple {
            mean: s.mean,
            var: s.var,
            p99: s.p99,
        }
    }
}

/// Which engine scored the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScorerEngine {
    /// AOT artifact via PJRT.
    Xla,
    /// Pure-rust composition engine.
    Native,
}

/// Former name of [`ScorerEngine`] (renamed to avoid confusion with the
/// [`ScoreBackend`](crate::compose::backend::ScoreBackend) trait).
#[deprecated(since = "0.3.0", note = "renamed to `ScorerEngine`; see docs/MIGRATION.md")]
pub type ScorerBackend = ScorerEngine;

/// Batched scorer with automatic fallback.
pub struct BatchScorer {
    registry: Option<ArtifactRegistry>,
    artifact: Option<String>,
    /// Fully-fused parametric scorer artifact, when the manifest has one.
    mmde_artifact: Option<(String, usize)>, // (name, M modes)
    /// Wavefront size of the artifact (B).
    pub batch: usize,
    /// Grid points of the artifact (G).
    pub grid_n: usize,
}

impl BatchScorer {
    /// Try to open the artifact registry; fall back to native silently.
    pub fn open_auto() -> BatchScorer {
        match ArtifactRegistry::open_default() {
            Ok(reg) => Self::from_registry(reg),
            Err(_) => Self::native(),
        }
    }

    /// Force the native backend.
    pub fn native() -> BatchScorer {
        BatchScorer {
            registry: None,
            artifact: None,
            mmde_artifact: None,
            batch: 64,
            grid_n: GridSpec::AOT_N,
        }
    }

    /// XLA backend from an opened registry (errors if the fig6 scorer
    /// artifact is absent). Prefers the CPU-optimized `score_fig6_fast_*`
    /// artifact (FFT convolution) over the TPU-shaped pallas one — on the
    /// CPU PJRT backend the interpret-mode pallas grid executes as an XLA
    /// while-loop and is orders of magnitude slower (§Perf).
    pub fn xla(reg: ArtifactRegistry) -> Result<BatchScorer, RuntimeError> {
        let names = reg.names();
        let name = names
            .iter()
            .find(|n| n.starts_with("score_fig6_fast"))
            .or_else(|| names.iter().find(|n| n.starts_with("score_fig6")))
            .map(|s| s.to_string())
            .ok_or_else(|| RuntimeError::UnknownArtifact("score_fig6_*".into()))?;
        Self::xla_with(reg, &name)
    }

    /// XLA backend pinned to a specific scorer artifact (perf A/B runs).
    pub fn xla_with(reg: ArtifactRegistry, name: &str) -> Result<BatchScorer, RuntimeError> {
        let meta = reg
            .meta(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let (batch, grid_n) = (meta.inputs[0][0], meta.inputs[0][2]);
        // the fully-fused parametric scorer, when lowered
        let mmde_artifact = reg
            .names()
            .iter()
            .find(|n| n.starts_with("score_fig6_mmde"))
            .map(|n| {
                let m = reg.meta(n).unwrap().inputs[0][2];
                (n.to_string(), m)
            });
        Ok(BatchScorer {
            registry: Some(reg),
            artifact: Some(name.to_string()),
            mmde_artifact,
            batch,
            grid_n,
        })
    }

    fn from_registry(reg: ArtifactRegistry) -> BatchScorer {
        Self::xla(reg).unwrap_or_else(|_| Self::native())
    }

    /// Active engine.
    pub fn backend(&self) -> ScorerEngine {
        if self.registry.is_some() {
            ScorerEngine::Xla
        } else {
            ScorerEngine::Native
        }
    }

    /// Score a wave of candidate allocations on a workflow.
    ///
    /// Uses the fused PJRT artifact when (a) the backend is XLA and
    /// (b) the workflow matches the Fig. 6 template slot layout;
    /// otherwise scores natively. Unstable candidates get infinite
    /// triples either way.
    pub fn score_batch(
        &mut self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Triple> {
        if self.registry.is_some() && is_fig6_shape(wf) && grid.n == self.grid_n {
            // prefer the fully-fused parametric path when every response
            // law in the wave is an (atomless) delayed-exp mixture
            if self.mmde_artifact.is_some() {
                if let Some(t) = self.try_score_batch_mmde(allocs, servers, grid, model) {
                    return t;
                }
            }
            match self.score_batch_xla(allocs, servers, grid, model) {
                Ok(t) => return t,
                Err(e) => {
                    // fall back once and remember; silenceable via util::warn
                    crate::util::warn::warn(&format!(
                        "xla scorer failed ({e}); falling back to native"
                    ));
                    self.registry = None;
                }
            }
        }
        allocs
            .iter()
            .map(|a| Triple::from_score(&score_allocation_with(wf, a, servers, grid, model)))
            .collect()
    }

    fn score_batch_xla(
        &mut self,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Result<Vec<Triple>, RuntimeError> {
        let (b, g) = (self.batch, self.grid_n);
        let name = self.artifact.clone().expect("xla backend has artifact");
        let reg = self.registry.as_mut().expect("xla backend has registry");
        let mut out = Vec::with_capacity(allocs.len());

        for wave in allocs.chunks(b) {
            let mut pdf = vec![0f32; b * 6 * g];
            let mut cdf = vec![0f32; b * 6 * g];
            // rows beyond the wave stay zero (scored then discarded)
            let mut stable = vec![true; wave.len()];
            for (row, alloc) in wave.iter().enumerate() {
                for slot in 0..6 {
                    let service = &servers[alloc.server_for(slot)].dist;
                    match response_dist(model, service, alloc.rate_for(slot)) {
                        Response::Unstable => {
                            stable[row] = false;
                            break;
                        }
                        Response::Stable(d) => {
                            let c = d.cdf_grid(grid.dt, g);
                            let p = central_diff(&c, grid.dt);
                            let base = (row * 6 + slot) * g;
                            for k in 0..g {
                                pdf[base + k] = p[k] as f32;
                                cdf[base + k] = c[k] as f32;
                            }
                        }
                    }
                }
            }
            let outs = reg.execute_f32(
                &name,
                &[
                    (&pdf, &[b, 6, g]),
                    (&cdf, &[b, 6, g]),
                    (&[grid.dt as f32], &[]),
                ],
            )?;
            let scores = &outs[0]; // [B, 3]
            for (row, &ok) in stable.iter().enumerate() {
                if !ok {
                    out.push(Triple::UNSTABLE);
                } else {
                    out.push(Triple {
                        mean: scores[row * 3] as f64,
                        var: scores[row * 3 + 1] as f64,
                        p99: scores[row * 3 + 2] as f64,
                    });
                }
            }
        }
        Ok(out)
    }
}

impl BatchScorer {
    /// Parametric path: pack response-law mixture parameters per slot and
    /// run the fully-fused `score_fig6_mmde_*` artifact. Returns None when
    /// any stable law in the wave is not an atomless delayed-exp mixture
    /// with at most M modes (the caller then uses the grid path).
    fn try_score_batch_mmde(
        &mut self,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Option<Vec<Triple>> {
        let (name, m_modes) = self.mmde_artifact.clone()?;
        let b = self.batch;
        let mut out = Vec::with_capacity(allocs.len());
        // pre-extract params; bail out (None) on unrepresentable laws
        let mut packed: Vec<Option<Vec<[f32; 3]>>> = Vec::with_capacity(allocs.len() * 6);
        for alloc in allocs {
            for slot in 0..6 {
                let service = &servers[alloc.server_for(slot)].dist;
                match response_dist(model, service, alloc.rate_for(slot)) {
                    Response::Unstable => packed.push(Some(Vec::new())), // marker: unstable row
                    Response::Stable(d) => {
                        let params = mmde_params(&d, m_modes)?;
                        packed.push(Some(params));
                    }
                }
            }
        }

        let reg = self.registry.as_mut()?;
        for (wave_idx, wave) in allocs.chunks(b).enumerate() {
            let mut w = vec![0f32; b * 6 * m_modes];
            let mut lam = vec![1f32; b * 6 * m_modes];
            let mut delay = vec![0f32; b * 6 * m_modes];
            let mut stable = vec![true; wave.len()];
            for (row, _alloc) in wave.iter().enumerate() {
                for slot in 0..6 {
                    let entry = &packed[(wave_idx * b + row) * 6 + slot];
                    let params = entry.as_ref().expect("pre-extracted");
                    if params.is_empty() {
                        stable[row] = false;
                        continue;
                    }
                    for (k, p) in params.iter().enumerate() {
                        let base = (row * 6 + slot) * m_modes + k;
                        w[base] = p[0];
                        lam[base] = p[1];
                        delay[base] = p[2];
                    }
                }
            }
            let outs = reg
                .execute_f32(
                    &name,
                    &[
                        (&w, &[b, 6, m_modes]),
                        (&lam, &[b, 6, m_modes]),
                        (&delay, &[b, 6, m_modes]),
                        (&[grid.dt as f32], &[]),
                    ],
                )
                .ok()?;
            let scores = &outs[0];
            for (row, &ok) in stable.iter().enumerate() {
                out.push(if ok {
                    Triple {
                        mean: scores[row * 3] as f64,
                        var: scores[row * 3 + 1] as f64,
                        p99: scores[row * 3 + 2] as f64,
                    }
                } else {
                    Triple::UNSTABLE
                });
            }
        }
        Some(out)
    }
}

/// Extract (weight, lam, delay) mixture parameters when the law is an
/// atomless multi-modal delayed exponential with at most `max_modes`
/// modes (exactly what the device-side grid builder evaluates).
pub fn mmde_params(d: &crate::dist::ServiceDist, max_modes: usize) -> Option<Vec<[f32; 3]>> {
    use crate::dist::TailKind;
    let modes = d.modes();
    if modes.len() > max_modes {
        return None;
    }
    let mut out = Vec::with_capacity(modes.len());
    for (p, m) in modes {
        if !matches!(m.kind, TailKind::Exponential) {
            return None;
        }
        // device formula has no alpha: requires the continuous (atomless)
        // parameterization, alpha == 1 for the exponential clock
        if (m.alpha - 1.0).abs() > 1e-9 {
            return None;
        }
        out.push([*p as f32, m.lam as f32, m.delay as f32]);
    }
    Some(out)
}

/// The PJRT/AOT scorer folded in as a [`ScoreBackend`]: the same
/// batched engine [`BatchScorer`] runs on the hot path, usable anywhere
/// a [`Planner`](crate::plan::Planner) or search engine takes an
/// injected backend. Falls back to the native composition engine when
/// artifacts are absent (identical math, cross-checked in tests).
///
/// On the XLA engine, *stable* scores carry the (mean, var, p99)
/// triple only — no attached PDF, and `mass` is reported as NaN because
/// the fused triple path does not track captured grid mass. Unstable
/// candidates return the exact [`Score::unstable_point`] sentinel
/// (infinite triple, `mass = 0.0`), identical to what the analytic
/// backend reports, so infeasibility propagates the same way whichever
/// backend scored the wave. On the native fallback engine the full
/// analytic [`Score`] (PDF + mass) is returned, so diagnostics behave
/// exactly like
/// [`AnalyticBackend`](crate::compose::backend::AnalyticBackend).
///
/// The scorer state sits behind a [`Mutex`](std::sync::Mutex), so a
/// `RuntimeBackend` is `Sync` and can be wrapped in a
/// [`ShardedBackend`](crate::compose::backend::ShardedBackend): each
/// scoring call takes the lock exactly once, briefly, to read the
/// active engine — on the native engine the lock is released before
/// any scoring work, so shards overlap fully; on the XLA engine the
/// wave is scored under the guard, serializing on the device handle.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let backend = RuntimeBackend::native(); // or RuntimeBackend::open_auto()
/// let plan = Planner::new(&wf, &servers)
///     .backend(&backend)
///     .plan(&ProposedPolicy::default())
///     .expect("feasible");
/// assert!(plan.score.is_stable());
/// ```
pub struct RuntimeBackend {
    inner: std::sync::Mutex<BatchScorer>,
}

impl RuntimeBackend {
    /// Backend over an auto-opened scorer: PJRT artifacts when present,
    /// native engine otherwise (see [`BatchScorer::open_auto`]).
    pub fn open_auto() -> RuntimeBackend {
        Self::from_scorer(BatchScorer::open_auto())
    }

    /// Backend pinned to the native engine.
    pub fn native() -> RuntimeBackend {
        Self::from_scorer(BatchScorer::native())
    }

    /// Backend over an explicitly-configured [`BatchScorer`].
    pub fn from_scorer(scorer: BatchScorer) -> RuntimeBackend {
        RuntimeBackend {
            inner: std::sync::Mutex::new(scorer),
        }
    }

    /// Which engine the wrapped scorer is using right now.
    pub fn engine(&self) -> ScorerEngine {
        self.lock().backend()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BatchScorer> {
        // a panic mid-score poisons the lock but not the scorer state
        // (waves are written whole); keep scoring
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Triple → Score. Stable triples carry no PDF and a NaN `mass`
    /// (not tracked on the fused path — not a fake "all mass captured"
    /// 1.0). Unstable triples map to the shared
    /// [`Score::unstable_point`] sentinel so every backend reports
    /// infeasibility identically (infinite triple, `mass = 0.0`).
    fn to_score(t: &Triple) -> Score {
        if !t.mean.is_finite() {
            return Score::unstable_point();
        }
        Score {
            mean: t.mean,
            var: t.var,
            p99: t.p99,
            mass: f64::NAN,
            pdf: Vec::new(),
        }
    }
}

impl crate::compose::backend::ScoreBackend for RuntimeBackend {
    fn name(&self) -> &str {
        match self.engine() {
            ScorerEngine::Xla => "runtime-xla",
            ScorerEngine::Native => "runtime-native",
        }
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        // one lock acquisition: read the engine and, on XLA, score
        // under the same guard. The native branch releases immediately
        // and scores outside the lock, so shards overlap fully.
        let mut guard = self.lock();
        if guard.backend() == ScorerEngine::Native {
            drop(guard);
            return score_allocation_with(wf, alloc, servers, grid, model);
        }
        let t = guard.score_batch(wf, std::slice::from_ref(alloc), servers, grid, model);
        Self::to_score(&t[0])
    }

    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        let mut guard = self.lock();
        if guard.backend() == ScorerEngine::Native {
            drop(guard);
            return allocs
                .iter()
                .map(|a| score_allocation_with(wf, a, servers, grid, model))
                .collect();
        }
        guard
            .score_batch(wf, allocs, servers, grid, model)
            .into_iter()
            .map(|t| Self::to_score(&t))
            .collect()
    }

    /// Fabric-worker path: the native engine drops the scorer lock
    /// before any work (shards overlap fully, exactly as in
    /// [`score_batch`](Self::score_batch)) and scores through the
    /// allocation-free scratch scorer; the XLA engine ignores the
    /// scratch and runs the fused batch under the lock as usual.
    fn score_batch_scratch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
        scratch: &mut crate::compose::scratch::Scratch,
    ) -> Vec<Score> {
        let guard = self.lock();
        if guard.backend() == ScorerEngine::Native {
            drop(guard);
            return allocs
                .iter()
                .map(|a| {
                    crate::compose::score::score_allocation_scratch(
                        wf, a, servers, grid, model, scratch,
                    )
                })
                .collect();
        }
        drop(guard);
        self.score_batch(wf, allocs, servers, grid, model)
    }
}

/// True when the workflow is the Fig. 6 template the fused artifact was
/// lowered for: Serial[Parallel(2), Queue, Queue, Parallel(2)] over 6
/// slots (the canonicalized fig6 shape).
pub fn is_fig6_shape(wf: &Workflow) -> bool {
    if wf.slots() != 6 {
        return false;
    }
    match wf.root() {
        Dcc::Serial { children, .. } if children.len() == 4 => {
            matches!(&children[0], Dcc::Parallel { children: c, .. } if c.len() == 2
                && c.iter().all(|x| matches!(x, Dcc::Queue { .. })))
                && matches!(&children[1], Dcc::Queue { .. })
                && matches!(&children[2], Dcc::Queue { .. })
                && matches!(&children[3], Dcc::Parallel { children: c, .. } if c.len() == 2
                && c.iter().all(|x| matches!(x, Dcc::Queue { .. })))
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{allocate_with, baseline_allocate_split, SplitPolicy};

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn runtime_backend_is_a_score_backend() {
        use crate::compose::backend::{AnalyticBackend, ScoreBackend};
        let (wf, servers) = fig6();
        let a = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto(&a, &servers);
        let rb = RuntimeBackend::native();
        assert_eq!(rb.engine(), ScorerEngine::Native);
        assert_eq!(rb.name(), "runtime-native");
        let got = rb.score(&wf, &a, &servers, &grid, ResponseModel::Mm1);
        let want = AnalyticBackend.score(&wf, &a, &servers, &grid, ResponseModel::Mm1);
        // native engine routes through the same composition math
        assert_eq!(got.mean, want.mean);
        assert_eq!(got.var, want.var);
        assert_eq!(got.p99, want.p99);
        let batch = rb.score_batch(&wf, &[a.clone(), a], &servers, &grid, ResponseModel::Mm1);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].mean, want.mean);
    }

    #[test]
    fn unstable_triples_map_to_the_shared_sentinel() {
        // the XLA triple path must report infeasibility exactly like the
        // analytic backend: +inf triple, mass 0.0 — never NaN keys
        let s = RuntimeBackend::to_score(&Triple::UNSTABLE);
        assert_eq!(s.mean, f64::INFINITY);
        assert_eq!(s.var, f64::INFINITY);
        assert_eq!(s.p99, f64::INFINITY);
        assert_eq!(s.mass, 0.0);
        assert!(s.pdf.is_empty());
        assert!(!s.is_stable());
    }

    #[test]
    fn runtime_backend_composes_with_sharding() {
        use crate::compose::backend::{AnalyticBackend, ScoreBackend, ShardedBackend};
        fn assert_sync<T: Sync>() {}
        assert_sync::<RuntimeBackend>();

        let (wf, servers) = fig6();
        let a1 = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let a2 = baseline_allocate_split(&wf, &servers, ResponseModel::Mm1, SplitPolicy::Uniform)
            .unwrap();
        let grid = GridSpec::auto(&a1, &servers);
        let wave = vec![a1, a2];
        let rb = RuntimeBackend::native();
        let sharded = ShardedBackend::new(&rb, 2);
        let got = sharded.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
        let want = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
        for (g, w) in got.iter().zip(want.iter()) {
            // the native fallback engine scores outside the lock and is
            // the analytic math bit for bit
            assert_eq!(g.mean, w.mean);
            assert_eq!(g.var, w.var);
            assert_eq!(g.p99, w.p99);
        }
    }

    #[test]
    fn fig6_shape_detector() {
        assert!(is_fig6_shape(&Workflow::fig6()));
        assert!(!is_fig6_shape(&Workflow::tandem(6, 1.0)));
        assert!(!is_fig6_shape(&Workflow::forkjoin(6, 1.0)));
    }

    #[test]
    fn native_scorer_matches_direct_scoring() {
        let (wf, servers) = fig6();
        let a1 = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let a2 = baseline_allocate_split(&wf, &servers, ResponseModel::Mm1, SplitPolicy::Uniform)
            .unwrap();
        let grid = GridSpec::auto(&a1, &servers);
        let mut scorer = BatchScorer::native();
        let triples = scorer.score_batch(
            &wf,
            &[a1.clone(), a2.clone()],
            &servers,
            &grid,
            ResponseModel::Mm1,
        );
        let d1 = score_allocation_with(&wf, &a1, &servers, &grid, ResponseModel::Mm1);
        assert!((triples[0].mean - d1.mean).abs() < 1e-12);
        assert!((triples[0].var - d1.var).abs() < 1e-12);
    }

    #[test]
    fn xla_scorer_matches_native_when_artifacts_present() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (wf, servers) = fig6();
        let a1 = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let a2 = baseline_allocate_split(&wf, &servers, ResponseModel::Mm1, SplitPolicy::Uniform)
            .unwrap();
        let grid = GridSpec::auto(&a1, &servers);
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let mut xla_scorer = BatchScorer::xla(reg).unwrap();
        assert_eq!(xla_scorer.backend(), ScorerEngine::Xla);
        let grid = GridSpec {
            dt: grid.dt,
            n: xla_scorer.grid_n,
        };
        let xla_t =
            xla_scorer.score_batch(&wf, &[a1.clone(), a2.clone()], &servers, &grid, ResponseModel::Mm1);
        let mut native = BatchScorer::native();
        let nat_t = native.score_batch(&wf, &[a1, a2], &servers, &grid, ResponseModel::Mm1);
        for (x, n) in xla_t.iter().zip(nat_t.iter()) {
            // f32 artifact vs f64 native: loose but tight enough to catch
            // any composition mismatch
            assert!((x.mean - n.mean).abs() < 2e-3 * (1.0 + n.mean), "{x:?} vs {n:?}");
            assert!((x.var - n.var).abs() < 5e-3 * (1.0 + n.var), "{x:?} vs {n:?}");
            // p99 crosses the CDF where the density is nearly flat, so a
            // ~1e-4 f32-cumsum wobble moves it by many grid cells: allow
            // 3% relative
            assert!((x.p99 - n.p99).abs() < 0.03 * n.p99 + 3.0 * grid.dt, "{x:?} vs {n:?}");
        }
    }
}
