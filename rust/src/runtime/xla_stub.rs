//! Inert stand-in for the vendored `xla` crate (PJRT C-API bindings).
//!
//! The build image that carries the real `xla` crate chain is not
//! available everywhere (CI, plain dev boxes), so the default build
//! links this stub instead: it exposes the exact slice of the `xla`
//! API that [`crate::runtime::executable`] compiles against, and every
//! entry point fails with a descriptive error. [`ArtifactRegistry::open`]
//! therefore errors out cleanly and [`crate::runtime::BatchScorer`]
//! falls back to the native engine — same behavior as a machine without
//! artifacts. To link the real backend, add the vendored `xla`
//! dependency on the build image and re-point
//! `runtime::xla_backend` at it (the `pjrt` feature is a tripwire that
//! keeps those two steps together).
//!
//! [`ArtifactRegistry::open`]: crate::runtime::executable::ArtifactRegistry::open

use std::path::Path;

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn disabled<T>() -> Result<T, Error> {
    Err(Error(
        "compiled without the `pjrt` feature: no PJRT/XLA backend linked".into(),
    ))
}

/// Stand-in for `xla::PjRtClient`. Construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Mirrors `xla::PjRtClient::cpu()`; always errors in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        disabled()
    }

    /// Mirrors `PjRtClient::compile`; unreachable (no client exists).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        disabled()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Mirrors `HloModuleProto::from_text_file`; always errors.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        disabled()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Mirrors `XlaComputation::from_proto`.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::Literal` (host tensor).
pub struct Literal;

impl Literal {
    /// Mirrors `Literal::vec1`.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Mirrors `Literal::reshape`; unreachable in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        disabled()
    }

    /// Mirrors `Literal::to_tuple`; unreachable in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        disabled()
    }

    /// Mirrors `Literal::to_vec`; unreachable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        disabled()
    }
}

/// Stand-in for `xla::PjRtBuffer` (device tensor).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Mirrors `PjRtBuffer::to_literal_sync`; unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        disabled()
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `PjRtLoadedExecutable::execute`; unreachable in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        disabled()
    }
}
