//! PJRT runtime: loads the AOT artifacts (jax/pallas lowered to HLO text
//! at build time) and executes them from the rust hot path.
//!
//! Python never runs here — `make artifacts` is the only python step.
//! The interchange format is HLO *text*: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! * [`executable`] — client + compiled-executable cache keyed by
//!   artifact name, with f32-literal marshalling helpers;
//! * [`scorer`] — the batched fig6 allocation scorer (the optimizer's
//!   inner loop) with a bit-compatible native fallback.

pub mod executable;
pub mod scorer;

pub use executable::{ArtifactRegistry, RuntimeError};
pub use scorer::{BatchScorer, ScorerBackend};
