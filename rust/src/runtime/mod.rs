//! PJRT runtime: loads the AOT artifacts (jax/pallas lowered to HLO text
//! at build time) and executes them from the rust hot path.
//!
//! Python never runs here — `make artifacts` is the only python step.
//! The interchange format is HLO *text*: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! * [`executable`] — client + compiled-executable cache keyed by
//!   artifact name, with f32-literal marshalling helpers;
//! * [`scorer`] — the batched fig6 allocation scorer (the optimizer's
//!   inner loop) with a bit-compatible native fallback, exposed to the
//!   planner as the [`scorer::RuntimeBackend`] implementation of
//!   [`crate::compose::backend::ScoreBackend`].

pub mod executable;
pub mod scorer;
pub mod xla_stub;

/// The linked XLA backend — currently always the inert [`xla_stub`].
/// Wiring the real vendored `xla` crate in means adding the dependency
/// to rust/Cargo.toml (build image only) and pointing this re-export at
/// it; the `pjrt` feature below guards against doing one without the
/// other.
pub use self::xla_stub as xla_backend;

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate chain, which this \
     checkout does not declare: add the `xla` dependency to rust/Cargo.toml \
     on the build image and re-point `runtime::xla_backend` at `::xla` \
     instead of `xla_stub`"
);

pub use executable::{ArtifactRegistry, RuntimeError};
#[allow(deprecated)]
pub use scorer::ScorerBackend;
pub use scorer::{BatchScorer, RuntimeBackend, ScorerEngine};
