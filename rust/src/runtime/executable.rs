//! Artifact loading and execution over the PJRT C API (`xla` crate).
//!
//! This checkout links [`crate::runtime::xla_stub`] instead of the
//! real crate (see `runtime::xla_backend`): [`ArtifactRegistry::open`]
//! then fails cleanly and every caller falls back to the native
//! engine. On the build image with the vendored `xla` crate, re-point
//! the `xla_backend` re-export and this module runs the real PJRT
//! path unchanged.

use crate::runtime::xla_backend as xla;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Runtime failure.
#[derive(Debug)]
pub enum RuntimeError {
    /// artifacts/ directory or manifest missing / unreadable.
    MissingArtifacts(String),
    /// Unknown artifact name.
    UnknownArtifact(String),
    /// Underlying XLA/PJRT error.
    Xla(xla::Error),
    /// Input arity/shape mismatch against the manifest.
    BadInput(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingArtifacts(p) => {
                write!(f, "artifacts unavailable: {p} (run `make artifacts`)")
            }
            RuntimeError::UnknownArtifact(n) => write!(f, "unknown artifact '{n}'"),
            RuntimeError::Xla(e) => write!(f, "xla error: {e:?}"),
            RuntimeError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// File name within the artifact directory.
    pub path: String,
    /// Input shapes (as listed by aot.py).
    pub inputs: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub num_outputs: usize,
}

/// PJRT CPU client + lazily compiled executables for every artifact in
/// `manifest.json`. Compilation happens once per artifact per process;
/// the hot path only executes.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    dir: PathBuf,
    meta: HashMap<String, ArtifactMeta>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactRegistry {
    /// Open an artifact directory (reads `manifest.json`, creates the
    /// PJRT CPU client; compilation is deferred per artifact).
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactRegistry, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| RuntimeError::MissingArtifacts(format!("{manifest_path:?}: {e}")))?;
        let v = Json::parse(&text)
            .map_err(|e| RuntimeError::MissingArtifacts(format!("manifest parse: {e}")))?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| RuntimeError::MissingArtifacts("manifest has no artifacts".into()))?;
        let mut meta = HashMap::new();
        for (name, m) in arts {
            let path = m
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError::MissingArtifacts(format!("{name}: no path")))?
                .to_string();
            let inputs = m
                .get("inputs")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .map(|shape| {
                            shape
                                .as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(Json::as_usize)
                                .collect()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let num_outputs = m
                .get("num_outputs")
                .and_then(Json::as_usize)
                .unwrap_or(1);
            meta.insert(
                name.clone(),
                ArtifactMeta {
                    path,
                    inputs,
                    num_outputs,
                },
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRegistry {
            client,
            dir,
            meta,
            compiled: HashMap::new(),
        })
    }

    /// Default location: `$DCFLOW_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactRegistry, RuntimeError> {
        let dir = std::env::var("DCFLOW_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<&str> {
        self.meta.keys().map(|s| s.as_str()).collect()
    }

    /// Manifest metadata for an artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.meta.get(name)
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let m = self
            .meta
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let path = self.dir.join(&m.path);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on f32 tensors. `args` are (data, shape)
    /// pairs; returns the flattened f32 data of every tuple output.
    pub fn execute_f32(
        &mut self,
        name: &str,
        args: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>, RuntimeError> {
        self.ensure_compiled(name)?;
        let m = &self.meta[name];
        if m.inputs.len() != args.len() {
            return Err(RuntimeError::BadInput(format!(
                "{name} expects {} inputs, got {}",
                m.inputs.len(),
                args.len()
            )));
        }
        for (i, ((data, shape), want)) in args.iter().zip(&m.inputs).enumerate() {
            let n: usize = shape.iter().product::<usize>().max(1);
            if shape[..] != want[..] {
                return Err(RuntimeError::BadInput(format!(
                    "{name} input {i}: shape {shape:?} != manifest {want:?}"
                )));
            }
            if data.len() != n {
                return Err(RuntimeError::BadInput(format!(
                    "{name} input {i}: {} elements for shape {shape:?}",
                    data.len()
                )));
            }
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // scalar: reshape to rank-0
                    lit.reshape(&[])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims)
                }
            })
            .collect::<Result<_, _>>()?;

        let exe = &self.compiled[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != m.num_outputs {
            return Err(RuntimeError::BadInput(format!(
                "{name}: manifest says {} outputs, got {}",
                m.num_outputs,
                outs.len()
            )));
        }
        outs.into_iter()
            .map(|l| l.to_vec::<f32>().map_err(RuntimeError::from))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(matches!(
            ArtifactRegistry::open("/nonexistent/path"),
            Err(RuntimeError::MissingArtifacts(_))
        ));
    }

    #[test]
    fn manifest_round_trip() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert!(reg.names().iter().any(|n| n.starts_with("score_fig6")));
        let meta = reg.meta(reg.names()[0]).unwrap();
        assert!(!meta.path.is_empty());
    }

    #[test]
    fn executes_conv_pair_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut reg = ArtifactRegistry::open(&dir).unwrap();
        let name = "conv_pair_b8_g1024";
        if reg.meta(name).is_none() {
            eprintln!("skipping: {name} not in manifest");
            return;
        }
        let (b, g) = (8usize, 1024usize);
        let dt = 0.01f32;
        // exp(2) and exp(5) pdfs, same in every batch row
        let mut f = vec![0f32; b * g];
        let mut h = vec![0f32; b * g];
        for row in 0..b {
            for k in 0..g {
                let t = k as f32 * dt;
                f[row * g + k] = 2.0 * (-2.0 * t).exp();
                h[row * g + k] = 5.0 * (-5.0 * t).exp();
            }
        }
        let outs = reg
            .execute_f32(
                name,
                &[(&f, &[b, g]), (&h, &[b, g]), (&[dt], &[])],
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        let out = &outs[0];
        assert_eq!(out.len(), b * g);
        // compare against the native engine
        let tgrid: Vec<f64> = (0..g).map(|k| k as f64 * dt as f64).collect();
        let fr: Vec<f64> = tgrid.iter().map(|&t| 2.0 * (-2.0 * t).exp()).collect();
        let hr: Vec<f64> = tgrid.iter().map(|&t| 5.0 * (-5.0 * t).exp()).collect();
        let want = crate::compose::conv::conv_fft(&fr, &hr, dt as f64);
        for k in (0..g).step_by(37) {
            assert!(
                (out[k] as f64 - want[k]).abs() < 1e-3,
                "k={k}: {} vs {}",
                out[k],
                want[k]
            );
        }
    }
}
