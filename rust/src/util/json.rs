//! Minimal JSON: parser + writer for config files, workflow specs and the
//! AOT `manifest.json`.
//!
//! Implements the full RFC 8259 grammar (objects, arrays, strings with
//! escapes incl. `\uXXXX`, numbers, bools, null). Serialization is
//! deterministic (object keys keep insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as f64; integers round-trip to 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object. BTreeMap gives deterministic iteration; key order is not
    /// semantically meaningful in JSON.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Human-readable message.
    pub msg: String,
    /// Byte offset in the input where the error occurred.
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document (must consume the entire input).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    /// Number as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// String contents.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array contents.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object contents.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            at: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            self.i -= 1; // compensated below
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().at(2).unwrap().get("b").unwrap(),
            &Json::Bool(false)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"obj":{"k":"v \"q\""},"s":"✓"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".to_string())
        );
    }

    #[test]
    fn fuzz_never_panics() {
        use crate::util::prop;
        // random byte soups: the parser must reject or accept, never panic
        prop::run("json parser total on garbage", 300, |g| {
            let len = g.usize_in(0, 64);
            let charset: Vec<char> =
                "{}[]\",:0123456789.eE+-truefalsn\\ \t\n\u{1F600}é".chars().collect();
            let s: String = (0..len).map(|_| *g.choose(&charset)).collect();
            let _ = Json::parse(&s); // must not panic
        });
    }

    #[test]
    fn fuzz_roundtrip_valid_values() {
        use crate::util::prop;
        prop::run("json roundtrip random trees", 100, |g| {
            fn gen_val(g: &mut crate::util::prop::Gen, depth: usize) -> Json {
                match if depth > 2 { g.usize_in(0, 2) } else { g.usize_in(0, 4) } {
                    0 => Json::Null,
                    1 => Json::Bool(g.bool(0.5)),
                    2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                    3 => Json::Arr((0..g.usize_in(0, 3)).map(|_| gen_val(g, depth + 1)).collect()),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..g.usize_in(0, 3) {
                            m.insert(format!("k{i}"), gen_val(g, depth + 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            let v = gen_val(g, 0);
            let back = Json::parse(&v.to_string()).expect("own output parses");
            assert_eq!(v, back);
        });
    }

    #[test]
    fn integer_roundtrip_is_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        assert_eq!(v.as_usize(), Some(42));
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
