//! Self-contained infrastructure: PRNG, JSON, statistics, property-test
//! harness, CLI parsing, and the library diagnostics channel
//! ([`warn`]).
//!
//! The build image is fully offline with a vendored crate set that carries
//! only the `xla` dependency chain, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `proptest`, `criterion`) are unavailable.
//! Everything in this module is a deliberately small, well-tested,
//! dependency-free replacement for exactly the slices of those crates the
//! rest of the library needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod warn;
