//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and generates usage text from the declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
}

/// Parsed argument values.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional (non-option) arguments in order.
    pub positional: Vec<String>,
}

impl Cli {
    /// New parser with program name and one-line description.
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{lhs:<26}{}{def}\n", o.help));
        }
        s
    }

    /// Parse an argv slice (without the program name). Returns Err with a
    /// message (or the usage text for `--help`).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    out.values.insert(key, v);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    /// String value of an option (present by construction if it had a default).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option --{name} was not declared with a default"))
    }

    /// Parse an option as any FromStr type.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        self.get(name)
            .parse::<T>()
            .map_err(|_| format!("option --{name}: cannot parse {:?}", self.get(name)))
    }

    /// Whether a boolean flag was passed.
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cli = Cli::new("x", "test").opt("n", "10", "count").opt("mode", "fast", "mode");
        let a = cli.parse(&argv(&["--n", "20"])).unwrap();
        assert_eq!(a.get_as::<u32>("n").unwrap(), 20);
        assert_eq!(a.get("mode"), "fast");
    }

    #[test]
    fn equals_syntax_and_flags() {
        let cli = Cli::new("x", "test").opt("seed", "0", "seed").flag("verbose", "talk");
        let a = cli.parse(&argv(&["--seed=99", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get_as::<u64>("seed").unwrap(), 99);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let cli = Cli::new("x", "test");
        assert!(cli.parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let cli = Cli::new("x", "test").opt("n", "1", "count");
        assert!(cli.parse(&argv(&["--n"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let cli = Cli::new("prog", "about").opt("n", "1", "count");
        let err = cli.parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("prog — about"));
        assert!(err.contains("--n"));
    }
}
