//! The one channel for library diagnostics.
//!
//! A handful of deep library paths emit rare, non-fatal diagnostics
//! that a caller cannot usefully handle as errors but should be able to
//! see (and, in production, to silence): the [`GridSpec`] auto-sizers
//! clamping a degenerate horizon, the runtime scorer falling back from
//! a failed XLA engine to the native one. All of them flow through
//! [`warn`], which writes one line to stderr with a `dcflow: ` prefix.
//!
//! Silencing: call [`set_quiet`]`(true)` from code, or set the
//! environment variable `DCFLOW_QUIET` to `1` or `true` before the
//! first diagnostic is emitted. The env var is read once and cached;
//! [`set_quiet`] always wins over it.
//!
//! ```
//! use dcflow::util::warn;
//!
//! warn::set_quiet(true);
//! warn::warn("this line is swallowed");
//! assert!(warn::quiet());
//! warn::set_quiet(false);
//! assert!(!warn::quiet());
//! ```
//!
//! [`GridSpec`]: crate::compose::grid::GridSpec

use std::sync::atomic::{AtomicU8, Ordering};

/// Mode not yet decided: first [`quiet`] call consults `DCFLOW_QUIET`.
const UNSET: u8 = 0;
const LOUD: u8 = 1;
const QUIET: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(UNSET);

/// Silence (`true`) or re-enable (`false`) dcflow library diagnostics
/// process-wide. Overrides the `DCFLOW_QUIET` environment variable.
pub fn set_quiet(quiet: bool) {
    MODE.store(if quiet { QUIET } else { LOUD }, Ordering::Relaxed);
}

/// Whether diagnostics are currently silenced. On the first call with
/// no prior [`set_quiet`], the `DCFLOW_QUIET` env var (`1`/`true`,
/// case-insensitive) decides and is cached.
pub fn quiet() -> bool {
    match MODE.load(Ordering::Relaxed) {
        LOUD => false,
        QUIET => true,
        _ => {
            let env_quiet = std::env::var("DCFLOW_QUIET")
                .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
                .unwrap_or(false);
            let desired = if env_quiet { QUIET } else { LOUD };
            // compare_exchange so a concurrent set_quiet() is never
            // overwritten by the env default (set_quiet always wins)
            match MODE.compare_exchange(UNSET, desired, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => env_quiet,
                Err(current) => current == QUIET,
            }
        }
    }
}

/// Emit one library diagnostic line (`dcflow: <msg>`) to stderr unless
/// silenced. Library code must route its diagnostics here instead of
/// calling `eprintln!` directly, so users get exactly one switch.
///
/// When telemetry capture is on ([`crate::obs`]), every diagnostic is
/// additionally recorded as a `level=warn` instant event — traces show
/// warnings next to the spans that produced them. `DCFLOW_QUIET` only
/// gates stderr; it does not filter the trace.
pub fn warn(msg: &str) {
    crate::obs::warn_event(msg);
    if !quiet() {
        eprintln!("dcflow: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_quiet_toggles_and_wins() {
        // the global is process-wide; restore LOUD so other tests that
        // exercise warning paths keep their stderr diagnostics
        set_quiet(true);
        assert!(quiet());
        warn("suppressed diagnostic (not visible in test output)");
        set_quiet(false);
        assert!(!quiet());
    }
}
