//! Streaming statistics: Welford moments, histograms, empirical quantiles,
//! and two-sample Kolmogorov–Smirnov — the numeric backbone of the
//! monitors (`crate::monitor`) and the simulator's result reporting.

/// Numerically stable streaming mean/variance (Welford's algorithm),
/// plus min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for the empty accumulator).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance (÷ n).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (÷ n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Standard deviation (population).
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation seen.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-range uniform histogram with overflow bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    counts: Vec<u64>,
    overflow: u64,
    total: u64,
}

impl Histogram {
    /// `bins` uniform buckets over `[lo, hi)`; values above `hi` land in
    /// the overflow bucket, values below `lo` clamp into bucket 0.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            width: (hi - lo) / bins as f64,
            counts: vec![0; bins],
            overflow: 0,
            total: 0,
        }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.total += 1;
        let idx = ((x - self.lo) / self.width).floor();
        if idx < 0.0 {
            self.counts[0] += 1;
        } else if (idx as usize) < self.counts.len() {
            self.counts[idx as usize] += 1;
        } else {
            self.overflow += 1;
        }
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Fraction of observations in the overflow bucket.
    pub fn overflow_frac(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.overflow as f64 / self.total as f64
        }
    }

    /// Empirical CDF evaluated at bucket right edges; the final entry
    /// excludes overflow mass (so it is < 1 when the range clipped).
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .map(|&c| {
                acc += c;
                acc as f64 / self.total.max(1) as f64
            })
            .collect()
    }

    /// Normalized PDF estimate (density per unit x) at bucket centers.
    pub fn pdf(&self) -> Vec<f64> {
        let norm = self.total.max(1) as f64 * self.width;
        self.counts.iter().map(|&c| c as f64 / norm).collect()
    }

    /// Approximate q-quantile by scanning the CDF (bucket right edge).
    pub fn quantile(&self, q: f64) -> f64 {
        let mut acc = 0u64;
        let need = (q * self.total as f64).ceil() as u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= need {
                return self.lo + (i + 1) as f64 * self.width;
            }
        }
        self.lo + self.counts.len() as f64 * self.width
    }

    /// Bucket centers (x coordinates for `pdf()`).
    pub fn centers(&self) -> Vec<f64> {
        (0..self.counts.len())
            .map(|i| self.lo + (i as f64 + 0.5) * self.width)
            .collect()
    }
}

/// Exact empirical quantile of a sample (interpolated, type-7 like numpy).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let h = (sorted.len() - 1) as f64 * q.clamp(0.0, 1.0);
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    sorted[lo] + (sorted[hi] - sorted[lo]) * (h - lo as f64)
}

/// Two-sample Kolmogorov–Smirnov statistic: sup |F_a - F_b|.
/// Both inputs must be sorted ascending (`total_cmp` order for NaN
/// tolerance). Tied values advance both empirical CDFs together, so
/// identical samples — including fully constant windows — score 0
/// rather than a spurious gap; NaN tails are skipped.
pub fn ks_statistic(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let (mut i, mut j) = (0usize, 0usize);
    let mut d: f64 = 0.0;
    while i < a.len() && j < b.len() {
        let x = a[i].min(b[j]);
        let (i0, j0) = (i, j);
        while i < a.len() && a[i] <= x {
            i += 1;
        }
        while j < b.len() && b[j] <= x {
            j += 1;
        }
        if i == i0 && j == j0 {
            // both heads are NaN (unordered with everything): no
            // rankable mass remains
            break;
        }
        let fa = i as f64 / a.len() as f64;
        let fb = j as f64 / b.len() as f64;
        d = d.max((fa - fb).abs());
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..1000).map(|_| r.f64() * 10.0).collect();
        let mut whole = Welford::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = Welford::new();
        let mut b = Welford::new();
        xs[..300].iter().for_each(|&x| a.push(x));
        xs[300..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn histogram_pdf_integrates_to_one() {
        let mut r = Rng::new(5);
        let mut h = Histogram::new(0.0, 10.0, 100);
        for _ in 0..10_000 {
            h.push(r.exponential(1.0));
        }
        let integral: f64 = h.pdf().iter().sum::<f64>() * 0.1;
        assert!((integral + h.overflow_frac() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantile_exponential() {
        let mut r = Rng::new(7);
        let mut h = Histogram::new(0.0, 20.0, 2000);
        for _ in 0..100_000 {
            h.push(r.exponential(1.0));
        }
        let med = h.quantile(0.5);
        assert!((med - (2.0f64).ln()).abs() < 0.05, "median {med}");
    }

    #[test]
    fn exact_quantile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.5), 2.0);
        assert!((quantile(&xs, 0.25) - 1.0).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn ks_same_distribution_small() {
        let mut r = Rng::new(11);
        let mut a: Vec<f64> = (0..5000).map(|_| r.exponential(2.0)).collect();
        let mut b: Vec<f64> = (0..5000).map(|_| r.exponential(2.0)).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!(ks_statistic(&a, &b) < 0.05);
    }

    #[test]
    fn ks_different_distribution_large() {
        let mut r = Rng::new(13);
        let mut a: Vec<f64> = (0..5000).map(|_| r.exponential(1.0)).collect();
        let mut b: Vec<f64> = (0..5000).map(|_| r.exponential(4.0)).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!(ks_statistic(&a, &b) > 0.3);
    }

    #[test]
    fn ks_handles_ties_exactly() {
        // identical constant samples: the CDFs coincide, KS must be 0
        assert_eq!(ks_statistic(&[0.5; 100], &[0.5; 100]), 0.0);
        // identical mixed samples with ties
        let xs = [1.0, 1.0, 2.0, 2.0, 3.0];
        assert_eq!(ks_statistic(&xs, &xs), 0.0);
        // disjoint constants: maximal separation
        assert_eq!(ks_statistic(&[1.0; 10], &[2.0; 10]), 1.0);
    }

    #[test]
    fn ks_tolerates_nan_tails() {
        // total_cmp sorting puts NaN last; the walk must terminate
        let a = [1.0, 2.0, f64::NAN];
        let b = [1.5, f64::NAN, f64::NAN];
        let d = ks_statistic(&a, &b);
        assert!(d.is_finite());
    }
}
