//! Mini property-based testing harness (proptest is not in the vendored
//! crate set).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! runner executes it for `cases` random seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```
//! use dcflow::util::prop::{run, Gen};
//! run("addition commutes", 200, |g: &mut Gen| {
//!     let (a, b) = (g.f64_in(-1e6, 1e6), g.f64_in(-1e6, 1e6));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Two environment knobs pin the harness for CI and local replays:
//!
//! * `DCFLOW_PROP_CASES=<n>` overrides every suite's case count (raise
//!   it for soak runs, lower it for quick iteration);
//! * `DCFLOW_PROP_SEED=<seed>` (decimal or `0x`-hex, the exact value a
//!   failure echoes) replays **only** that seed, skipping the normal
//!   case sweep — paste the seed from a CI failure to reproduce it
//!   locally in one run.

use crate::util::rng::Rng;

/// Random value source handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Seed of this case (printed on failure for replay).
    pub seed: u64,
}

impl Gen {
    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Biased coin.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.f64() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vector of values from a generator function.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// A positive rate-like value, log-uniform over [0.1, 20).
    pub fn rate(&mut self) -> f64 {
        (self.f64_in(0.1f64.ln(), 20.0f64.ln())).exp()
    }

    /// Access to the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` deterministic seeds derived from the property
/// name (stable across runs/machines). Panics with the failing seed and
/// the `DCFLOW_PROP_SEED` incantation that replays it. `cases` is
/// overridden by `DCFLOW_PROP_CASES` when set; `DCFLOW_PROP_SEED` runs
/// exactly that one seed instead of the sweep (see the
/// [module docs](self)).
pub fn run(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    if let Some(seed) = env_u64("DCFLOW_PROP_SEED") {
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
        };
        prop(&mut g);
        return;
    }
    let cases = env_u64("DCFLOW_PROP_CASES").unwrap_or(cases);
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}; \
                 rerun with DCFLOW_PROP_SEED={seed:#x}): {msg}"
            );
        }
    }
}

/// Parse a u64 environment knob (decimal or `0x`-prefixed hex). A set
/// but malformed value panics loudly — a silently ignored typo in
/// `DCFLOW_PROP_SEED` would "pass" the wrong test.
fn env_u64(name: &str) -> Option<u64> {
    let raw = std::env::var(name).ok()?;
    match parse_u64(raw.trim()) {
        Some(v) => Some(v),
        None => panic!("{name} must be a u64 (decimal or 0x-hex), got '{raw}'"),
    }
}

fn parse_u64(v: &str) -> Option<u64> {
    if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
    };
    prop(&mut g);
}

fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("tautology", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        run("always-fails", 5, |_| panic!("boom"));
    }

    #[test]
    fn usize_in_bounds_inclusive() {
        run("usize_in bounds", 100, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            assert!(x >= lo && x <= hi);
        });
    }

    #[test]
    fn rate_is_positive_bounded() {
        run("rate positive", 200, |g| {
            let r = g.rate();
            assert!(r >= 0.1 && r < 20.0, "rate {r}");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = 0.0;
        replay(12345, |g| v1 = g.f64_in(0.0, 1.0));
        let mut v2 = 0.0;
        replay(12345, |g| v2 = g.f64_in(0.0, 1.0));
        assert_eq!(v1, v2);
    }

    #[test]
    fn env_knob_values_parse_both_radices() {
        // the parser behind DCFLOW_PROP_CASES / DCFLOW_PROP_SEED (the
        // env vars themselves are not set here: mutating the process
        // environment would race parallel tests)
        assert_eq!(parse_u64("42"), Some(42));
        assert_eq!(parse_u64("0x2a"), Some(42));
        assert_eq!(parse_u64("0X2A"), Some(42));
        assert_eq!(parse_u64("0xDEADBEEF"), Some(0xDEAD_BEEF));
        assert_eq!(parse_u64(""), None);
        assert_eq!(parse_u64("nope"), None);
        assert_eq!(parse_u64("-3"), None);
        assert_eq!(parse_u64("0x"), None);
    }
}
