//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! All stochastic components (simulator, workers, property tests) take an
//! explicit seed so every experiment in EXPERIMENTS.md is reproducible
//! bit-for-bit. The generator is Blackman–Vigna xoshiro256++, which passes
//! BigCrush and is the de-facto default of the `rand` ecosystem.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lam` by inversion.
    pub fn exponential(&mut self, lam: f64) -> f64 {
        debug_assert!(lam > 0.0);
        -self.f64_open().ln() / lam
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-worker generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {counts:?}");
        }
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(13);
        let lam = 2.5;
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.exponential(lam)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lam).abs() < 0.01);
        assert!((var - 1.0 / (lam * lam)).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(19);
        let mut b = a.fork();
        let av: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
