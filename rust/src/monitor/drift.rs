//! Distribution drift detection: decides *when* the coordinator should
//! re-run the optimizer (paper Alg. 3's "gradually updated" loop).
//!
//! Splits the monitor window into a reference half and a recent half and
//! compares them with the two-sample KS statistic. Threshold defaults to
//! the 1%-significance asymptotic critical value `1.63·sqrt(2/n)`.

use crate::util::stats::ks_statistic;

/// Drift verdict for one server.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftReport {
    /// Two-sample KS statistic between reference and recent halves.
    pub ks: f64,
    /// Critical value used.
    pub threshold: f64,
    /// true when ks > threshold.
    pub drifted: bool,
}

/// Detect drift within a window of samples (chronological order).
/// Returns None when fewer than `2 * min_half` samples are available.
pub fn detect_drift(samples: &[f64], min_half: usize) -> Option<DriftReport> {
    let n = samples.len();
    if n < 2 * min_half {
        return None;
    }
    let mid = n / 2;
    let mut a = samples[..mid].to_vec();
    let mut b = samples[mid..].to_vec();
    a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let ks = ks_statistic(&a, &b);
    let half = mid.min(n - mid) as f64;
    let threshold = 1.63 * (2.0 / half).sqrt();
    Some(DriftReport {
        ks,
        threshold,
        drifted: ks > threshold,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;
    use crate::util::rng::Rng;

    #[test]
    fn stable_server_no_drift() {
        let d = ServiceDist::exponential(3.0);
        let mut rng = Rng::new(21);
        let samples: Vec<f64> = (0..4000).map(|_| d.sample(&mut rng)).collect();
        let r = detect_drift(&samples, 100).unwrap();
        assert!(!r.drifted, "ks {} thr {}", r.ks, r.threshold);
    }

    #[test]
    fn degradation_detected() {
        let fast = ServiceDist::exponential(10.0);
        let slow = ServiceDist::exponential(3.0);
        let mut rng = Rng::new(23);
        let mut samples: Vec<f64> = (0..2000).map(|_| fast.sample(&mut rng)).collect();
        samples.extend((0..2000).map(|_| slow.sample(&mut rng)));
        let r = detect_drift(&samples, 100).unwrap();
        assert!(r.drifted, "ks {} thr {}", r.ks, r.threshold);
    }

    #[test]
    fn straggler_onset_detected() {
        // mode shift: 0% -> 20% straggling in the second half
        let clean = ServiceDist::exponential(8.0);
        let straggly = ServiceDist::straggler(8.0, 0.4, 0.2, 0.0);
        let mut rng = Rng::new(25);
        let mut samples: Vec<f64> = (0..3000).map(|_| clean.sample(&mut rng)).collect();
        samples.extend((0..3000).map(|_| straggly.sample(&mut rng)));
        assert!(detect_drift(&samples, 100).unwrap().drifted);
    }

    #[test]
    fn needs_enough_samples() {
        assert!(detect_drift(&[1.0; 50], 100).is_none());
        assert!(detect_drift(&[1.0; 199], 100).is_none());
    }
}
