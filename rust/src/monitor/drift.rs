//! Distribution drift detection: decides *when* the coordinator should
//! re-run the optimizer (paper Alg. 3's "gradually updated" loop).
//!
//! Splits the monitor window into a reference half and a recent half and
//! compares them with the two-sample KS statistic. Threshold defaults to
//! the 1%-significance asymptotic critical value `1.63·sqrt(2/n)`.

use crate::util::stats::ks_statistic;

/// Drift verdict for one server.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftReport {
    /// Two-sample KS statistic between reference and recent halves.
    pub ks: f64,
    /// Critical value used.
    pub threshold: f64,
    /// true when ks > threshold.
    pub drifted: bool,
}

/// Detect drift within a window of samples (chronological order).
/// Returns None when fewer than `2 * min_half` samples are available;
/// `min_half` values `< 1` are treated as 1 (a zero would accept
/// windows too small to split into two non-empty halves — an empty
/// reference half and an infinite/NaN threshold).
pub fn detect_drift(samples: &[f64], min_half: usize) -> Option<DriftReport> {
    let min_half = min_half.max(1);
    let n = samples.len();
    if n < 2 * min_half {
        return None;
    }
    let mid = n / 2;
    let mut a = samples[..mid].to_vec();
    let mut b = samples[mid..].to_vec();
    // total_cmp, not partial_cmp().unwrap(): a NaN observation (e.g. a
    // corrupted measurement) must not panic the monitoring loop
    a.sort_by(f64::total_cmp);
    b.sort_by(f64::total_cmp);
    let ks = ks_statistic(&a, &b);
    let half = mid.min(n - mid) as f64;
    let threshold = 1.63 * (2.0 / half).sqrt();
    let drifted = ks > threshold;
    if crate::obs::enabled() {
        crate::obs::event(
            "monitor.drift",
            vec![
                ("drifted".to_string(), drifted.into()),
                ("ks".to_string(), ks.into()),
                ("threshold".to_string(), threshold.into()),
            ],
        );
    }
    Some(DriftReport {
        ks,
        threshold,
        drifted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;
    use crate::util::rng::Rng;

    #[test]
    fn stable_server_no_drift() {
        let d = ServiceDist::exponential(3.0);
        let mut rng = Rng::new(21);
        let samples: Vec<f64> = (0..4000).map(|_| d.sample(&mut rng)).collect();
        let r = detect_drift(&samples, 100).unwrap();
        assert!(!r.drifted, "ks {} thr {}", r.ks, r.threshold);
    }

    #[test]
    fn degradation_detected() {
        let fast = ServiceDist::exponential(10.0);
        let slow = ServiceDist::exponential(3.0);
        let mut rng = Rng::new(23);
        let mut samples: Vec<f64> = (0..2000).map(|_| fast.sample(&mut rng)).collect();
        samples.extend((0..2000).map(|_| slow.sample(&mut rng)));
        let r = detect_drift(&samples, 100).unwrap();
        assert!(r.drifted, "ks {} thr {}", r.ks, r.threshold);
    }

    #[test]
    fn straggler_onset_detected() {
        // mode shift: 0% -> 20% straggling in the second half
        let clean = ServiceDist::exponential(8.0);
        let straggly = ServiceDist::straggler(8.0, 0.4, 0.2, 0.0);
        let mut rng = Rng::new(25);
        let mut samples: Vec<f64> = (0..3000).map(|_| clean.sample(&mut rng)).collect();
        samples.extend((0..3000).map(|_| straggly.sample(&mut rng)));
        assert!(detect_drift(&samples, 100).unwrap().drifted);
    }

    #[test]
    fn needs_enough_samples() {
        assert!(detect_drift(&[1.0; 50], 100).is_none());
        assert!(detect_drift(&[1.0; 199], 100).is_none());
    }

    #[test]
    fn zero_min_half_is_clamped_not_degenerate() {
        // regression: min_half == 0 used to pass the size guard on any
        // window, slicing an empty reference half (mid == 0 for n == 1)
        // and producing an infinite/NaN threshold
        assert!(detect_drift(&[], 0).is_none());
        assert!(detect_drift(&[1.0], 0).is_none());
        // two samples is the smallest window the clamp admits, and its
        // verdict must be finite and well-formed
        let r = detect_drift(&[1.0, 2.0], 0).expect("clamped to min_half = 1");
        assert!(r.ks.is_finite());
        assert!(r.threshold.is_finite() && r.threshold > 0.0);
        // clamped call agrees with the explicit min_half = 1 call
        let explicit = detect_drift(&[1.0, 2.0], 1).unwrap();
        assert_eq!(r, explicit);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // regression: partial_cmp().unwrap() panicked here before the
        // total_cmp hardening
        let mut samples = vec![1.0; 400];
        samples[7] = f64::NAN;
        samples[350] = f64::NAN;
        let r = detect_drift(&samples, 100);
        assert!(r.is_some()); // verdict value is unspecified, survival is not
    }

    #[test]
    fn constant_samples_have_zero_ks() {
        let r = detect_drift(&[0.5; 1000], 100).unwrap();
        assert_eq!(r.ks, 0.0);
        assert!(!r.drifted);
    }

    #[test]
    fn window_exactly_twice_min_half_is_enough() {
        let samples = vec![1.0; 200];
        let r = detect_drift(&samples, 100).unwrap();
        // halves of 100 each, threshold from the smaller half
        assert!((r.threshold - 1.63 * (2.0_f64 / 100.0).sqrt()).abs() < 1e-12);
        assert!(!r.drifted);
    }

    #[test]
    fn drift_then_recover_verdict_transitions() {
        // law A → law B → law B: sliding the window across the change
        // point must go no-drift → drift → no-drift
        let a = ServiceDist::exponential(10.0);
        let b = ServiceDist::exponential(2.0);
        let mut rng = Rng::new(27);
        let phase_a: Vec<f64> = (0..2000).map(|_| a.sample(&mut rng)).collect();
        let phase_b: Vec<f64> = (0..4000).map(|_| b.sample(&mut rng)).collect();

        // window fully inside phase A: stable
        assert!(!detect_drift(&phase_a, 100).unwrap().drifted);
        // window straddling the change point: drifted
        let mut straddle = phase_a[1000..].to_vec();
        straddle.extend_from_slice(&phase_b[..1000]);
        assert!(detect_drift(&straddle, 100).unwrap().drifted);
        // window fully inside phase B: the new law is the new normal
        assert!(!detect_drift(&phase_b[2000..], 100).unwrap().drifted);
    }
}
