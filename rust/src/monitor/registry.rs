//! Cluster-wide monitor registry: one [`ServerMonitor`] per server plus
//! the aggregated re-optimization trigger the coordinator polls.

use crate::dist::ServiceDist;
use crate::monitor::drift::{detect_drift, DriftReport};
use crate::monitor::estimator::ServerMonitor;
use crate::sched::server::Server;

/// Monitors for a pool of servers.
#[derive(Clone, Debug)]
pub struct MonitorRegistry {
    monitors: Vec<ServerMonitor>,
    min_fit_samples: usize,
}

impl MonitorRegistry {
    /// One monitor per server, each with `window` samples.
    pub fn new(n_servers: usize, window: usize, min_fit_samples: usize) -> MonitorRegistry {
        MonitorRegistry {
            monitors: (0..n_servers).map(|_| ServerMonitor::new(window)).collect(),
            min_fit_samples,
        }
    }

    /// Record a service-time observation for `server_id`.
    pub fn observe(&mut self, server_id: usize, service_time: f64) {
        self.monitors[server_id].observe(service_time);
    }

    /// Access a monitor.
    pub fn monitor(&self, server_id: usize) -> &ServerMonitor {
        &self.monitors[server_id]
    }

    /// Number of monitored servers.
    pub fn len(&self) -> usize {
        self.monitors.len()
    }

    /// True when no servers are registered.
    pub fn is_empty(&self) -> bool {
        self.monitors.is_empty()
    }

    /// Refresh a server pool with fitted laws where available: servers
    /// without enough observations keep their prior law. Returns the
    /// number of servers whose law was refreshed.
    pub fn refresh_pool(&self, servers: &mut [Server]) -> usize {
        let mut updated = 0;
        for s in servers.iter_mut() {
            if let Some((_, fitted, _)) = self.monitors[s.id].fitted(self.min_fit_samples) {
                s.dist = fitted;
                updated += 1;
            }
        }
        updated
    }

    /// Fitted law for one server, if estimable.
    pub fn fitted_dist(&self, server_id: usize) -> Option<ServiceDist> {
        self.monitors[server_id]
            .fitted(self.min_fit_samples)
            .map(|(_, d, _)| d)
    }

    /// Drift reports for all servers with enough data.
    pub fn drift_reports(&self, min_half: usize) -> Vec<(usize, DriftReport)> {
        self.monitors
            .iter()
            .enumerate()
            .filter_map(|(id, m)| detect_drift(&m.window_samples(), min_half).map(|r| (id, r)))
            .collect()
    }

    /// True when any server drifted — the Alg. 3 re-optimization trigger.
    pub fn any_drifted(&self, min_half: usize) -> bool {
        self.drift_reports(min_half).iter().any(|(_, r)| r.drifted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn refresh_updates_only_observed_servers() {
        let truth = ServiceDist::exponential(4.0);
        let mut reg = MonitorRegistry::new(3, 4096, 256);
        let mut rng = Rng::new(31);
        for _ in 0..2000 {
            reg.observe(1, truth.sample(&mut rng));
        }
        let mut pool = Server::pool_exponential(&[1.0, 1.0, 1.0]);
        let updated = reg.refresh_pool(&mut pool);
        assert_eq!(updated, 1);
        assert!((pool[1].dist.mean() - 0.25).abs() < 0.02);
        assert!((pool[0].dist.mean() - 1.0).abs() < 1e-9); // prior kept
    }

    #[test]
    fn drift_trigger_fires_cluster_wide() {
        let mut reg = MonitorRegistry::new(2, 4096, 256);
        let fast = ServiceDist::exponential(10.0);
        let slow = ServiceDist::exponential(2.0);
        let mut rng = Rng::new(33);
        for _ in 0..1000 {
            reg.observe(0, fast.sample(&mut rng));
            reg.observe(1, fast.sample(&mut rng));
        }
        assert!(!reg.any_drifted(100));
        for _ in 0..1000 {
            reg.observe(1, slow.sample(&mut rng));
        }
        assert!(reg.any_drifted(100));
    }
}
