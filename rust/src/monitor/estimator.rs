//! Sliding-window service-time estimation for one server.

use crate::dist::empirical::Empirical;
use crate::dist::fit::{select_family, Family};
use crate::dist::ServiceDist;
use crate::util::stats::Welford;
use std::collections::VecDeque;

/// Monitors one server: keeps the last `window` observed service times,
/// streaming lifetime moments, and (re)fits a Table-1 family on demand.
#[derive(Clone, Debug)]
pub struct ServerMonitor {
    window: usize,
    samples: VecDeque<f64>,
    lifetime: Welford,
}

impl ServerMonitor {
    /// Monitor with a sliding window of `window` samples.
    pub fn new(window: usize) -> ServerMonitor {
        assert!(window >= 8, "window too small to estimate anything");
        ServerMonitor {
            window,
            samples: VecDeque::with_capacity(window),
            lifetime: Welford::new(),
        }
    }

    /// Record one observed service time.
    pub fn observe(&mut self, service_time: f64) {
        debug_assert!(service_time.is_finite() && service_time >= 0.0);
        if self.samples.len() == self.window {
            self.samples.pop_front();
        }
        self.samples.push_back(service_time);
        self.lifetime.push(service_time);
    }

    /// Number of samples currently in the window.
    pub fn window_len(&self) -> usize {
        self.samples.len()
    }

    /// Total observations ever.
    pub fn count(&self) -> u64 {
        self.lifetime.count()
    }

    /// Window mean (None until the window has >= 2 samples).
    pub fn mean(&self) -> Option<f64> {
        if self.samples.len() < 2 {
            return None;
        }
        Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
    }

    /// Windowed samples, oldest first.
    pub fn window_samples(&self) -> Vec<f64> {
        self.samples.iter().copied().collect()
    }

    /// Non-parametric estimate from the current window.
    pub fn empirical(&self) -> Option<Empirical> {
        if self.samples.len() < 8 {
            return None;
        }
        Some(Empirical::from_samples(&self.window_samples()))
    }

    /// Parametric re-fit: best Table-1 family for the current window
    /// (None until enough samples; `min_samples` gates fit stability).
    pub fn fitted(&self, min_samples: usize) -> Option<(Family, ServiceDist, f64)> {
        if self.samples.len() < min_samples.max(8) {
            return None;
        }
        Some(select_family(&self.window_samples()))
    }

    /// Lifetime mean (all observations, not just the window).
    pub fn lifetime_mean(&self) -> f64 {
        self.lifetime.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;
    use crate::util::rng::Rng;

    #[test]
    fn window_slides() {
        let mut m = ServerMonitor::new(8);
        for i in 0..20 {
            m.observe(i as f64);
        }
        assert_eq!(m.window_len(), 8);
        assert_eq!(m.count(), 20);
        assert_eq!(m.window_samples(), (12..20).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn fit_recovers_law_from_window() {
        let truth = ServiceDist::delayed_exponential(5.0, 0.2);
        let mut rng = Rng::new(3);
        let mut m = ServerMonitor::new(4096);
        for _ in 0..4096 {
            m.observe(truth.sample(&mut rng));
        }
        let (_, fitted, ks) = m.fitted(512).unwrap();
        assert!(ks < 0.05, "ks {ks}");
        assert!((fitted.mean() - truth.mean()).abs() < 0.05 * truth.mean());
    }

    #[test]
    fn tracks_regime_change() {
        // server degrades mid-stream: window forgets the old regime
        let fast = ServiceDist::exponential(10.0);
        let slow = ServiceDist::exponential(1.0);
        let mut rng = Rng::new(5);
        let mut m = ServerMonitor::new(1000);
        for _ in 0..5000 {
            m.observe(fast.sample(&mut rng));
        }
        for _ in 0..1500 {
            m.observe(slow.sample(&mut rng));
        }
        // window now holds only slow samples
        assert!((m.mean().unwrap() - 1.0).abs() < 0.15, "mean {:?}", m.mean());
        // lifetime mean is blended
        assert!(m.lifetime_mean() < 0.5);
    }

    #[test]
    fn gates_until_enough_samples() {
        let mut m = ServerMonitor::new(64);
        assert!(m.mean().is_none());
        assert!(m.empirical().is_none());
        assert!(m.fitted(16).is_none());
        for i in 0..16 {
            m.observe(1.0 + i as f64 * 0.01);
        }
        assert!(m.mean().is_some());
        assert!(m.empirical().is_some());
        assert!(m.fitted(16).is_some());
    }
}
