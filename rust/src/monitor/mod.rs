//! Online per-server service-time monitoring — the input side of the
//! paper's Algorithm 3 ("the necessary information to manage job
//! workflow is the performance distribution of each server which is
//! gradually updated over the time").
//!
//! * [`estimator::ServerMonitor`] — sliding-window sample store with
//!   streaming moments and parametric re-fitting ([`crate::dist::fit`]);
//! * [`drift`] — KS-based change detection that tells the coordinator
//!   when a server's law has shifted enough to warrant re-optimization;
//! * [`registry::MonitorRegistry`] — the per-cluster collection.

pub mod drift;
pub mod estimator;
pub mod registry;

pub use estimator::ServerMonitor;
pub use registry::MonitorRegistry;
