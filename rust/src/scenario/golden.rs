//! Golden-result corpus: committed traces + result summaries per zoo
//! scenario, with a bless-on-absence workflow.
//!
//! The corpus lives in `rust/tests/golden/` as two files per scenario:
//! `<name>.trace.jsonl` (the captured [`ExecTrace`]) and
//! `<name>.golden.json` (the canonical result summary produced by
//! [`golden_summary`]). [`check_or_bless`] is the single entry point
//! used by both the test suite and the `scenario_corpus` example:
//!
//! * files present → replay the committed trace twice, require
//!   bit-identical reports and re-captured traces (the determinism
//!   contract), and require the summary to match the committed golden
//!   byte-for-byte → [`GoldenStatus::Match`] or
//!   [`GoldenStatus::Divergence`];
//! * files absent → capture the scenario live, verify the same
//!   determinism contract plus capture≡replay, write both files →
//!   [`GoldenStatus::Blessed`]. Committing the written files freezes
//!   the behavior; any later semantic change shows up as a divergence
//!   in CI with a readable JSON diff.

use crate::coordinator::RunReport;
use crate::scenario::record::{ExecTrace, TRACE_FORMAT_VERSION};
use crate::scenario::zoo::{ScenarioClass, ScenarioSpec};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Directory holding the committed corpus (`rust/tests/golden/`,
/// resolved from the crate manifest so tests and examples agree
/// regardless of working directory).
pub fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn num_arr(xs: impl IntoIterator<Item = f64>) -> Json {
    Json::Arr(xs.into_iter().map(Json::Num).collect())
}

/// Canonical, deterministic result summary for a scenario run. Every
/// float passes through the crate's shortest-round-trip JSON writer, so
/// equal summaries are byte-equal strings and bit-equal numbers.
pub fn golden_summary(spec: &ScenarioSpec, report: &RunReport, trace: &ExecTrace) -> Json {
    let m = &report.metrics;
    let mut fields = vec![
        ("class", Json::Str(spec.class.label().into())),
        ("completed", Json::Num(m.completed as f64)),
        (
            "events",
            obj(vec![
                ("arrivals", Json::Num(trace.arrivals() as f64)),
                ("churns", Json::Num(trace.churns() as f64)),
                ("reopts", Json::Num(trace.reopts() as f64)),
                ("services", Json::Num(trace.services() as f64)),
            ]),
        ),
        (
            "final_allocation",
            obj(vec![
                (
                    "servers",
                    num_arr(
                        report
                            .final_allocation
                            .slot_server
                            .iter()
                            .map(|&s| s as f64),
                    ),
                ),
                (
                    "rates",
                    num_arr(report.final_allocation.slot_rate.iter().copied()),
                ),
            ]),
        ),
        ("format_version", Json::Num(TRACE_FORMAT_VERSION as f64)),
        ("makespan", Json::Num(m.makespan)),
        ("mean_latency", Json::Num(m.mean_latency())),
        ("p50_latency", Json::Num(m.latency_quantile(0.5))),
        ("p99_latency", Json::Num(m.latency_quantile(0.99))),
        ("reoptimizations", Json::Num(m.reoptimizations as f64)),
        ("scenario", Json::Str(spec.name.clone())),
        ("seed", Json::Num(spec.seed as f64)),
        (
            "swaps",
            Json::Arr(
                report
                    .swaps
                    .iter()
                    .map(|(at, reason)| {
                        obj(vec![
                            ("at", Json::Num(*at as f64)),
                            ("reason", Json::Str(reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "tasks_per_server",
            num_arr(m.tasks_per_server.iter().map(|&t| t as f64)),
        ),
        ("throughput", Json::Num(m.throughput())),
        ("var_latency", Json::Num(m.var_latency())),
    ];
    if spec.class == ScenarioClass::EmpiricalRefit {
        // the capture→refit→replan loop: plan against empirical laws
        // fitted from the replayed samples
        let refit = match spec.refit_plan(trace) {
            Ok(plan) => obj(vec![
                ("mean", Json::Num(plan.score.mean)),
                ("p99", Json::Num(plan.score.p99)),
                (
                    "servers",
                    num_arr(plan.allocation.slot_server.iter().map(|&s| s as f64)),
                ),
            ]),
            Err(e) => Json::Str(format!("infeasible: {e}")),
        };
        fields.push(("refit", refit));
    }
    obj(fields)
}

/// Bitwise equality of two run reports: every metric, the final
/// allocation and the swap history must match exactly (`f64::to_bits`,
/// not epsilon comparison — the determinism contract is *identical*,
/// not *close*).
pub fn reports_identical(a: &RunReport, b: &RunReport) -> bool {
    let bits = |x: f64| x.to_bits();
    let (ma, mb) = (&a.metrics, &b.metrics);
    ma.completed == mb.completed
        && ma.reoptimizations == mb.reoptimizations
        && bits(ma.makespan) == bits(mb.makespan)
        && bits(ma.mean_latency()) == bits(mb.mean_latency())
        && bits(ma.var_latency()) == bits(mb.var_latency())
        && bits(ma.latency_quantile(0.99)) == bits(mb.latency_quantile(0.99))
        && ma.tasks_per_server == mb.tasks_per_server
        && ma.busy_time.len() == mb.busy_time.len()
        && ma
            .busy_time
            .iter()
            .zip(&mb.busy_time)
            .all(|(x, y)| bits(*x) == bits(*y))
        && a.final_allocation.slot_server == b.final_allocation.slot_server
        && a.final_allocation.slot_rate.len() == b.final_allocation.slot_rate.len()
        && a.final_allocation
            .slot_rate
            .iter()
            .zip(&b.final_allocation.slot_rate)
            .all(|(x, y)| bits(*x) == bits(*y))
        && a.swaps == b.swaps
}

/// Outcome of a corpus check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GoldenStatus {
    /// Committed trace replayed deterministically and the summary
    /// matched the committed golden byte-for-byte.
    Match,
    /// No committed files existed; the scenario was captured, verified
    /// and written out (commit the new files to freeze it).
    Blessed,
    /// Determinism or golden-summary mismatch — the message says which.
    Divergence(String),
}

/// Replay + re-capture a trace twice and enforce the determinism
/// contract; returns the first report on success.
fn verified_replay(spec: &ScenarioSpec, trace: &ExecTrace) -> Result<RunReport, GoldenStatus> {
    let (r1, t1) = spec.replay(trace).map_err(GoldenStatus::Divergence)?;
    let (r2, t2) = spec.replay(trace).map_err(GoldenStatus::Divergence)?;
    if !reports_identical(&r1, &r2) || t1 != t2 {
        return Err(GoldenStatus::Divergence(format!(
            "{}: two replays of the same trace disagree (determinism broken)",
            spec.name
        )));
    }
    if &t1 != trace {
        return Err(GoldenStatus::Divergence(format!(
            "{}: re-captured trace differs from the input trace (capture/replay loop not closed)",
            spec.name
        )));
    }
    Ok(r1)
}

/// Check a scenario against the committed corpus, blessing it when no
/// corpus files exist yet. `Err` is reserved for IO/parse problems;
/// semantic mismatches come back as [`GoldenStatus::Divergence`].
pub fn check_or_bless(spec: &ScenarioSpec) -> Result<GoldenStatus, String> {
    let dir = corpus_dir();
    let trace_path = dir.join(format!("{}.trace.jsonl", spec.name));
    let golden_path = dir.join(format!("{}.golden.json", spec.name));

    if trace_path.exists() && golden_path.exists() {
        let text = std::fs::read_to_string(&trace_path)
            .map_err(|e| format!("read {}: {e}", trace_path.display()))?;
        let trace = ExecTrace::from_jsonl(&text)?;
        let report = match verified_replay(spec, &trace) {
            Ok(r) => r,
            Err(status) => return Ok(status),
        };
        let summary = golden_summary(spec, &report, &trace).to_string() + "\n";
        let committed = std::fs::read_to_string(&golden_path)
            .map_err(|e| format!("read {}: {e}", golden_path.display()))?;
        if summary != committed {
            return Ok(GoldenStatus::Divergence(format!(
                "{}: golden summary diverged\n-- committed --\n{committed}\n\
                 -- replayed --\n{summary}",
                spec.name
            )));
        }
        Ok(GoldenStatus::Match)
    } else {
        let status = bless(spec, &trace_path, &golden_path)?;
        Ok(status)
    }
}

/// Capture, verify and (re)write a scenario's corpus files
/// unconditionally — the `--regen` path after an intentional behavior
/// change.
pub fn regenerate(spec: &ScenarioSpec) -> Result<GoldenStatus, String> {
    let dir = corpus_dir();
    let trace_path = dir.join(format!("{}.trace.jsonl", spec.name));
    let golden_path = dir.join(format!("{}.golden.json", spec.name));
    bless(spec, &trace_path, &golden_path)
}

fn bless(
    spec: &ScenarioSpec,
    trace_path: &std::path::Path,
    golden_path: &std::path::Path,
) -> Result<GoldenStatus, String> {
    let (live_report, trace) = spec
        .capture()
        .map_err(|e| format!("capture of '{}' failed: {e}", spec.name))?;
    let replayed = match verified_replay(spec, &trace) {
        Ok(r) => r,
        Err(status) => return Ok(status),
    };
    if !reports_identical(&live_report, &replayed) {
        return Ok(GoldenStatus::Divergence(format!(
            "{}: replayed report differs from the live capture",
            spec.name
        )));
    }
    // round-trip the trace through the wire format before writing so
    // the committed bytes are exactly what future readers will parse
    let wire = trace.to_jsonl();
    let parsed = ExecTrace::from_jsonl(&wire)?;
    if parsed != trace {
        return Ok(GoldenStatus::Divergence(format!(
            "{}: trace does not round-trip through JSONL",
            spec.name
        )));
    }
    if let Some(parent) = trace_path.parent() {
        std::fs::create_dir_all(parent).map_err(|e| format!("mkdir {}: {e}", parent.display()))?;
    }
    std::fs::write(trace_path, &wire)
        .map_err(|e| format!("write {}: {e}", trace_path.display()))?;
    let summary = golden_summary(spec, &replayed, &trace).to_string() + "\n";
    std::fs::write(golden_path, summary)
        .map_err(|e| format!("write {}: {e}", golden_path.display()))?;
    Ok(GoldenStatus::Blessed)
}
