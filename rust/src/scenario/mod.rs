//! Scenario subsystem: trace capture, deterministic replay, the
//! workload zoo and the golden-result corpus.
//!
//! ```text
//!   ScenarioSpec ──capture()──▶ live Coordinator run ──▶ RunReport
//!        │                          │ (recording on)
//!        │                          ▼
//!        │                ExecTrace (versioned JSONL)
//!        │                          │
//!        └──replay(trace)──▶ scripted Coordinator run ──▶ RunReport'
//!                                   │ (re-recording)         ‖ bit-identical
//!                                   ▼                        ▼
//!                            ExecTrace' == ExecTrace    golden_summary
//!                                                            │
//!                                              rust/tests/golden/*.golden.json
//! ```
//!
//! * [`record`] — the durable trace format: [`record::ExecTrace`] in
//!   versioned JSONL, round-tripping bit-identically through
//!   [`crate::util::json`];
//! * [`replay`] — [`replay::Replay`] drives a captured trace back
//!   through the real `coordinator`/`monitor` stack with scripted
//!   workers and virtual time;
//! * [`zoo`] — [`zoo::ScenarioSpec`] generators for the workload
//!   classes (heterogeneous pools, correlated stragglers, churn, DAG
//!   pipelines, heavy-tail extremes, empirical re-fits);
//! * [`golden`] — the committed corpus with a bless-on-absence
//!   workflow ([`golden::check_or_bless`]).
//!
//! The data-flow diagram above is documented in prose in
//! `docs/ARCHITECTURE.md` ("Scenario subsystem"); the trace format and
//! the bench matrix schema are in `docs/BENCHMARKS.md`.

pub mod golden;
pub mod record;
pub mod replay;
pub mod zoo;

pub use golden::{check_or_bless, golden_summary, regenerate, reports_identical, GoldenStatus};
pub use record::{ChurnKind, ExecTrace, Recorder, TraceEvent, TraceHeader, TRACE_FORMAT_VERSION};
pub use replay::Replay;
pub use zoo::{ChurnAction, ChurnOp, ScenarioClass, ScenarioSpec};
