//! The workload zoo: named, seeded scenario generators covering the
//! regimes the paper's argument lives or dies on.
//!
//! Each [`ScenarioSpec`] fully determines a run — workflow, server
//! pool, hidden worker laws, coordinator config, arrival stream and
//! (for the churn class) a membership schedule — from its name and
//! seed alone. [`ScenarioSpec::capture`] executes the scenario on the
//! live coordinator stack and records an
//! [`ExecTrace`](crate::scenario::record::ExecTrace);
//! [`ScenarioSpec::replay`] feeds a captured trace back through
//! [`crate::scenario::Replay`]. The committed golden corpus
//! (`rust/tests/golden/`) holds one trace + summary per class.
//!
//! Classes (mirroring the survey taxonomy in PAPERS.md):
//!
//! * **HeterogeneousPool** — fig. 6 workflow on a pool whose service
//!   rates span 12×; allocation quality dominates.
//! * **CorrelatedStragglers** — three of six servers degrade *together*
//!   into a straggler mixture mid-run; the KS monitor must catch the
//!   correlated onset and the planner must route around it.
//! * **WorkerChurn** — a fast server joins a third of the way in and
//!   is decommissioned at two thirds; arrivals carry a compressed
//!   burst composed with the `sim::trace` helpers.
//! * **DagPipeline** — a non-trivial TTSP-reducible stage DAG run
//!   through [`FlowDag::to_series_parallel`].
//! * **HeavyTailExtreme** — Table-1 families at their nastiest
//!   committed corners (Pareto shape 2.4 barely above finite variance,
//!   Weibull shape 0.65, a 20% straggler mixture) under the M/G/1
//!   model.
//! * **EmpiricalRefit** — paced arrivals on the fig. 6 pool; the
//!   captured samples are re-fitted into an
//!   [`EmpiricalBackend`](crate::compose::backend::EmpiricalBackend)
//!   plan via [`ScenarioSpec::refit_plan`].

use crate::compose::backend::EmpiricalBackend;
use crate::coordinator::{Coordinator, CoordinatorConfig, RunReport, WorkerSpec};
use crate::dist::ServiceDist;
use crate::flow::dag::FlowDag;
use crate::flow::Workflow;
use crate::plan::{Plan, Planner, ProposedPolicy};
use crate::scenario::record::ExecTrace;
use crate::scenario::replay::{drive, Replay};
use crate::sched::multijob::SwapEngine;
use crate::sched::server::Server;
use crate::sched::{ResponseModel, SchedError};
use crate::sim::trace::{ArrivalProcess, Trace};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Workload class of a scenario (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioClass {
    /// Wide service-rate spread, static membership.
    HeterogeneousPool,
    /// Several servers degrade into straggler mixtures together.
    CorrelatedStragglers,
    /// A server joins mid-run and leaves later.
    WorkerChurn,
    /// General stage DAG reduced to series–parallel form.
    DagPipeline,
    /// Table-1 heavy-tail families at their extremes.
    HeavyTailExtreme,
    /// Captured samples re-fitted into an empirical-law plan.
    EmpiricalRefit,
}

impl ScenarioClass {
    /// Stable string label (used in golden summaries and bench rows).
    pub fn label(self) -> &'static str {
        match self {
            ScenarioClass::HeterogeneousPool => "heterogeneous_pool",
            ScenarioClass::CorrelatedStragglers => "correlated_stragglers",
            ScenarioClass::WorkerChurn => "worker_churn",
            ScenarioClass::DagPipeline => "dag_pipeline",
            ScenarioClass::HeavyTailExtreme => "heavy_tail_extreme",
            ScenarioClass::EmpiricalRefit => "empirical_refit",
        }
    }

    /// All classes, in zoo order.
    pub fn all() -> [ScenarioClass; 6] {
        [
            ScenarioClass::HeterogeneousPool,
            ScenarioClass::CorrelatedStragglers,
            ScenarioClass::WorkerChurn,
            ScenarioClass::DagPipeline,
            ScenarioClass::HeavyTailExtreme,
            ScenarioClass::EmpiricalRefit,
        ]
    }
}

/// One scheduled membership change, applied just before dispatching the
/// task with sequence number `at_seq`.
#[derive(Clone, Debug)]
pub struct ChurnAction {
    /// Task sequence number the action fires before.
    pub at_seq: u64,
    /// What happens.
    pub op: ChurnOp,
}

/// A membership operation.
#[derive(Clone, Debug)]
pub enum ChurnOp {
    /// Spawn a worker and extend the believed pool.
    Join {
        /// Worker behavior (scripted during replay).
        spec: WorkerSpec,
        /// The leader's prior belief about the joiner's law.
        prior: Server,
    },
    /// Decommission the last (highest-id) worker.
    Leave,
}

/// A fully deterministic scenario: name + seed determine the workflow,
/// pool, hidden laws, config, arrivals and churn schedule.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Unique scenario name (doubles as the trace header name and the
    /// golden corpus file stem).
    pub name: String,
    /// Workload class.
    pub class: ScenarioClass,
    /// Master seed (coordinator + arrival stream derive from it).
    pub seed: u64,
    /// Nominal run length in tasks (the churn schedule and arrival
    /// composition scale with it; the composed stream may differ by a
    /// few tasks).
    pub n_tasks: usize,
    /// Base arrival process.
    pub arrival: ArrivalProcess,
    /// Swap engine the coordinator's multi-job planner
    /// (`Coordinator::run_multi`) refines with. Capture/replay plan
    /// single jobs, so every engine reproduces the golden corpus
    /// bit-identically; the knob exists so the corpus can assert
    /// exactly that.
    pub swap_engine: SwapEngine,
}

impl ScenarioSpec {
    /// The committed workload zoo: one entry per [`ScenarioClass`].
    pub fn zoo() -> Vec<ScenarioSpec> {
        vec![
            ScenarioSpec {
                name: "heterogeneous_pool".into(),
                class: ScenarioClass::HeterogeneousPool,
                seed: 101,
                n_tasks: 400,
                arrival: ArrivalProcess::Poisson { rate: 2.0 },
                swap_engine: SwapEngine::Wave,
            },
            ScenarioSpec {
                name: "correlated_stragglers".into(),
                class: ScenarioClass::CorrelatedStragglers,
                seed: 211,
                n_tasks: 700,
                arrival: ArrivalProcess::Poisson { rate: 1.5 },
                swap_engine: SwapEngine::Wave,
            },
            ScenarioSpec {
                name: "worker_churn".into(),
                class: ScenarioClass::WorkerChurn,
                seed: 307,
                n_tasks: 600,
                arrival: ArrivalProcess::Poisson { rate: 1.0 },
                swap_engine: SwapEngine::Wave,
            },
            ScenarioSpec {
                name: "dag_pipeline".into(),
                class: ScenarioClass::DagPipeline,
                seed: 401,
                n_tasks: 400,
                arrival: ArrivalProcess::Poisson { rate: 0.8 },
                swap_engine: SwapEngine::Wave,
            },
            ScenarioSpec {
                name: "heavy_tail_extreme".into(),
                class: ScenarioClass::HeavyTailExtreme,
                seed: 503,
                n_tasks: 400,
                arrival: ArrivalProcess::Poisson { rate: 0.4 },
                swap_engine: SwapEngine::Wave,
            },
            ScenarioSpec {
                name: "empirical_refit".into(),
                class: ScenarioClass::EmpiricalRefit,
                seed: 601,
                n_tasks: 400,
                arrival: ArrivalProcess::Paced { interval: 0.5 },
                swap_engine: SwapEngine::Wave,
            },
        ]
    }

    /// Look a zoo scenario up by name.
    pub fn by_name(name: &str) -> Option<ScenarioSpec> {
        Self::zoo().into_iter().find(|s| s.name == name)
    }

    /// The short soak scenario the live re-planning service
    /// ([`crate::serve`]) is goldened against: worker-churn machinery
    /// (the class with the richest event stream — joins, leaves, a
    /// compressed arrival burst, periodic re-opt checks) under its own
    /// name and seed, deliberately **not** part of [`ScenarioSpec::zoo`]
    /// — the zoo stays exactly one entry per class; this spec rides the
    /// same golden machinery via its distinct corpus file stem.
    pub fn serve_soak_short() -> ScenarioSpec {
        ScenarioSpec {
            name: "serve_soak_short".into(),
            class: ScenarioClass::WorkerChurn,
            seed: 0x50AC,
            n_tasks: 240,
            arrival: ArrivalProcess::Poisson { rate: 1.0 },
            swap_engine: SwapEngine::Wave,
        }
    }

    /// Same scenario, different seed (property tests sweep this).
    pub fn with_seed(mut self, seed: u64) -> ScenarioSpec {
        self.seed = seed;
        self
    }

    /// Same scenario, different nominal length.
    pub fn with_tasks(mut self, n_tasks: usize) -> ScenarioSpec {
        self.n_tasks = n_tasks;
        self
    }

    /// Same scenario, different multi-job swap engine (the golden
    /// suite sweeps this to pin engine-invariance of the corpus).
    pub fn with_swap_engine(mut self, engine: SwapEngine) -> ScenarioSpec {
        self.swap_engine = engine;
        self
    }

    /// The scenario's workflow.
    pub fn workflow(&self) -> Workflow {
        match self.class {
            ScenarioClass::HeterogeneousPool | ScenarioClass::EmpiricalRefit => Workflow::fig6(),
            ScenarioClass::CorrelatedStragglers => Workflow::forkjoin(4, 2.0),
            ScenarioClass::WorkerChurn => Workflow::tandem(3, 1.2),
            ScenarioClass::DagPipeline => {
                // two parallel map stages, a diamond (direct edge vs a
                // two-stage detour), a shuffle, two parallel reducers —
                // TTSP-reducible, 8 stage slots
                let dag = FlowDag::new()
                    .stage(0, 1, "map-a")
                    .stage(0, 1, "map-b")
                    .stage(1, 5, "agg-x")
                    .stage(5, 2, "agg-y")
                    .stage(1, 2, "passthrough")
                    .stage(2, 3, "shuffle")
                    .stage(3, 4, "reduce-a")
                    .stage(3, 4, "reduce-b");
                let tree = dag
                    .to_series_parallel(0, 4)
                    .expect("pipeline dag is series-parallel by construction");
                Workflow::new(tree, 1.0).expect("reduced pipeline workflow is valid")
            }
            ScenarioClass::HeavyTailExtreme => Workflow::chain(2, 2, 0.5),
        }
    }

    /// The leader's initial believed pool (also the hidden initial
    /// laws: every scenario starts with truthful priors, divergence
    /// comes from drift/churn afterwards).
    pub fn initial_view(&self) -> Vec<Server> {
        match self.class {
            ScenarioClass::HeterogeneousPool => {
                Server::pool_exponential(&[24.0, 18.0, 12.0, 9.0, 6.0, 4.0, 3.0, 2.0])
            }
            ScenarioClass::CorrelatedStragglers => {
                Server::pool_exponential(&[10.0, 9.0, 8.0, 7.0, 6.0, 5.0])
            }
            ScenarioClass::WorkerChurn => Server::pool_exponential(&[6.0, 5.0, 4.0, 3.0]),
            ScenarioClass::DagPipeline => {
                Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0])
            }
            ScenarioClass::HeavyTailExtreme => vec![
                // Table-1 families at their extremes: Pareto shape 2.4
                // (variance barely finite), sub-exponential Weibull,
                // a 20% straggler mixture
                Server::new(0, ServiceDist::delayed_pareto(2.4, 0.05)),
                Server::new(1, ServiceDist::delayed_pareto(3.5, 0.0)),
                Server::new(2, ServiceDist::delayed_weibull(1.4, 0.65, 0.1)),
                Server::new(3, ServiceDist::delayed_weibull(2.2, 0.8, 0.0)),
                Server::new(4, ServiceDist::straggler(9.0, 0.35, 0.2, 0.05)),
                Server::new(5, ServiceDist::exponential(5.0)),
            ],
            ScenarioClass::EmpiricalRefit => {
                Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0])
            }
        }
    }

    /// Hidden worker laws for the live (capture) run. Stragglers get
    /// correlated drift onsets; everything else is truthful.
    pub fn live_worker_specs(&self) -> Vec<WorkerSpec> {
        let view = self.initial_view();
        match self.class {
            ScenarioClass::CorrelatedStragglers => view
                .iter()
                .map(|s| {
                    if s.id < 3 {
                        // three servers degrade *together* after 250
                        // draws into the same straggler mixture
                        WorkerSpec::drifting(
                            s.id,
                            s.dist.clone(),
                            250,
                            ServiceDist::straggler(8.0, 1.2, 0.25, 0.0),
                        )
                    } else {
                        WorkerSpec::stable(s.id, s.dist.clone())
                    }
                })
                .collect(),
            _ => view
                .iter()
                .map(|s| WorkerSpec::stable(s.id, s.dist.clone()))
                .collect(),
        }
    }

    /// Coordinator configuration for this scenario.
    pub fn config(&self) -> CoordinatorConfig {
        let mut cfg = CoordinatorConfig {
            seed: self.seed,
            reopt_every: 0,
            swap_engine: self.swap_engine,
            ..Default::default()
        };
        match self.class {
            ScenarioClass::HeterogeneousPool | ScenarioClass::DagPipeline => {}
            ScenarioClass::CorrelatedStragglers => {
                cfg.reopt_every = 100;
                cfg.reopt_on_drift_only = true;
                cfg.min_fit_samples = 128;
                cfg.monitor_window = 1024;
            }
            ScenarioClass::WorkerChurn => {
                cfg.reopt_every = 150;
                cfg.reopt_on_drift_only = false;
                cfg.min_fit_samples = 128;
                cfg.monitor_window = 512;
            }
            ScenarioClass::HeavyTailExtreme => {
                cfg.model = ResponseModel::Mg1;
            }
            ScenarioClass::EmpiricalRefit => {
                cfg.reopt_every = 200;
                cfg.reopt_on_drift_only = false;
                cfg.min_fit_samples = 128;
                cfg.monitor_window = 1024;
            }
        }
        cfg
    }

    /// The scheduled membership changes (non-empty only for
    /// [`ScenarioClass::WorkerChurn`]): one joiner a third of the way
    /// in, decommissioned at two thirds. With `scripts` (from a
    /// captured trace) the joiner replays its recorded draws; ids are
    /// never reused, so per-server scripts stay unambiguous.
    pub fn churn_actions(&self, scripts: Option<&[Vec<f64>]>) -> Vec<ChurnAction> {
        if self.class != ScenarioClass::WorkerChurn {
            return Vec::new();
        }
        let join_id = self.initial_view().len();
        let law = ServiceDist::exponential(10.0);
        let spec = match scripts {
            Some(s) => WorkerSpec::scripted(
                join_id,
                law.clone(),
                s.get(join_id).cloned().unwrap_or_default(),
            ),
            None => WorkerSpec::stable(join_id, law.clone()),
        };
        let n = self.n_tasks as u64;
        vec![
            ChurnAction {
                at_seq: n / 3,
                op: ChurnOp::Join {
                    spec,
                    prior: Server::new(join_id, law),
                },
            },
            ChurnAction {
                at_seq: 2 * n / 3,
                op: ChurnOp::Leave,
            },
        ]
    }

    /// The deterministic arrival stream. The churn class composes a
    /// compressed early burst onto the base stream with the
    /// [`Trace::merge`] / [`Trace::scale_time`] / [`Trace::truncate`]
    /// helpers; every other class generates its base process directly.
    pub fn arrival_trace(&self) -> Trace {
        let mut rng = Rng::new(self.seed ^ 0xA55A_5AA5_D00D_F00D);
        match self.class {
            ScenarioClass::WorkerChurn => {
                let base_n = self.n_tasks - self.n_tasks / 4;
                let base = Trace::generate(self.arrival, base_n, &mut rng);
                let horizon = base.arrivals.last().copied().unwrap_or(0.0);
                // a unit-rate stream compressed 4x and clipped to the
                // first half of the run: a correlated arrival burst
                let burst = Trace::generate(
                    ArrivalProcess::Poisson { rate: 1.0 },
                    self.n_tasks / 4,
                    &mut rng,
                )
                .scale_time(0.25)
                .truncate(horizon * 0.5);
                base.merge(&burst)
            }
            _ => Trace::generate(self.arrival, self.n_tasks, &mut rng),
        }
    }

    /// Run the scenario live (hidden laws, real drift/churn) with
    /// recording on; returns the run report and the captured trace.
    pub fn capture(&self) -> Result<(RunReport, ExecTrace), SchedError> {
        let mut coord = Coordinator::new(
            self.live_worker_specs(),
            self.initial_view(),
            self.config(),
        );
        coord.start_recording(&self.name);
        let job = coord.submit(&self.name, self.workflow());
        let arrivals = self.arrival_trace();
        let churn = self.churn_actions(None);
        let report = drive(&mut coord, &job, &arrivals, &churn)?;
        let trace = coord.take_trace().expect("recording was started");
        coord.shutdown();
        Ok((report, trace))
    }

    /// Replay a captured trace through the live stack (scripted
    /// workers); returns the replayed report and the re-captured trace
    /// (equal to the input for a faithful replay).
    pub fn replay(&self, trace: &ExecTrace) -> Result<(RunReport, ExecTrace), String> {
        Replay::new(self, trace)?
            .run_traced()
            .map_err(|e| format!("replay of '{}' failed: {e}", self.name))
    }

    /// Coordinator whose workers answer draws from per-server scripts
    /// (falling back to the scenario's initial laws when exhausted).
    pub(crate) fn scripted_coordinator(&self, scripts: &[Vec<f64>]) -> Coordinator {
        let specs = self
            .live_worker_specs()
            .into_iter()
            .map(|mut s| {
                s.script = Some(Arc::new(
                    scripts.get(s.server_id).cloned().unwrap_or_default(),
                ));
                // scripted draws shadow the drift schedule entirely
                s
            })
            .collect();
        Coordinator::new(specs, self.initial_view(), self.config())
    }

    /// Re-fit captured service samples into empirical laws and plan
    /// against them: every server with ≥ 32 recorded draws scores
    /// through an [`EmpiricalBackend`] law, the rest stay analytic.
    /// This is the capture→refit→replan loop the EmpiricalRefit class
    /// exists to exercise.
    pub fn refit_plan(&self, trace: &ExecTrace) -> Result<Plan, SchedError> {
        let scripts = trace.service_scripts();
        let mut backend = EmpiricalBackend::new();
        for (sid, samples) in scripts.iter().enumerate() {
            if samples.len() >= 32 {
                backend = backend.with_samples(sid, samples);
            }
        }
        let servers = self.initial_view();
        let wf = self.workflow();
        let cfg = self.config();
        Planner::new(&wf, &servers)
            .model(cfg.model)
            .objective(cfg.objective)
            .backend(&backend)
            .plan(&ProposedPolicy::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_covers_every_class_once() {
        let zoo = ScenarioSpec::zoo();
        assert_eq!(zoo.len(), ScenarioClass::all().len());
        for class in ScenarioClass::all() {
            let hits: Vec<_> = zoo.iter().filter(|s| s.class == class).collect();
            assert_eq!(hits.len(), 1, "class {class:?} must appear exactly once");
            assert_eq!(hits[0].name, class.label());
        }
        // names unique ⇒ by_name resolves every entry
        for s in &zoo {
            assert_eq!(ScenarioSpec::by_name(&s.name).unwrap().seed, s.seed);
        }
        assert!(ScenarioSpec::by_name("no_such_scenario").is_none());
    }

    #[test]
    fn every_scenario_is_feasible_on_paper() {
        for s in ScenarioSpec::zoo() {
            let wf = s.workflow();
            let pool = s.initial_view();
            assert!(
                pool.len() >= wf.slots(),
                "{}: pool {} < slots {}",
                s.name,
                pool.len(),
                wf.slots()
            );
            // ids dense, as the coordinator requires
            for (i, srv) in pool.iter().enumerate() {
                assert_eq!(srv.id, i, "{}: ids must be dense", s.name);
            }
        }
    }

    #[test]
    fn arrival_traces_are_deterministic_and_sorted() {
        for s in ScenarioSpec::zoo() {
            let a = s.arrival_trace();
            let b = s.arrival_trace();
            assert_eq!(a.arrivals, b.arrivals, "{}: regeneration must match", s.name);
            assert!(
                a.arrivals.windows(2).all(|w| w[0] <= w[1]),
                "{}: arrivals must be sorted",
                s.name
            );
            assert!(!a.arrivals.is_empty(), "{}: no arrivals", s.name);
        }
    }

    #[test]
    fn churn_schedule_only_for_churn_class() {
        for s in ScenarioSpec::zoo() {
            let actions = s.churn_actions(None);
            if s.class == ScenarioClass::WorkerChurn {
                assert_eq!(actions.len(), 2);
                assert!(actions[0].at_seq < actions[1].at_seq);
                assert!(matches!(actions[0].op, ChurnOp::Join { .. }));
                assert!(matches!(actions[1].op, ChurnOp::Leave));
                // the schedule must fire within the composed stream
                let n = s.arrival_trace().arrivals.len() as u64;
                assert!(actions[1].at_seq < n);
            } else {
                assert!(actions.is_empty(), "{}: unexpected churn", s.name);
            }
        }
    }

    #[test]
    fn dag_pipeline_reduces_to_eight_slots() {
        let s = ScenarioSpec::by_name("dag_pipeline").unwrap();
        assert_eq!(s.workflow().slots(), 8);
    }

    #[test]
    fn stragglers_remain_feasible_after_degradation() {
        // the degraded law must still out-rate the per-slot demand,
        // otherwise mid-run re-planning could become infeasible
        let degraded = ServiceDist::straggler(8.0, 1.2, 0.25, 0.0);
        assert!(degraded.rate() > 2.0, "rate {}", degraded.rate());
    }
}
