//! Durable execution traces: the versioned JSONL capture format.
//!
//! A coordinator run with recording enabled
//! ([`crate::coordinator::Coordinator::start_recording`]) produces an
//! [`ExecTrace`]: the arrival stream, every raw per-task service draw in
//! dispatch order, re-optimization decisions and membership (churn)
//! events. The format is line-oriented JSON (one event per line, header
//! first) so traces diff cleanly, stream through standard tooling, and
//! round-trip **bit-identically**: serialization uses the crate's
//! deterministic [`crate::util::json`] writer, whose float formatting is
//! the shortest representation that parses back to the same `f64`.
//!
//! Format version: [`TRACE_FORMAT_VERSION`]. Readers reject newer
//! versions with a precise error instead of misinterpreting them; field
//! additions within a version are allowed, renames/removals bump it.

use crate::sim::trace::Trace;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Version stamp written into every trace header (`"version"` field).
///
/// Version 1 events: `header`, `arrival`, `service`, `reopt`, `churn`.
pub const TRACE_FORMAT_VERSION: u64 = 1;

/// First line of every trace: identity + provenance of the run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceHeader {
    /// Format version ([`TRACE_FORMAT_VERSION`] when written by this
    /// crate).
    pub version: u64,
    /// Scenario (or free-form run) name the trace was captured from.
    pub scenario: String,
    /// Coordinator RNG seed of the captured run (must fit in 2^53 so it
    /// survives the JSON number round-trip; all zoo seeds do).
    pub seed: u64,
    /// Number of servers at the start of the run (churn events may grow
    /// or shrink the pool afterwards).
    pub servers: usize,
}

/// Membership-change direction of a [`TraceEvent::Churn`] event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// A worker joined the pool.
    Join,
    /// A worker left the pool.
    Leave,
}

/// One recorded event, in global capture order.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A task entered the system.
    Arrival {
        /// Task sequence number within the job.
        seq: u64,
        /// Absolute virtual arrival time.
        at: f64,
    },
    /// One raw service-time draw answered by a worker (unscaled: the
    /// value the worker's hidden law produced, before any
    /// partitioned-data share scaling applied by the dispatcher).
    Service {
        /// Server that produced the draw.
        server: usize,
        /// The raw drawn service time.
        draw: f64,
    },
    /// The allocation was swapped by the re-optimization loop.
    Reopt {
        /// Completed-task count at the swap.
        completed: u64,
        /// Why (`"drift"`, `"periodic"` or `"churn"`).
        reason: String,
    },
    /// A worker joined or left the pool.
    Churn {
        /// Direction of the membership change.
        op: ChurnKind,
        /// Server id that joined / left.
        server: usize,
    },
}

/// A captured execution trace: header plus events in capture order.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecTrace {
    /// Run identity and format version.
    pub header: TraceHeader,
    /// Events in global capture order.
    pub events: Vec<TraceEvent>,
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn field_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing/invalid number field '{key}'"))
}

fn field_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("missing/invalid integer field '{key}'"))
}

fn field_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing/invalid string field '{key}'"))
}

impl ExecTrace {
    /// Serialize to the JSONL wire format (header line, then one event
    /// per line, trailing newline).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let h = obj(vec![
            ("kind", Json::Str("header".into())),
            ("scenario", Json::Str(self.header.scenario.clone())),
            ("seed", Json::Num(self.header.seed as f64)),
            ("servers", Json::Num(self.header.servers as f64)),
            ("version", Json::Num(self.header.version as f64)),
        ]);
        out.push_str(&h.to_string());
        out.push('\n');
        for e in &self.events {
            let line = match e {
                TraceEvent::Arrival { seq, at } => obj(vec![
                    ("at", Json::Num(*at)),
                    ("kind", Json::Str("arrival".into())),
                    ("seq", Json::Num(*seq as f64)),
                ]),
                TraceEvent::Service { server, draw } => obj(vec![
                    ("draw", Json::Num(*draw)),
                    ("kind", Json::Str("service".into())),
                    ("server", Json::Num(*server as f64)),
                ]),
                TraceEvent::Reopt { completed, reason } => obj(vec![
                    ("completed", Json::Num(*completed as f64)),
                    ("kind", Json::Str("reopt".into())),
                    ("reason", Json::Str(reason.clone())),
                ]),
                TraceEvent::Churn { op, server } => obj(vec![
                    ("kind", Json::Str("churn".into())),
                    (
                        "op",
                        Json::Str(
                            match op {
                                ChurnKind::Join => "join",
                                ChurnKind::Leave => "leave",
                            }
                            .into(),
                        ),
                    ),
                    ("server", Json::Num(*server as f64)),
                ]),
            };
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a trace from its JSONL form. Rejects unknown format
    /// versions, unknown event kinds and malformed lines with an error
    /// naming the offending line.
    pub fn from_jsonl(text: &str) -> Result<ExecTrace, String> {
        let mut lines = text
            .lines()
            .enumerate()
            .filter(|(_, l)| !l.trim().is_empty());
        let (hline_no, hline) = lines.next().ok_or("empty trace")?;
        let hv = Json::parse(hline)
            .map_err(|e| format!("trace line {}: {e}", hline_no + 1))?;
        if field_str(&hv, "kind")? != "header" {
            return Err(format!(
                "trace line {}: first line must be the header",
                hline_no + 1
            ));
        }
        let version = field_usize(&hv, "version")? as u64;
        if version != TRACE_FORMAT_VERSION {
            return Err(format!(
                "unsupported trace format version {version} (this build reads \
                 version {TRACE_FORMAT_VERSION})"
            ));
        }
        let header = TraceHeader {
            version,
            scenario: field_str(&hv, "scenario")?.to_string(),
            seed: field_f64(&hv, "seed")? as u64,
            servers: field_usize(&hv, "servers")?,
        };
        let mut events = Vec::new();
        for (no, line) in lines {
            let v = Json::parse(line).map_err(|e| format!("trace line {}: {e}", no + 1))?;
            let kind = field_str(&v, "kind")?.to_string();
            let ev = match kind.as_str() {
                "arrival" => TraceEvent::Arrival {
                    seq: field_f64(&v, "seq")? as u64,
                    at: field_f64(&v, "at")?,
                },
                "service" => TraceEvent::Service {
                    server: field_usize(&v, "server")?,
                    draw: field_f64(&v, "draw")?,
                },
                "reopt" => TraceEvent::Reopt {
                    completed: field_f64(&v, "completed")? as u64,
                    reason: field_str(&v, "reason")?.to_string(),
                },
                "churn" => TraceEvent::Churn {
                    op: match field_str(&v, "op")? {
                        "join" => ChurnKind::Join,
                        "leave" => ChurnKind::Leave,
                        other => {
                            return Err(format!(
                                "trace line {}: unknown churn op '{other}'",
                                no + 1
                            ))
                        }
                    },
                    server: field_usize(&v, "server")?,
                },
                other => {
                    return Err(format!(
                        "trace line {}: unknown event kind '{other}'",
                        no + 1
                    ))
                }
            };
            events.push(ev);
        }
        Ok(ExecTrace { header, events })
    }

    /// Per-server raw service draws, in per-server draw order — exactly
    /// what a scripted replay worker must answer. The returned vector
    /// covers every server id the trace mentions (initial pool plus any
    /// churn joiners); servers that never served are empty.
    pub fn service_scripts(&self) -> Vec<Vec<f64>> {
        let mut n = self.header.servers;
        for e in &self.events {
            match e {
                TraceEvent::Service { server, .. } | TraceEvent::Churn { server, .. } => {
                    n = n.max(server + 1)
                }
                _ => {}
            }
        }
        let mut scripts = vec![Vec::new(); n];
        for e in &self.events {
            if let TraceEvent::Service { server, draw } = e {
                scripts[*server].push(*draw);
            }
        }
        scripts
    }

    /// The captured arrival stream as a [`Trace`] (replay feeds this
    /// back through the dispatch loop instead of regenerating arrivals).
    pub fn arrival_trace(&self) -> Trace {
        Trace {
            arrivals: self
                .events
                .iter()
                .filter_map(|e| match e {
                    TraceEvent::Arrival { at, .. } => Some(*at),
                    _ => None,
                })
                .collect(),
        }
    }

    /// Number of arrival events.
    pub fn arrivals(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Arrival { .. }))
            .count()
    }

    /// Number of service-draw events.
    pub fn services(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Service { .. }))
            .count()
    }

    /// Number of allocation-swap events.
    pub fn reopts(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Reopt { .. }))
            .count()
    }

    /// Number of membership-change events.
    pub fn churns(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Churn { .. }))
            .count()
    }
}

/// In-flight trace capture. The coordinator owns one while recording is
/// on and feeds it from the dispatch loop; [`Recorder::finish`] yields
/// the immutable [`ExecTrace`].
#[derive(Clone, Debug)]
pub struct Recorder {
    trace: ExecTrace,
}

impl Recorder {
    /// Start a capture for `scenario` on a pool of `servers` workers.
    pub fn new(scenario: &str, seed: u64, servers: usize) -> Recorder {
        Recorder {
            trace: ExecTrace {
                header: TraceHeader {
                    version: TRACE_FORMAT_VERSION,
                    scenario: scenario.to_string(),
                    seed,
                    servers,
                },
                events: Vec::new(),
            },
        }
    }

    /// Record a task arrival.
    pub fn arrival(&mut self, seq: u64, at: f64) {
        self.trace.events.push(TraceEvent::Arrival { seq, at });
    }

    /// Record a raw worker service draw.
    pub fn service(&mut self, server: usize, draw: f64) {
        self.trace.events.push(TraceEvent::Service { server, draw });
    }

    /// Record an allocation swap.
    pub fn reopt(&mut self, completed: u64, reason: &str) {
        self.trace.events.push(TraceEvent::Reopt {
            completed,
            reason: reason.to_string(),
        });
    }

    /// Record a membership change.
    pub fn churn(&mut self, op: ChurnKind, server: usize) {
        self.trace.events.push(TraceEvent::Churn { op, server });
    }

    /// Finish the capture.
    pub fn finish(self) -> ExecTrace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> ExecTrace {
        let mut r = Recorder::new("unit", 42, 3);
        r.arrival(0, 0.125);
        r.service(0, 0.1);
        r.service(2, 0.30000000000000004); // a float with no short decimal
        r.reopt(1, "drift");
        r.churn(ChurnKind::Join, 3);
        r.arrival(1, 1.0 / 3.0);
        r.service(3, 1e-9);
        r.churn(ChurnKind::Leave, 3);
        r.finish()
    }

    #[test]
    fn jsonl_roundtrip_is_bit_identical() {
        let t = sample_trace();
        let text = t.to_jsonl();
        let back = ExecTrace::from_jsonl(&text).unwrap();
        assert_eq!(t, back);
        // serialization is a fixed point: re-serializing parses to the
        // same bytes
        assert_eq!(text, back.to_jsonl());
    }

    #[test]
    fn header_is_first_line_and_versioned() {
        let t = sample_trace();
        let text = t.to_jsonl();
        let first = text.lines().next().unwrap();
        assert!(first.contains("\"kind\":\"header\""));
        assert!(first.contains("\"version\":1"));
    }

    #[test]
    fn rejects_future_version() {
        let text = sample_trace()
            .to_jsonl()
            .replacen("\"version\":1", "\"version\":999", 1);
        let err = ExecTrace::from_jsonl(&text).unwrap_err();
        assert!(err.contains("version 999"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ExecTrace::from_jsonl("").is_err());
        assert!(ExecTrace::from_jsonl("{\"kind\":\"arrival\"}").is_err());
        let t = sample_trace().to_jsonl() + "{\"kind\":\"mystery\"}\n";
        assert!(ExecTrace::from_jsonl(&t).is_err());
        let t = sample_trace().to_jsonl() + "not json\n";
        assert!(ExecTrace::from_jsonl(&t).is_err());
    }

    #[test]
    fn scripts_and_arrivals_extracted() {
        let t = sample_trace();
        let scripts = t.service_scripts();
        assert_eq!(scripts.len(), 4); // 3 initial + churn joiner id 3
        assert_eq!(scripts[0], vec![0.1]);
        assert!(scripts[1].is_empty());
        assert_eq!(scripts[3], vec![1e-9]);
        let arr = t.arrival_trace();
        assert_eq!(arr.arrivals.len(), 2);
        assert!(arr.arrivals[0] < arr.arrivals[1]);
        assert_eq!(t.arrivals(), 2);
        assert_eq!(t.services(), 3);
        assert_eq!(t.reopts(), 1);
        assert_eq!(t.churns(), 2);
    }

    #[test]
    fn empty_run_roundtrips() {
        let t = Recorder::new("empty", 7, 0).finish();
        let back = ExecTrace::from_jsonl(&t.to_jsonl()).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.service_scripts().len(), 0);
    }
}
