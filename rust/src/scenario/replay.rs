//! Deterministic replay: feed a captured [`ExecTrace`] back through the
//! live coordinator stack.
//!
//! Replay rebuilds the coordinator with *scripted* workers
//! ([`crate::coordinator::WorkerSpec::scripted`]): worker *s* answers
//! its *k*-th draw request with the *k*-th recorded raw draw for server
//! *s*. Everything else — dispatch order, virtual per-server clocks,
//! monitor windows, KS drift detection, parametric re-fits, planner
//! re-optimization — runs the real code paths. Because the coordinator
//! is a deterministic function of (arrival stream, raw draws, config),
//! replaying a trace reproduces the original run's plans and metrics
//! **bit-identically**, and replaying it twice is likewise
//! bit-identical; `tests/scenario_golden.rs` property-tests this.
//!
//! The driver here also applies scripted membership churn (joins /
//! leaves at recorded task sequence numbers), which `run_job` alone
//! cannot do — capture and replay share this loop so both sides see the
//! same control flow.

use crate::coordinator::{Coordinator, Job, Metrics, RunReport, Task};
use crate::scenario::record::{ExecTrace, TRACE_FORMAT_VERSION};
use crate::scenario::zoo::{ChurnAction, ChurnOp, ScenarioSpec};
use crate::sched::SchedError;
use crate::sim::trace::Trace;

/// Shared capture/replay dispatch loop: run `job` over the `arrivals`
/// stream on `coord`, applying `churn` actions at their recorded task
/// sequence numbers and running Algorithm 3's re-optimization cadence.
/// This mirrors `Coordinator::run_job` exactly (same dispatch, same
/// monitor feed, same swap rule) plus the churn hooks.
///
/// `serve::Service::run` mirrors this loop in turn (admission control
/// layered on the optimization re-plans): under a transparent
/// [`crate::serve::ServeConfig`] a service run records the *same* trace
/// this loop would — which is what lets serve soak traces replay here
/// bit-identically (`tests/scenario_golden.rs`). Changes to this loop
/// must be reflected there.
pub(crate) fn drive(
    coord: &mut Coordinator,
    job: &Job,
    arrivals: &Trace,
    churn: &[ChurnAction],
) -> Result<RunReport, SchedError> {
    let cfg = coord.config();
    let mut alloc = coord.allocate(job)?;
    let mut metrics = Metrics::new(coord.workers_len());
    let mut swaps: Vec<(u64, String)> = Vec::new();
    let mut next_free = vec![0.0f64; coord.workers_len()];
    let mut ci = 0usize;

    for (seq, &arrival) in arrivals.arrivals.iter().enumerate() {
        let mut membership_changed = false;
        while ci < churn.len() && churn[ci].at_seq <= seq as u64 {
            match &churn[ci].op {
                ChurnOp::Join { spec, prior } => {
                    coord.add_worker(spec.clone(), prior.clone());
                    next_free.push(0.0);
                    metrics.ensure_servers(coord.workers_len());
                }
                ChurnOp::Leave => {
                    coord.remove_last_worker();
                    next_free.pop();
                }
            }
            membership_changed = true;
            ci += 1;
        }
        if membership_changed {
            // the old allocation may reference a departed server or
            // ignore a joined one: re-plan against the new pool
            let new_alloc = coord.allocate(job)?;
            if new_alloc != alloc {
                alloc = new_alloc;
                metrics.record_reopt();
                coord.record_reopt(metrics.completed, "churn");
                swaps.push((metrics.completed, "churn".to_string()));
            }
        }

        let task = Task {
            job_id: job.id,
            seq: seq as u64,
            arrival,
        };
        coord.record_arrival(seq as u64, arrival);
        let finish = coord.dispatch(
            job.workflow.root(),
            &alloc,
            arrival,
            1.0,
            &mut next_free,
            &mut metrics,
        );
        metrics.record_completion(finish - task.arrival, finish);

        // Algorithm 3's periodic re-optimization (same rule as run_job)
        if cfg.reopt_every > 0 && metrics.completed % cfg.reopt_every == 0 {
            let drifted = coord.monitors().any_drifted(cfg.min_fit_samples / 2);
            if drifted || !cfg.reopt_on_drift_only {
                coord.refresh_pool_view();
                if let Ok(new_alloc) = coord.allocate(job) {
                    if new_alloc != alloc {
                        alloc = new_alloc;
                        metrics.record_reopt();
                        let reason = if drifted { "drift" } else { "periodic" };
                        coord.record_reopt(metrics.completed, reason);
                        swaps.push((metrics.completed, reason.to_string()));
                    }
                }
            }
        }
    }

    Ok(RunReport {
        metrics,
        final_allocation: alloc,
        swaps,
    })
}

/// Replay driver: a scenario spec plus one of its captured traces.
#[derive(Clone, Copy, Debug)]
pub struct Replay<'a> {
    spec: &'a ScenarioSpec,
    trace: &'a ExecTrace,
}

impl<'a> Replay<'a> {
    /// Bind a trace to its scenario. Fails if the trace's format
    /// version or scenario name does not match.
    pub fn new(spec: &'a ScenarioSpec, trace: &'a ExecTrace) -> Result<Replay<'a>, String> {
        if trace.header.version != TRACE_FORMAT_VERSION {
            return Err(format!(
                "trace format version {} != supported {}",
                trace.header.version, TRACE_FORMAT_VERSION
            ));
        }
        if trace.header.scenario != spec.name {
            return Err(format!(
                "trace was captured from scenario '{}', not '{}'",
                trace.header.scenario, spec.name
            ));
        }
        Ok(Replay { spec, trace })
    }

    /// Replay the trace through the live coordinator stack.
    pub fn run(&self) -> Result<RunReport, SchedError> {
        self.run_traced().map(|(report, _)| report)
    }

    /// Replay while re-capturing: returns the run report *and* the
    /// trace the replayed run itself recorded. For a faithful replay
    /// the re-captured trace equals the input trace event-for-event —
    /// the closed-loop check the golden tests enforce.
    pub fn run_traced(&self) -> Result<(RunReport, ExecTrace), SchedError> {
        let scripts = self.trace.service_scripts();
        let mut coord = self.spec.scripted_coordinator(&scripts);
        coord.start_recording(&self.spec.name);
        let job = coord.submit(&self.spec.name, self.spec.workflow());
        let arrivals = self.trace.arrival_trace();
        let churn = self.spec.churn_actions(Some(&scripts));
        let report = drive(&mut coord, &job, &arrivals, &churn)?;
        let trace = coord.take_trace().expect("recording was started");
        coord.shutdown();
        Ok((report, trace))
    }
}
