//! The unified planning surface: one builder, pluggable policies,
//! pluggable scoring backends.
//!
//! The paper contributes a *family* of allocation/rate-scheduling
//! algorithms (Alg. 1–3) evaluated against a heuristic baseline and an
//! exhaustive optimum. [`Planner`] is the single entry point for all of
//! them: configure the request once (workflow, pool, queueing model,
//! objective, optional grid, optional [`ScoreBackend`]), then evaluate
//! any [`AllocationPolicy`] — the paper's schemes or your own.
//!
//! ```
//! use dcflow::prelude::*;
//!
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//! let wf = Workflow::fig6();
//!
//! let planner = Planner::new(&wf, &servers)
//!     .model(ResponseModel::Mm1)
//!     .objective(Objective::Mean);
//!
//! // One policy:
//! let plan = planner.plan(&ProposedPolicy::default()).expect("feasible");
//! println!("{}: mean={:.4} p99={:.4}", plan.policy_name, plan.score.mean, plan.score.p99);
//!
//! // The Table-2 bake-off, every candidate scored on one common grid:
//! for plan in planner
//!     .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
//!     .into_iter()
//!     .flatten()
//! {
//!     println!("{:<12} mean={:.4}", plan.policy_name, plan.score.mean);
//! }
//! ```
//!
//! Scoring flows through one seam: every policy search, [`Planner::plan`],
//! [`Planner::compare`], [`Planner::score`] and [`Planner::plan_jobs`]
//! evaluate against the planner's [`ScoreBackend`] —
//! [`AnalyticBackend`](crate::compose::backend::AnalyticBackend) by
//! default, the PJRT [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend)
//! or a measurement-driven
//! [`EmpiricalBackend`](crate::compose::backend::EmpiricalBackend) by
//! injection ([`Planner::backend`]), or any custom implementation. Wrap
//! any of them in a
//! [`ShardedBackend`](crate::compose::backend::ShardedBackend) to fan
//! candidate waves across worker threads — or in an
//! [`AsyncScoreBackend`](crate::compose::backend::AsyncScoreBackend) to
//! pipeline chunks through the scoring fabric with a bounded in-flight
//! depth — with bit-identical results either way:
//!
//! ```
//! use dcflow::prelude::*;
//!
//! let wf = Workflow::fig6();
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//! let sharded = ShardedBackend::new(&AnalyticBackend, 4);
//! let plan = Planner::new(&wf, &servers)
//!     .backend(&sharded)
//!     .plan(&ProposedPolicy::default())
//!     .expect("feasible");
//! assert!(plan.score.is_stable());
//! ```
//!
//! Multi-job planning ([`Planner::plan_jobs`]) adds the wave-batched
//! cross-job swap refinement of [`crate::sched::multijob`]; its knobs
//! ([`Planner::swap_rounds`], [`Planner::max_wave`],
//! [`Planner::swap_engine`]) ride the same builder.
//!
//! The original legacy free functions (`sdcc_allocate`,
//! `baseline_allocate`, `proposed_allocate`, `optimal_allocate`) were
//! removed in 0.4.0 after two releases as deprecated shims —
//! `docs/MIGRATION.md` maps each onto its replacement above.

pub mod policy;

pub use crate::compose::backend::{
    AnalyticBackend, AsyncScoreBackend, ChunkPolicy, Dispatch, EmpiricalBackend, ScoreBackend,
    ShardedBackend,
};
pub use crate::compose::fabric::{FabricStats, ScoringPool};
pub use crate::runtime::scorer::RuntimeBackend;
pub use crate::sched::multijob::{MultiJobConfig, RoundStats, SwapEngine, SwapStats};
pub use policy::{
    AllocationPolicy, BaselinePolicy, OptimalPolicy, PlanContext, ProposedPolicy, SdccPolicy,
};

use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::multijob::{multijob_allocate_cfg, multijob_allocate_report, JobPlan};
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};
use std::fmt;

/// The default backend a planner scores through when none is injected.
static DEFAULT_BACKEND: AnalyticBackend = AnalyticBackend;

/// Where a [`Plan`]'s numbers came from: the evaluation configuration
/// the planner actually used (useful for reproducing a score and for
/// scoring other allocations on the same grid).
#[derive(Clone, Debug)]
pub struct Diagnostics {
    /// Queueing model used for response laws.
    pub model: ResponseModel,
    /// Objective the policy optimized.
    pub objective: Objective,
    /// Grid the score was computed on.
    pub grid: GridSpec,
    /// Name of the [`ScoreBackend`] that produced the score.
    pub backend: String,
    /// True when every queue in the allocation was stable.
    pub stable: bool,
}

/// The outcome of planning one workflow under one policy.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The rate-scheduled server assignment.
    pub allocation: Allocation,
    /// Score of the allocation under the planner's backend.
    pub score: Score,
    /// Which policy produced it (from [`AllocationPolicy::name`]).
    pub policy_name: String,
    /// Evaluation configuration used.
    pub diagnostics: Diagnostics,
}

impl Plan {
    /// The score component the configured objective minimizes (smaller
    /// is better).
    pub fn objective_key(&self) -> f64 {
        self.diagnostics.objective.key(&self.score)
    }
}

/// Builder-style planner over one workflow and one server pool.
///
/// Defaults: [`ResponseModel::Mm1`], [`Objective::Mean`], the
/// [`AnalyticBackend`] scorer, and one auto-sized *evaluation grid* per
/// invocation — response-aware, derived from the Alg. 1/2 seed
/// allocation (falling back to the pool-wide service-law grid when no
/// seed exists). The seed and the grid are computed **lazily**, at most
/// once per invocation: a non-scoring policy on the
/// [`Planner::allocate`] path never pays the seed pass, and the seed a
/// refining policy starts from is the same one the grid was sized from.
/// Policies search and plans are scored on that same grid through the
/// same backend, so a policy that optimizes on the grid is judged on
/// the grid it optimized. See the [module docs](self) for a
/// walkthrough.
#[derive(Clone, Copy)]
pub struct Planner<'a> {
    wf: &'a Workflow,
    servers: &'a [Server],
    model: ResponseModel,
    objective: Objective,
    grid: Option<GridSpec>,
    backend: Option<&'a dyn ScoreBackend>,
    multijob: MultiJobConfig,
    recorder: Option<crate::obs::Recorder>,
}

impl fmt::Debug for Planner<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Planner")
            .field("wf", &self.wf)
            .field("servers", &self.servers.len())
            .field("model", &self.model)
            .field("objective", &self.objective)
            .field("grid", &self.grid)
            .field("backend", &self.backend_ref().name())
            .field("multijob", &self.multijob)
            .field("recorder", &self.recorder)
            .finish()
    }
}

impl<'a> Planner<'a> {
    /// Plan `wf` over `servers` with default model/objective/grid.
    pub fn new(wf: &'a Workflow, servers: &'a [Server]) -> Planner<'a> {
        Planner {
            wf,
            servers,
            model: ResponseModel::Mm1,
            objective: Objective::Mean,
            grid: None,
            backend: None,
            multijob: MultiJobConfig::default(),
            recorder: None,
        }
    }

    /// Select the queueing model (default [`ResponseModel::Mm1`]).
    #[must_use]
    pub fn model(mut self, model: ResponseModel) -> Planner<'a> {
        self.model = model;
        self
    }

    /// Select the objective (default [`Objective::Mean`]).
    #[must_use]
    pub fn objective(mut self, objective: Objective) -> Planner<'a> {
        self.objective = objective;
        self
    }

    /// Pin the evaluation grid (default: auto-sized, see
    /// [`Planner`] docs).
    #[must_use]
    pub fn grid(mut self, grid: GridSpec) -> Planner<'a> {
        self.grid = Some(grid);
        self
    }

    /// Inject the scoring backend every evaluation flows through
    /// (default [`AnalyticBackend`]). The planner borrows the backend,
    /// so one backend instance — and whatever device state it caches —
    /// can serve many planners.
    ///
    /// ```
    /// use dcflow::prelude::*;
    ///
    /// let wf = Workflow::fig6();
    /// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    /// let backend = RuntimeBackend::native();
    /// let plan = Planner::new(&wf, &servers)
    ///     .backend(&backend)
    ///     .plan(&SdccPolicy)
    ///     .expect("feasible");
    /// assert_eq!(plan.diagnostics.backend, "runtime-native");
    /// ```
    #[must_use]
    pub fn backend(mut self, backend: &'a dyn ScoreBackend) -> Planner<'a> {
        self.backend = Some(backend);
        self
    }

    /// Maximum cross-job swap-refinement rounds [`Planner::plan_jobs`]
    /// runs (default 4; refinement stops earlier once a round applies
    /// no improving swap). Single-workflow planning is unaffected —
    /// [`ProposedPolicy`] carries its own per-job `rounds` knob.
    #[must_use]
    pub fn swap_rounds(mut self, rounds: usize) -> Planner<'a> {
        self.multijob.swap_rounds = rounds;
        self
    }

    /// Cap on the number of swap candidates [`Planner::plan_jobs`]
    /// scores per [`ScoreBackend::score_batch`] wave (default 4096;
    /// values `< 1` behave as 1). Chunking bounds the size of each
    /// scored batch and never changes the resulting plans.
    #[must_use]
    pub fn max_wave(mut self, max_wave: usize) -> Planner<'a> {
        self.multijob.max_wave = max_wave;
        self
    }

    /// Select how [`Planner::plan_jobs`] scores its cross-job swap
    /// candidates: the wave-batched engine (default), the serial
    /// reference pass, or the memoized incremental engine
    /// ([`SwapEngine::Incremental`], which skips re-scoring pairs
    /// untouched since the previous round). All three produce
    /// bit-identical plans for the built-in backends; see
    /// [`SwapEngine`].
    #[must_use]
    pub fn swap_engine(mut self, engine: SwapEngine) -> Planner<'a> {
        self.multijob.engine = engine;
        self
    }

    /// Attach a telemetry [`Recorder`](crate::obs::Recorder): every
    /// planning entry point ([`Planner::plan`], [`Planner::compare`],
    /// [`Planner::score`], [`Planner::allocate`],
    /// [`Planner::plan_jobs`], [`Planner::plan_jobs_report`]) then
    /// captures spans for the duration of that call, restoring the
    /// previous capture mode afterwards — trace one planner without
    /// flipping `DCFLOW_TRACE` for the whole process. Capture never
    /// changes the plans: instrumentation only observes.
    ///
    /// ```
    /// use dcflow::prelude::*;
    ///
    /// let wf = Workflow::fig6();
    /// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    /// let plan = Planner::new(&wf, &servers)
    ///     .recorder(Recorder::global())
    ///     .plan(&SdccPolicy)
    ///     .expect("feasible");
    /// assert!(plan.score.mean > 0.0);
    /// let events = Recorder::global().drain();
    /// assert!(dcflow::obs::validate(&events).is_ok());
    /// ```
    #[must_use]
    pub fn recorder(mut self, recorder: crate::obs::Recorder) -> Planner<'a> {
        self.recorder = Some(recorder);
        self
    }

    /// Capture guard for one planning call (`None` when no recorder is
    /// attached — the global `DCFLOW_TRACE` gate still applies).
    fn activate(&self) -> Option<crate::obs::ActiveRecorder> {
        self.recorder.map(crate::obs::Recorder::activate)
    }

    fn backend_ref(&self) -> &'a dyn ScoreBackend {
        self.backend.unwrap_or(&DEFAULT_BACKEND)
    }

    /// The context handed to policies at allocation time. Seed and grid
    /// materialize lazily inside it (see [`PlanContext`]).
    fn ctx(&self) -> PlanContext<'a> {
        PlanContext::new(
            self.wf,
            self.servers,
            self.model,
            self.objective,
            self.backend_ref(),
            self.grid,
        )
    }

    /// Run a policy and return the raw allocation without the final
    /// scoring — the cheap path for callers (like the coordinator's
    /// dispatch loop) that only need the assignment. Non-scoring
    /// policies skip grid sizing entirely on this path:
    /// [`BaselinePolicy`] pays no Alg. 1/2 seed pass at all, and for
    /// [`SdccPolicy`] the only seed pass is the allocation itself
    /// (cached in the context, never recomputed). Scoring policies
    /// materialize the grid lazily when they first consult it.
    pub fn allocate(&self, policy: &dyn AllocationPolicy) -> Result<Allocation, SchedError> {
        let _capture = self.activate();
        let _span = crate::obs::span("plan.allocate");
        policy.allocate(&self.ctx())
    }

    /// Run a policy and score its allocation through the planner's
    /// backend, on this invocation's evaluation grid (the same grid the
    /// policy saw in its [`PlanContext`]).
    pub fn plan(&self, policy: &dyn AllocationPolicy) -> Result<Plan, SchedError> {
        let _capture = self.activate();
        let mut span = crate::obs::span("plan");
        if span.is_recording() {
            span.attr("policy", policy.name());
        }
        let ctx = self.ctx();
        let allocation = policy.allocate(&ctx)?;
        Ok(self.finish(policy.name(), allocation, &ctx))
    }

    /// Evaluate several policies on one *common* grid (the Fig. 7 /
    /// Table 2 bake-off) — the same evaluation grid each policy
    /// searched on. Results align with the input order; a policy that
    /// cannot allocate yields its error instead of poisoning the whole
    /// comparison.
    pub fn compare(
        &self,
        policies: &[&dyn AllocationPolicy],
    ) -> Vec<Result<Plan, SchedError>> {
        let _capture = self.activate();
        let mut span = crate::obs::span("plan.compare");
        if span.is_recording() {
            span.attr("policies", policies.len());
        }
        let ctx = self.ctx();
        policies
            .iter()
            .map(|p| {
                p.allocate(&ctx)
                    .map(|alloc| self.finish(p.name(), alloc, &ctx))
            })
            .collect()
    }

    /// Score an arbitrary allocation through the planner's backend —
    /// the builder-surface replacement for deep-importing the raw
    /// scoring free function. On a pinned [`Planner::grid`] it scores
    /// on that grid; with no pinned grid the evaluation grid is sized
    /// from the *scored allocation's own* response laws (the pairing
    /// the legacy `auto_response` + raw-score call sites used), so an
    /// allocation with much longer tails than the Alg. 1/2 seed is not
    /// silently truncated.
    ///
    /// ```
    /// use dcflow::prelude::*;
    ///
    /// let wf = Workflow::fig6();
    /// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    /// let planner = Planner::new(&wf, &servers);
    /// let plan = planner.plan(&SdccPolicy).expect("feasible");
    /// // re-scoring the planned allocation on the plan's grid is exact
    /// let s = planner.grid(plan.diagnostics.grid).score(&plan.allocation);
    /// assert_eq!(s.mean, plan.score.mean);
    /// ```
    pub fn score(&self, alloc: &Allocation) -> Score {
        let _capture = self.activate();
        let _span = crate::obs::span("plan.score");
        if self.grid.is_some() {
            return self.ctx().score(alloc);
        }
        let backend = self.backend_ref();
        let pool = backend.resolve_scoring_pool(self.servers);
        let grid = GridSpec::auto_response(alloc, &pool, self.model);
        backend.score(self.wf, alloc, self.servers, &grid, self.model)
    }

    /// Partition the pool across several concurrent workflows and plan
    /// each (wraps [`multijob_allocate_cfg`] with this planner's
    /// model, objective, backend and swap knobs —
    /// [`Planner::swap_rounds`], [`Planner::max_wave`],
    /// [`Planner::swap_engine`]). All jobs are evaluated on **one
    /// shared grid**: the pinned [`Planner::grid`] when set, else a
    /// grid auto-sized once to cover every job's seed-response horizon.
    /// Only the pool, model, objective, grid, backend and swap knobs
    /// carry over: the builder's own workflow is not implicitly part of
    /// the job set.
    pub fn plan_jobs(&self, jobs: &[&Workflow]) -> Result<Vec<JobPlan>, SchedError> {
        let _capture = self.activate();
        let mut span = crate::obs::span("plan_jobs");
        if span.is_recording() {
            span.attr("jobs", jobs.len());
        }
        multijob_allocate_cfg(
            jobs,
            self.servers,
            self.model,
            self.objective,
            self.backend_ref(),
            self.grid,
            &self.multijob,
        )
    }

    /// [`Planner::plan_jobs`] plus swap-phase telemetry: the plans are
    /// identical, and the returned [`SwapStats`] carries the per-round
    /// candidate/scored/memo-hit counters (all memo fields zero under
    /// the non-incremental engines). Use this to observe how much work
    /// [`SwapEngine::Incremental`] skipped.
    pub fn plan_jobs_report(
        &self,
        jobs: &[&Workflow],
    ) -> Result<(Vec<JobPlan>, SwapStats), SchedError> {
        let _capture = self.activate();
        let mut span = crate::obs::span("plan_jobs");
        if span.is_recording() {
            span.attr("jobs", jobs.len());
        }
        multijob_allocate_report(
            jobs,
            self.servers,
            self.model,
            self.objective,
            self.backend_ref(),
            self.grid,
            &self.multijob,
        )
    }

    fn finish(&self, policy_name: String, allocation: Allocation, ctx: &PlanContext<'a>) -> Plan {
        let score = ctx.score(&allocation);
        let stable = score.is_stable();
        Plan {
            allocation,
            score,
            policy_name,
            diagnostics: Diagnostics {
                model: self.model,
                objective: self.objective,
                grid: ctx.grid(),
                backend: ctx.backend().name().to_string(),
                stable,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::score::score_allocation_with;
    use crate::sched::response::{mean_response, ResponseModel};
    use crate::sched::schedule_rates;

    #[test]
    fn swap_knobs_flow_through_plan_jobs() {
        // serial reference engine == default wave engine, and zero swap
        // rounds means the greedy+refine plans come back untouched by
        // the cross-job phase (still valid and disjoint)
        let heavy = Workflow::fig6();
        let light = Workflow::tandem(3, 1.0);
        let pool =
            Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let jobs = [&heavy, &light];
        let wave = Planner::new(&heavy, &pool).plan_jobs(&jobs).unwrap();
        let serial = Planner::new(&heavy, &pool)
            .swap_engine(SwapEngine::Serial)
            .plan_jobs(&jobs)
            .unwrap();
        for (w, s) in wave.iter().zip(serial.iter()) {
            assert_eq!(w.alloc, s.alloc);
            assert_eq!(w.score.mean, s.score.mean);
        }
        let tiny_waves = Planner::new(&heavy, &pool)
            .max_wave(3)
            .plan_jobs(&jobs)
            .unwrap();
        for (w, t) in wave.iter().zip(tiny_waves.iter()) {
            assert_eq!(w.alloc, t.alloc);
        }
        let no_swaps = Planner::new(&heavy, &pool)
            .swap_rounds(0)
            .plan_jobs(&jobs)
            .unwrap();
        assert_eq!(no_swaps.len(), 2);
        for p in &no_swaps {
            assert!(p.score.is_stable());
        }
    }

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn plan_scores_each_builtin_policy() {
        let (wf, servers) = fig6();
        let planner = Planner::new(&wf, &servers);
        for policy in [
            &SdccPolicy as &dyn AllocationPolicy,
            &BaselinePolicy::default(),
            &ProposedPolicy::default(),
            &OptimalPolicy,
        ] {
            let plan = planner.plan(policy).expect("fig6 is feasible");
            assert!(plan.diagnostics.stable, "{} unstable", plan.policy_name);
            assert_eq!(plan.diagnostics.backend, "analytic");
            assert!(plan.score.mean > 0.0 && plan.score.p99 > plan.score.mean);
            plan.allocation.validate(&wf, servers.len()).unwrap();
        }
    }

    #[test]
    fn compare_reproduces_table2_ordering() {
        // the paper's Fig. 7 / Table 2 claim: optimal <= proposed <= baseline
        let (wf, servers) = fig6();
        let plans: Vec<Plan> = Planner::new(&wf, &servers)
            .objective(Objective::Mean)
            .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
            .into_iter()
            .collect::<Result<_, _>>()
            .expect("all feasible on fig6");
        let (ours, base, opt) = (&plans[0], &plans[1], &plans[2]);
        assert_eq!(ours.policy_name, "proposed");
        assert_eq!(base.policy_name, "baseline");
        assert_eq!(opt.policy_name, "optimal");
        // common grid across the whole comparison
        assert_eq!(ours.diagnostics.grid, base.diagnostics.grid);
        assert_eq!(ours.diagnostics.grid, opt.diagnostics.grid);
        assert!(opt.score.mean <= ours.score.mean + 1e-6);
        assert!(ours.score.mean <= base.score.mean + 1e-9);
    }

    #[test]
    fn pinned_grid_is_respected() {
        let (wf, servers) = fig6();
        let grid = GridSpec::new(0.02, 2048);
        let plan = Planner::new(&wf, &servers)
            .grid(grid)
            .plan(&SdccPolicy)
            .unwrap();
        assert_eq!(plan.diagnostics.grid, grid);
    }

    #[test]
    fn score_matches_plan_bit_for_bit() {
        let (wf, servers) = fig6();
        let planner = Planner::new(&wf, &servers);
        let plan = planner.plan(&ProposedPolicy::default()).unwrap();
        let rescored = planner.grid(plan.diagnostics.grid).score(&plan.allocation);
        assert_eq!(rescored.mean, plan.score.mean);
        assert_eq!(rescored.var, plan.score.var);
        assert_eq!(rescored.p99, plan.score.p99);
        // and Planner::score is score_allocation_with on the same inputs
        let direct = score_allocation_with(
            &wf,
            &plan.allocation,
            &servers,
            &plan.diagnostics.grid,
            ResponseModel::Mm1,
        );
        assert_eq!(rescored.mean, direct.mean);
        assert_eq!(rescored.var, direct.var);
        assert_eq!(rescored.p99, direct.p99);
    }

    #[test]
    fn sharded_backend_flows_through_every_planner_path() {
        // plan / compare / score / plan_jobs through a sharded analytic
        // backend are bit-identical to the serial default
        let (wf, servers) = fig6();
        let sharded = ShardedBackend::new(&AnalyticBackend, 4);
        let serial_planner = Planner::new(&wf, &servers);
        let sharded_planner = Planner::new(&wf, &servers).backend(&sharded);

        let a = serial_planner.plan(&ProposedPolicy::default()).unwrap();
        let b = sharded_planner.plan(&ProposedPolicy::default()).unwrap();
        assert_eq!(b.diagnostics.backend, "sharded(analytic)x4");
        assert_eq!(a.allocation, b.allocation);
        assert_eq!(a.score.mean, b.score.mean);
        assert_eq!(a.score.p99, b.score.p99);
        assert_eq!(a.diagnostics.grid, b.diagnostics.grid);

        let rescored = sharded_planner.grid(a.diagnostics.grid).score(&a.allocation);
        assert_eq!(rescored.mean, a.score.mean);

        let light = Workflow::tandem(3, 1.0);
        let pool =
            Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let serial_jobs = Planner::new(&wf, &pool).plan_jobs(&[&wf, &light]).unwrap();
        let sharded_jobs = Planner::new(&wf, &pool)
            .backend(&sharded)
            .plan_jobs(&[&wf, &light])
            .unwrap();
        for (s, p) in serial_jobs.iter().zip(sharded_jobs.iter()) {
            assert_eq!(s.alloc, p.alloc);
            assert_eq!(s.score.mean, p.score.mean);
            assert_eq!(s.grid, p.grid);
        }
    }

    #[test]
    fn injected_backend_flows_through() {
        let (wf, servers) = fig6();
        let backend = RuntimeBackend::native();
        let plan = Planner::new(&wf, &servers)
            .backend(&backend)
            .plan(&ProposedPolicy::default())
            .unwrap();
        assert_eq!(plan.diagnostics.backend, "runtime-native");
        // the native runtime backend runs the same composition math
        let reference = Planner::new(&wf, &servers)
            .plan(&ProposedPolicy::default())
            .unwrap();
        assert_eq!(plan.allocation, reference.allocation);
        assert_eq!(plan.score.mean, reference.score.mean);
        assert_eq!(plan.score.p99, reference.score.p99);
    }

    #[test]
    fn objective_flows_through() {
        let (wf, servers) = fig6();
        let by_mean = Planner::new(&wf, &servers)
            .objective(Objective::Mean)
            .plan(&ProposedPolicy::default())
            .unwrap();
        let by_var = Planner::new(&wf, &servers)
            .objective(Objective::Variance)
            .plan(&ProposedPolicy::default())
            .unwrap();
        assert!(by_var.score.var <= by_mean.score.var + 1e-9);
        assert!(by_mean.objective_key() == by_mean.score.mean);
        assert!(by_var.objective_key() == by_var.score.var);
    }

    #[test]
    fn infeasible_policies_do_not_poison_compare() {
        // 2-slot tandem at a load only good placements survive: the
        // whole comparison still returns per-policy results
        let wf = Workflow::tandem(2, 20.0);
        let servers = Server::pool_exponential(&[3.0, 4.0]);
        let results = Planner::new(&wf, &servers)
            .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default()]);
        assert_eq!(results.len(), 2);
        for r in results {
            assert!(r.is_err(), "overload must be infeasible");
        }
    }

    #[test]
    fn plan_jobs_partitions_the_pool() {
        let heavy = Workflow::fig6();
        let light = Workflow::tandem(3, 1.0);
        let pool =
            Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let plans = Planner::new(&heavy, &pool)
            .plan_jobs(&[&heavy, &light])
            .unwrap();
        assert_eq!(plans.len(), 2);
        // every job evaluated on the one shared grid
        assert_eq!(plans[0].grid, plans[1].grid);
        let mut used: Vec<usize> = plans
            .iter()
            .flat_map(|p| p.alloc.slot_server.clone())
            .collect();
        used.sort_unstable();
        let before = used.len();
        used.dedup();
        assert_eq!(before, used.len(), "jobs must not share servers");
    }

    #[test]
    fn plan_jobs_respects_pinned_grid() {
        let heavy = Workflow::fig6();
        let light = Workflow::tandem(3, 1.0);
        let pool =
            Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let pinned = GridSpec::new(0.015, 2048);
        let plans = Planner::new(&heavy, &pool)
            .grid(pinned)
            .plan_jobs(&[&heavy, &light])
            .unwrap();
        for p in &plans {
            assert_eq!(p.grid, pinned);
        }
    }

    #[test]
    fn user_policies_plug_in() {
        // a custom policy: identity placement + equilibrium rates
        struct IdentityPolicy;
        impl AllocationPolicy for IdentityPolicy {
            fn name(&self) -> String {
                "identity".into()
            }
            fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
                schedule_rates(
                    ctx.wf,
                    (0..ctx.wf.slots()).collect(),
                    ctx.servers,
                    ctx.model,
                )
            }
        }
        let (wf, servers) = fig6();
        let plan = Planner::new(&wf, &servers).plan(&IdentityPolicy).unwrap();
        assert_eq!(plan.policy_name, "identity");
        assert_eq!(plan.allocation.slot_server, vec![0, 1, 2, 3, 4, 5]);
        assert!(plan.diagnostics.stable);
        // and the context exposes a usable model for custom logic
        assert!(mean_response(ResponseModel::Mm1, &servers[0].dist, 1.0).is_some());
    }
}
