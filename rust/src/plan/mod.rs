//! The unified planning surface: one builder, pluggable policies.
//!
//! The paper contributes a *family* of allocation/rate-scheduling
//! algorithms (Alg. 1–3) evaluated against a heuristic baseline and an
//! exhaustive optimum. [`Planner`] is the single entry point for all of
//! them: configure the request once (workflow, pool, queueing model,
//! objective, optional grid), then evaluate any [`AllocationPolicy`] —
//! the paper's schemes or your own.
//!
//! ```no_run
//! use dcflow::prelude::*;
//!
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//! let wf = Workflow::fig6();
//!
//! let planner = Planner::new(&wf, &servers)
//!     .model(ResponseModel::Mm1)
//!     .objective(Objective::Mean);
//!
//! // One policy:
//! let plan = planner.plan(&ProposedPolicy::default()).expect("feasible");
//! println!("{}: mean={:.4} p99={:.4}", plan.policy_name, plan.score.mean, plan.score.p99);
//!
//! // The Table-2 bake-off, every candidate scored on one common grid:
//! for plan in planner
//!     .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
//!     .into_iter()
//!     .flatten()
//! {
//!     println!("{:<12} mean={:.4}", plan.policy_name, plan.score.mean);
//! }
//! ```
//!
//! The legacy free functions (`sdcc_allocate`, `baseline_allocate`,
//! `proposed_allocate`, `optimal_allocate`) survive as deprecated shims
//! over this module — see [`crate::sched::compat`].

pub mod policy;

pub use policy::{
    AllocationPolicy, BaselinePolicy, OptimalPolicy, PlanContext, ProposedPolicy, SdccPolicy,
};

use crate::compose::grid::GridSpec;
use crate::compose::score::{score_allocation_with, Score};
use crate::flow::Workflow;
use crate::sched::algorithms::allocate_with;
use crate::sched::multijob::{multijob_allocate, JobPlan};
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// Where a [`Plan`]'s numbers came from: the evaluation configuration
/// the planner actually used (useful for reproducing a score and for
/// scoring other allocations on the same grid).
#[derive(Clone, Copy, Debug)]
pub struct Diagnostics {
    /// Queueing model used for response laws.
    pub model: ResponseModel,
    /// Objective the policy optimized.
    pub objective: Objective,
    /// Grid the score was computed on.
    pub grid: GridSpec,
    /// True when every queue in the allocation was stable.
    pub stable: bool,
}

/// The outcome of planning one workflow under one policy.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The rate-scheduled server assignment.
    pub allocation: Allocation,
    /// Exact analytic score of the allocation.
    pub score: Score,
    /// Which policy produced it (from [`AllocationPolicy::name`]).
    pub policy_name: String,
    /// Evaluation configuration used.
    pub diagnostics: Diagnostics,
}

impl Plan {
    /// The score component the configured objective minimizes (smaller
    /// is better).
    pub fn objective_key(&self) -> f64 {
        self.diagnostics.objective.key(&self.score)
    }
}

/// Builder-style planner over one workflow and one server pool.
///
/// Defaults: [`ResponseModel::Mm1`], [`Objective::Mean`], and one
/// auto-sized *evaluation grid* per invocation — response-aware,
/// derived from the Alg. 1/2 seed allocation (falling back to the
/// pool-wide service-law grid when no seed exists). Policies search
/// and plans are scored on that same grid, so a policy that optimizes
/// on the grid is judged on the grid it optimized. See the
/// [module docs](self) for a walkthrough.
#[derive(Clone, Copy, Debug)]
pub struct Planner<'a> {
    wf: &'a Workflow,
    servers: &'a [Server],
    model: ResponseModel,
    objective: Objective,
    grid: Option<GridSpec>,
}

impl<'a> Planner<'a> {
    /// Plan `wf` over `servers` with default model/objective/grid.
    pub fn new(wf: &'a Workflow, servers: &'a [Server]) -> Planner<'a> {
        Planner {
            wf,
            servers,
            model: ResponseModel::Mm1,
            objective: Objective::Mean,
            grid: None,
        }
    }

    /// Select the queueing model (default [`ResponseModel::Mm1`]).
    #[must_use]
    pub fn model(mut self, model: ResponseModel) -> Planner<'a> {
        self.model = model;
        self
    }

    /// Select the objective (default [`Objective::Mean`]).
    #[must_use]
    pub fn objective(mut self, objective: Objective) -> Planner<'a> {
        self.objective = objective;
        self
    }

    /// Pin the evaluation grid (default: auto-sized, see
    /// [`Planner`] docs).
    #[must_use]
    pub fn grid(mut self, grid: GridSpec) -> Planner<'a> {
        self.grid = Some(grid);
        self
    }

    /// The single evaluation grid for this invocation: the pinned one,
    /// else a response-aware grid sized from the Alg. 1/2 seed
    /// allocation (the legacy call sites sized their optimal-search
    /// grids from an allocation's response laws the same way), else
    /// the pool-wide service-law grid when no seed is feasible.
    fn eval_grid(&self) -> GridSpec {
        if let Some(grid) = self.grid {
            return grid;
        }
        match allocate_with(self.wf, self.servers, self.model) {
            Ok(seed) => GridSpec::auto_response(&seed, self.servers, self.model),
            Err(_) => GridSpec::auto_pool(self.wf, self.servers),
        }
    }

    /// The context handed to policies at allocation time.
    fn ctx(&self) -> PlanContext<'a> {
        PlanContext {
            wf: self.wf,
            servers: self.servers,
            model: self.model,
            objective: self.objective,
            grid: self.eval_grid(),
        }
    }

    /// Run a policy and return the raw allocation without the final
    /// exact scoring — the cheap path for callers (like the
    /// coordinator's dispatch loop) that only need the assignment.
    /// (The context still carries the evaluation grid, so this path
    /// pays one Alg. 1/2 seed pass and grid sizing — microseconds —
    /// but skips all grid scoring for policies that don't score.)
    pub fn allocate(&self, policy: &dyn AllocationPolicy) -> Result<Allocation, SchedError> {
        policy.allocate(&self.ctx())
    }

    /// Run a policy and score its allocation exactly, on this
    /// invocation's evaluation grid (the same grid the policy saw in
    /// its [`PlanContext`]).
    pub fn plan(&self, policy: &dyn AllocationPolicy) -> Result<Plan, SchedError> {
        let ctx = self.ctx();
        let allocation = policy.allocate(&ctx)?;
        Ok(self.finish(policy.name(), allocation, ctx.grid))
    }

    /// Evaluate several policies on one *common* grid (the Fig. 7 /
    /// Table 2 bake-off) — the same evaluation grid each policy
    /// searched on. Results align with the input order; a policy that
    /// cannot allocate yields its error instead of poisoning the whole
    /// comparison.
    pub fn compare(
        &self,
        policies: &[&dyn AllocationPolicy],
    ) -> Vec<Result<Plan, SchedError>> {
        let ctx = self.ctx();
        policies
            .iter()
            .map(|p| {
                p.allocate(&ctx)
                    .map(|alloc| self.finish(p.name(), alloc, ctx.grid))
            })
            .collect()
    }

    /// Partition the pool across several concurrent workflows and plan
    /// each (wraps [`multijob_allocate`] with this planner's model and
    /// objective). Only the pool, model and objective carry over: the
    /// builder's own workflow is not implicitly part of the job set,
    /// and a pinned [`Planner::grid`] is not used — each job is scored
    /// on its own response-aware grid inside the partitioner.
    pub fn plan_jobs(&self, jobs: &[&Workflow]) -> Result<Vec<JobPlan>, SchedError> {
        multijob_allocate(jobs, self.servers, self.model, self.objective)
    }

    fn finish(&self, policy_name: String, allocation: Allocation, grid: GridSpec) -> Plan {
        let score = score_allocation_with(self.wf, &allocation, self.servers, &grid, self.model);
        let stable = score.is_stable();
        Plan {
            allocation,
            score,
            policy_name,
            diagnostics: Diagnostics {
                model: self.model,
                objective: self.objective,
                grid,
                stable,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::response::{mean_response, ResponseModel};
    use crate::sched::schedule_rates;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn plan_scores_each_builtin_policy() {
        let (wf, servers) = fig6();
        let planner = Planner::new(&wf, &servers);
        for policy in [
            &SdccPolicy as &dyn AllocationPolicy,
            &BaselinePolicy::default(),
            &ProposedPolicy::default(),
            &OptimalPolicy,
        ] {
            let plan = planner.plan(policy).expect("fig6 is feasible");
            assert!(plan.diagnostics.stable, "{} unstable", plan.policy_name);
            assert!(plan.score.mean > 0.0 && plan.score.p99 > plan.score.mean);
            plan.allocation.validate(&wf, servers.len()).unwrap();
        }
    }

    #[test]
    fn compare_reproduces_table2_ordering() {
        // the paper's Fig. 7 / Table 2 claim: optimal <= proposed <= baseline
        let (wf, servers) = fig6();
        let plans: Vec<Plan> = Planner::new(&wf, &servers)
            .objective(Objective::Mean)
            .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default(), &OptimalPolicy])
            .into_iter()
            .collect::<Result<_, _>>()
            .expect("all feasible on fig6");
        let (ours, base, opt) = (&plans[0], &plans[1], &plans[2]);
        assert_eq!(ours.policy_name, "proposed");
        assert_eq!(base.policy_name, "baseline");
        assert_eq!(opt.policy_name, "optimal");
        // common grid across the whole comparison
        assert_eq!(ours.diagnostics.grid, base.diagnostics.grid);
        assert_eq!(ours.diagnostics.grid, opt.diagnostics.grid);
        assert!(opt.score.mean <= ours.score.mean + 1e-6);
        assert!(ours.score.mean <= base.score.mean + 1e-9);
    }

    #[test]
    fn pinned_grid_is_respected() {
        let (wf, servers) = fig6();
        let grid = GridSpec::new(0.02, 2048);
        let plan = Planner::new(&wf, &servers)
            .grid(grid)
            .plan(&SdccPolicy)
            .unwrap();
        assert_eq!(plan.diagnostics.grid, grid);
    }

    #[test]
    fn objective_flows_through() {
        let (wf, servers) = fig6();
        let by_mean = Planner::new(&wf, &servers)
            .objective(Objective::Mean)
            .plan(&ProposedPolicy::default())
            .unwrap();
        let by_var = Planner::new(&wf, &servers)
            .objective(Objective::Variance)
            .plan(&ProposedPolicy::default())
            .unwrap();
        assert!(by_var.score.var <= by_mean.score.var + 1e-9);
        assert!(by_mean.objective_key() == by_mean.score.mean);
        assert!(by_var.objective_key() == by_var.score.var);
    }

    #[test]
    fn infeasible_policies_do_not_poison_compare() {
        // 2-slot tandem at a load only good placements survive: the
        // whole comparison still returns per-policy results
        let wf = Workflow::tandem(2, 20.0);
        let servers = Server::pool_exponential(&[3.0, 4.0]);
        let results = Planner::new(&wf, &servers)
            .compare(&[&ProposedPolicy::default(), &BaselinePolicy::default()]);
        assert_eq!(results.len(), 2);
        for r in results {
            assert!(r.is_err(), "overload must be infeasible");
        }
    }

    #[test]
    fn plan_jobs_partitions_the_pool() {
        let heavy = Workflow::fig6();
        let light = Workflow::tandem(3, 1.0);
        let pool =
            Server::pool_exponential(&[14.0, 12.0, 10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
        let plans = Planner::new(&heavy, &pool)
            .plan_jobs(&[&heavy, &light])
            .unwrap();
        assert_eq!(plans.len(), 2);
        let mut used: Vec<usize> = plans
            .iter()
            .flat_map(|p| p.alloc.slot_server.clone())
            .collect();
        used.sort_unstable();
        let before = used.len();
        used.dedup();
        assert_eq!(before, used.len(), "jobs must not share servers");
    }

    #[test]
    fn user_policies_plug_in() {
        // a custom policy: identity placement + equilibrium rates
        struct IdentityPolicy;
        impl AllocationPolicy for IdentityPolicy {
            fn name(&self) -> String {
                "identity".into()
            }
            fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
                schedule_rates(
                    ctx.wf,
                    (0..ctx.wf.slots()).collect(),
                    ctx.servers,
                    ctx.model,
                )
            }
        }
        let (wf, servers) = fig6();
        let plan = Planner::new(&wf, &servers).plan(&IdentityPolicy).unwrap();
        assert_eq!(plan.policy_name, "identity");
        assert_eq!(plan.allocation.slot_server, vec![0, 1, 2, 3, 4, 5]);
        assert!(plan.diagnostics.stable);
        // and the context exposes a usable model for custom logic
        assert!(mean_response(ResponseModel::Mm1, &servers[0].dist, 1.0).is_some());
    }
}
