//! Allocation policies — the pluggable strategy behind a
//! [`Planner`](crate::plan::Planner).
//!
//! Each paper algorithm is one [`AllocationPolicy`] implementation;
//! user code can add its own by implementing the trait (the
//! [`PlanContext`] hands a policy everything the built-ins use: the
//! request, a lazily-computed Alg. 1/2 seed, a lazily-sized evaluation
//! grid, and the injected [`ScoreBackend`]).

use std::cell::OnceCell;
use std::fmt;

use crate::compose::backend::ScoreBackend;
use crate::compose::grid::GridSpec;
use crate::compose::score::Score;
use crate::flow::Workflow;
use crate::sched::algorithms::{allocate_with, baseline_allocate_split, SplitPolicy};
use crate::sched::optimal::exhaustive_with;
use crate::sched::refine::refine_with;
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// Everything a policy may consult when producing an allocation: the
/// workflow, the believed server pool, the queueing model, the
/// administrator's objective, plus three lazily-materialized resources
/// shared across every policy the same planner invocation runs:
///
/// * [`PlanContext::seed`] — the Alg. 1/2 sort-matching allocation,
///   computed at most once (policies that refine from the seed and the
///   grid sizing below share it);
/// * [`PlanContext::grid`] — the evaluation grid (the pinned one, else
///   response-aware from the seed), sized at most once and only when
///   some policy actually scores — the pure
///   [`Planner::allocate`](crate::plan::Planner::allocate) path of a
///   non-scoring policy never pays the seed pass;
/// * [`PlanContext::backend`] — the injected [`ScoreBackend`] all
///   scoring flows through.
pub struct PlanContext<'a> {
    /// Workflow being planned.
    pub wf: &'a Workflow,
    /// Server pool (believed laws).
    pub servers: &'a [Server],
    /// Queueing model turning service laws into response laws.
    pub model: ResponseModel,
    /// What the administrator optimizes.
    pub objective: Objective,
    backend: &'a dyn ScoreBackend,
    pinned: Option<GridSpec>,
    seed: OnceCell<Result<Allocation, SchedError>>,
    grid: OnceCell<GridSpec>,
}

impl<'a> PlanContext<'a> {
    /// Build a context. `grid` pins the evaluation grid; `None` defers
    /// to the seed-derived auto grid. (Normally the
    /// [`Planner`](crate::plan::Planner) builds this for you.)
    pub fn new(
        wf: &'a Workflow,
        servers: &'a [Server],
        model: ResponseModel,
        objective: Objective,
        backend: &'a dyn ScoreBackend,
        grid: Option<GridSpec>,
    ) -> PlanContext<'a> {
        PlanContext {
            wf,
            servers,
            model,
            objective,
            backend,
            pinned: grid,
            seed: OnceCell::new(),
            grid: OnceCell::new(),
        }
    }

    /// The scoring backend this invocation evaluates against.
    pub fn backend(&self) -> &dyn ScoreBackend {
        self.backend
    }

    /// The Alg. 1/2 sort-matching seed allocation, computed on first
    /// use and shared by every later caller in this invocation.
    pub fn seed(&self) -> Result<Allocation, SchedError> {
        self.seed
            .get_or_init(|| allocate_with(self.wf, self.servers, self.model))
            .clone()
    }

    /// The single evaluation grid for this invocation: the pinned one,
    /// else a response-aware grid sized from the [`PlanContext::seed`]
    /// allocation (falling back to the pool-wide service-law grid when
    /// no seed is feasible). Sized lazily, at most once, against the
    /// laws the backend actually scores
    /// ([`ScoreBackend::scoring_pool`]), so measured tails longer than
    /// the believed ones still fit the grid.
    pub fn grid(&self) -> GridSpec {
        if let Some(g) = self.pinned {
            return g;
        }
        *self.grid.get_or_init(|| {
            let pool = self.backend.resolve_scoring_pool(self.servers);
            match self.seed() {
                Ok(seed) => GridSpec::auto_response(&seed, &pool, self.model),
                Err(_) => GridSpec::auto_pool(self.wf, &pool),
            }
        })
    }

    /// Score an allocation through the injected backend on this
    /// invocation's evaluation grid.
    pub fn score(&self, alloc: &Allocation) -> Score {
        self.backend
            .score(self.wf, alloc, self.servers, &self.grid(), self.model)
    }
}

impl fmt::Debug for PlanContext<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PlanContext")
            .field("wf", &self.wf)
            .field("servers", &self.servers.len())
            .field("model", &self.model)
            .field("objective", &self.objective)
            .field("backend", &self.backend.name())
            .field("pinned_grid", &self.pinned)
            .finish()
    }
}

/// A resource-allocation strategy: maps a [`PlanContext`] to a
/// rate-scheduled [`Allocation`]. Implement this to plug a custom
/// scheme into [`Planner`](crate::plan::Planner) next to the paper's
/// algorithms.
pub trait AllocationPolicy {
    /// Short human-readable policy name (appears in [`Plan`] rows).
    ///
    /// [`Plan`]: crate::plan::Plan
    fn name(&self) -> String;

    /// Produce an allocation for the context, or report why none
    /// exists.
    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError>;
}

/// Algorithm 1 + 2 exactly as the paper states them: sort-matching
/// placement plus equilibrium rate scheduling, no refinement.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let plan = Planner::new(&wf, &servers).plan(&SdccPolicy).expect("feasible");
/// assert_eq!(plan.policy_name, "sdcc");
/// assert!(plan.score.mean > 0.0);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SdccPolicy;

impl AllocationPolicy for SdccPolicy {
    fn name(&self) -> String {
        "sdcc".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        ctx.seed()
    }
}

/// The §3 heuristic baseline: fastest servers to serial slots first,
/// fork rates split per `split` (the paper's comparator uses
/// [`SplitPolicy::Uniform`], the "homogeneous assumption"; the
/// equilibrium split is the `fair-baseline` ablation).
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let base = Planner::new(&wf, &servers)
///     .plan(&BaselinePolicy::default())
///     .expect("feasible");
/// assert_eq!(base.policy_name, "baseline");
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BaselinePolicy {
    /// How fork rates are split when the spec leaves them open.
    pub split: SplitPolicy,
}

impl Default for BaselinePolicy {
    fn default() -> Self {
        BaselinePolicy {
            split: SplitPolicy::Uniform,
        }
    }
}

impl AllocationPolicy for BaselinePolicy {
    fn name(&self) -> String {
        match self.split {
            SplitPolicy::Uniform => "baseline".into(),
            SplitPolicy::Equilibrium => "fair-baseline".into(),
        }
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        baseline_allocate_split(ctx.wf, ctx.servers, ctx.model, self.split)
    }
}

/// The paper's full proposed scheme: Alg. 1/2 seed plus the §3
/// min-max balancing refinement (`rounds` hill-climb rounds, scored
/// through the context's backend on its evaluation grid). With the
/// planner's default grid — response-aware, sized from the same
/// Alg. 1/2 seed — and `rounds == 8` this is the exact legacy
/// `proposed_allocate` pipeline (removed in 0.4.0), bit for bit.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let planner = Planner::new(&wf, &servers);
/// let ours = planner.plan(&ProposedPolicy::default()).expect("feasible");
/// let base = planner.plan(&BaselinePolicy::default()).expect("feasible");
/// assert!(ours.score.mean <= base.score.mean + 1e-9);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProposedPolicy {
    /// Maximum pairwise-swap refinement rounds. This is the *within-job*
    /// §3 hill-climb depth; the *cross-job* analogue for multi-job
    /// planning is [`Planner::swap_rounds`](crate::plan::Planner::swap_rounds).
    pub rounds: usize,
}

impl ProposedPolicy {
    /// The proposed scheme with an explicit refinement depth (`rounds`
    /// hill-climb rounds; `ProposedPolicy::default()` uses 8, the
    /// legacy pipeline's depth).
    pub fn with_rounds(rounds: usize) -> ProposedPolicy {
        ProposedPolicy { rounds }
    }
}

impl Default for ProposedPolicy {
    fn default() -> Self {
        ProposedPolicy { rounds: 8 }
    }
}

impl AllocationPolicy for ProposedPolicy {
    fn name(&self) -> String {
        "proposed".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        let seed = ctx.seed()?;
        let (alloc, _) = refine_with(
            ctx.wf,
            seed,
            ctx.servers,
            &ctx.grid(),
            ctx.model,
            ctx.objective,
            self.rounds,
            ctx.backend(),
        )?;
        Ok(alloc)
    }
}

/// The exhaustive-search reference ("optimal" in the paper's Fig. 7 /
/// Table 2): every injective assignment ranked by the cheap mean-RT
/// estimator, shortlist scored through the context's backend on its
/// evaluation grid.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let planner = Planner::new(&wf, &servers);
/// let opt = planner.plan(&OptimalPolicy).expect("feasible");
/// let ours = planner.plan(&ProposedPolicy::default()).expect("feasible");
/// assert!(opt.score.mean <= ours.score.mean + 1e-6);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptimalPolicy;

impl AllocationPolicy for OptimalPolicy {
    fn name(&self) -> String {
        "optimal".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        exhaustive_with(
            ctx.wf,
            ctx.servers,
            &ctx.grid(),
            ctx.objective,
            ctx.model,
            ctx.backend(),
        )
        .map(|(alloc, _)| alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_are_stable() {
        // the names appear in CSVs and reports; keep them pinned
        assert_eq!(SdccPolicy.name(), "sdcc");
        assert_eq!(BaselinePolicy::default().name(), "baseline");
        assert_eq!(
            BaselinePolicy {
                split: SplitPolicy::Equilibrium
            }
            .name(),
            "fair-baseline"
        );
        assert_eq!(ProposedPolicy::default().name(), "proposed");
        assert_eq!(OptimalPolicy.name(), "optimal");
    }
}
