//! Allocation policies — the pluggable strategy behind a
//! [`Planner`](crate::plan::Planner).
//!
//! Each paper algorithm is one [`AllocationPolicy`] implementation;
//! user code can add its own by implementing the trait (the
//! [`PlanContext`] hands a policy everything the built-ins use).

use crate::compose::grid::GridSpec;
use crate::flow::Workflow;
use crate::sched::algorithms::{allocate_with, baseline_allocate_split, SplitPolicy};
use crate::sched::optimal::exhaustive;
use crate::sched::refine::refine;
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::{Allocation, Objective, SchedError};

/// Everything a policy may consult when producing an allocation: the
/// workflow, the believed server pool, the queueing model, the
/// administrator's objective, and the evaluation grid (sized by the
/// [`Planner`](crate::plan::Planner) when the caller did not pin one).
#[derive(Clone, Copy, Debug)]
pub struct PlanContext<'a> {
    /// Workflow being planned.
    pub wf: &'a Workflow,
    /// Server pool (believed laws).
    pub servers: &'a [Server],
    /// Queueing model turning service laws into response laws.
    pub model: ResponseModel,
    /// What the administrator optimizes.
    pub objective: Objective,
    /// Evaluation grid for policies that score candidates exactly.
    pub grid: GridSpec,
}

/// A resource-allocation strategy: maps a [`PlanContext`] to a
/// rate-scheduled [`Allocation`]. Implement this to plug a custom
/// scheme into [`Planner`](crate::plan::Planner) next to the paper's
/// algorithms.
pub trait AllocationPolicy {
    /// Short human-readable policy name (appears in [`Plan`] rows).
    ///
    /// [`Plan`]: crate::plan::Plan
    fn name(&self) -> String;

    /// Produce an allocation for the context, or report why none
    /// exists.
    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError>;
}

/// Algorithm 1 + 2 exactly as the paper states them: sort-matching
/// placement plus equilibrium rate scheduling, no refinement.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SdccPolicy;

impl AllocationPolicy for SdccPolicy {
    fn name(&self) -> String {
        "sdcc".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        allocate_with(ctx.wf, ctx.servers, ctx.model)
    }
}

/// The §3 heuristic baseline: fastest servers to serial slots first,
/// fork rates split per `split` (the paper's comparator uses
/// [`SplitPolicy::Uniform`], the "homogeneous assumption"; the
/// equilibrium split is the `fair-baseline` ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BaselinePolicy {
    /// How fork rates are split when the spec leaves them open.
    pub split: SplitPolicy,
}

impl Default for BaselinePolicy {
    fn default() -> Self {
        BaselinePolicy {
            split: SplitPolicy::Uniform,
        }
    }
}

impl AllocationPolicy for BaselinePolicy {
    fn name(&self) -> String {
        match self.split {
            SplitPolicy::Uniform => "baseline".into(),
            SplitPolicy::Equilibrium => "fair-baseline".into(),
        }
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        baseline_allocate_split(ctx.wf, ctx.servers, ctx.model, self.split)
    }
}

/// The paper's full proposed scheme: Alg. 1/2 seed plus the §3
/// min-max balancing refinement (`rounds` hill-climb rounds, scored
/// on the context's evaluation grid). With the planner's default grid
/// — response-aware, sized from the same Alg. 1/2 seed — and
/// `rounds == 8` this is the exact legacy `proposed_allocate`
/// pipeline, bit for bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProposedPolicy {
    /// Maximum pairwise-swap refinement rounds.
    pub rounds: usize,
}

impl Default for ProposedPolicy {
    fn default() -> Self {
        ProposedPolicy { rounds: 8 }
    }
}

impl AllocationPolicy for ProposedPolicy {
    fn name(&self) -> String {
        "proposed".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        let seed = allocate_with(ctx.wf, ctx.servers, ctx.model)?;
        let (alloc, _) = refine(
            ctx.wf,
            seed,
            ctx.servers,
            &ctx.grid,
            ctx.model,
            ctx.objective,
            self.rounds,
        )?;
        Ok(alloc)
    }
}

/// The exhaustive-search reference ("optimal" in the paper's Fig. 7 /
/// Table 2): every injective assignment ranked by the cheap mean-RT
/// estimator, shortlist scored exactly on the context grid.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptimalPolicy;

impl AllocationPolicy for OptimalPolicy {
    fn name(&self) -> String {
        "optimal".into()
    }

    fn allocate(&self, ctx: &PlanContext<'_>) -> Result<Allocation, SchedError> {
        exhaustive(ctx.wf, ctx.servers, &ctx.grid, ctx.objective, ctx.model)
            .map(|(alloc, _)| alloc)
    }
}
