//! The persistent scoring fabric: long-lived worker threads fed from a
//! shared chunk queue.
//!
//! [`ShardedBackend`](super::backend::ShardedBackend) used to spawn a
//! scoped thread pool *per wave*; at re-optimization frequencies the
//! spawn/join cost dominates cheap analytic scores. [`ScoringPool`]
//! replaces it with the fabric pattern of timely's allocator layer:
//! workers are spawned **once**, park on a condvar-fed queue, execute
//! wave chunks as they arrive, and shut down gracefully when the pool
//! is dropped. Each worker owns one long-lived
//! [`Scratch`](super::scratch::Scratch) arena, so kernel buffers are
//! reused across every chunk the worker ever scores — the other half of
//! the allocation-free hot loop.
//!
//! Data flow of one [`ScoringPool::dispatch`] wave:
//!
//! ```text
//!   dispatch(n_chunks, work)                 worker 0 .. worker W-1
//!      │  enqueue n packets ──► [ chunk queue ] ──► pop ─► work(i, &mut scratch)
//!      │  (Mutex<VecDeque> + Condvar)                 │
//!      └── block on wave latch ◄── count down ◄───────┘
//!           (rethrows any worker panic)
//! ```
//!
//! `dispatch` **blocks until every chunk of its wave completed**, which
//! is what makes the lifetime-erased packet safe: the work closure is
//! borrowed only while the dispatcher is parked on the latch. A panic
//! inside a chunk is caught on the worker, carried through the latch,
//! and re-thrown on the dispatching thread — same observable behavior
//! as the scoped-pool path, and the pool stays usable afterwards.
//!
//! Optional **core pinning** (`DCFLOW_PIN_CORES=1`, or
//! [`ShardedBackend::pin_cores`](super::backend::ShardedBackend::pin_cores))
//! pins worker `i` to core `i % available_parallelism` via a raw
//! `sched_setaffinity` call on Linux (no-op elsewhere) — the
//! `core_affinity` idiom of the timely/graspan experiment drivers,
//! without the dependency.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::compose::scratch::Scratch;

/// Counter snapshot of a scoring fabric — reported through
/// [`ScoreBackend::fabric_stats`](super::backend::ScoreBackend::fabric_stats)
/// and surfaced in [`SwapStats`](crate::sched::multijob::SwapStats) /
/// `BENCH_multijob.json` so pool behavior is observable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Worker threads in the pool (the configured shard count).
    pub workers: usize,
    /// Whether workers were pinned to cores (only ever true on Linux).
    pub pinned: bool,
    /// Waves scored inline on the caller thread (below the parallel
    /// threshold) instead of being dispatched.
    pub waves_inline: usize,
    /// Waves fanned out across workers.
    pub waves_dispatched: usize,
    /// Chunks enqueued across all dispatched waves.
    pub chunks_dispatched: usize,
    /// High-water mark of the chunk queue depth at enqueue time.
    pub max_queue_depth: usize,
    /// Scratch-buffer heap events (created + grown) summed over all
    /// workers — flat after warm-up when the hot loop is
    /// allocation-free (see [`Scratch::buffer_allocs`]).
    pub scratch_allocs: usize,
}

/// A caught worker panic, carried back to the dispatching thread.
type Payload = Box<dyn std::any::Any + Send + 'static>;

/// Completion latch for one dispatched wave.
struct WaveLatch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Payload>,
}

impl WaveLatch {
    fn new(remaining: usize) -> WaveLatch {
        WaveLatch {
            state: Mutex::new(LatchState {
                remaining,
                panic: None,
            }),
            done: Condvar::new(),
        }
    }

    /// One chunk finished (carrying its panic payload, if it had one).
    fn complete(&self, panic: Option<Payload>) {
        let mut st = self.state.lock().expect("fabric latch");
        if st.panic.is_none() {
            if let Some(p) = panic {
                st.panic = Some(p);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every chunk completed; rethrow the first panic.
    fn wait(&self) {
        let mut st = self.state.lock().expect("fabric latch");
        while st.remaining > 0 {
            st = self.done.wait(st).expect("fabric latch");
        }
        if let Some(p) = st.panic.take() {
            drop(st);
            resume_unwind(p);
        }
    }
}

/// One unit of queued work: a lifetime-erased chunk closure call.
struct Packet {
    /// Monomorphized trampoline re-typing `ctx` to the closure.
    run: unsafe fn(*const (), usize, &mut Scratch),
    /// Borrow of the `dispatch` caller's closure, erased.
    ctx: *const (),
    /// Chunk index passed through to the closure.
    chunk: usize,
    /// The dispatching wave's completion latch.
    wave: Arc<WaveLatch>,
}

// Safety: `ctx` borrows the closure passed to `dispatch`, and
// `dispatch` blocks on the wave latch until every packet of the wave
// has called `complete` — the pointee strictly outlives every use. The
// closure bound is `Sync`, so concurrent shared access from workers is
// sound. Nothing else in the packet is thread-affine.
unsafe impl Send for Packet {}

struct Queue {
    packets: VecDeque<Packet>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
    waves: AtomicUsize,
    chunks: AtomicUsize,
    depth_hwm: AtomicUsize,
    scratch_allocs: AtomicUsize,
}

/// A persistent pool of scoring workers (see the [module docs](self)).
///
/// Construction spawns the threads; [`ScoringPool::dispatch`] fans a
/// wave of chunk indices across them and blocks until the wave
/// completed; dropping the pool signals shutdown and joins every
/// worker. The pool is `Sync`: concurrent `dispatch` calls interleave
/// safely (each wave has its own latch) — the property
/// [`AsyncScoreBackend`](super::backend::AsyncScoreBackend) builds on
/// to keep several chunks in flight while candidates are still being
/// enumerated. [`ShardedBackend`](super::backend::ShardedBackend)
/// dispatches sequentially, one wave at a time.
pub struct ScoringPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    pinned: bool,
}

impl ScoringPool {
    /// Spawn a pool of `workers` threads (values `< 1` are treated as
    /// 1) without core pinning.
    pub fn new(workers: usize) -> ScoringPool {
        Self::with_pinning(workers, false)
    }

    /// Spawn a pool of `workers` threads, optionally pinning worker `i`
    /// to core `i % available_parallelism` (Linux only; `pin` is
    /// recorded as effective only where the syscall exists).
    pub fn with_pinning(workers: usize, pin: bool) -> ScoringPool {
        let n = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                packets: VecDeque::new(),
                shutdown: false,
            }),
            available: Condvar::new(),
            waves: AtomicUsize::new(0),
            chunks: AtomicUsize::new(0),
            depth_hwm: AtomicUsize::new(0),
            scratch_allocs: AtomicUsize::new(0),
        });
        let handles = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dcflow-score-{i}"))
                    .spawn(move || worker_loop(&shared, i, pin))
                    .expect("spawn scoring worker")
            })
            .collect();
        ScoringPool {
            shared,
            workers: handles,
            pinned: pin && cfg!(target_os = "linux"),
        }
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Whether workers were pinned to cores.
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// Fan `work` over chunk indices `0..chunks` and block until every
    /// chunk completed. Each invocation receives the chunk index and
    /// the executing worker's long-lived [`Scratch`]. Chunks may run on
    /// any worker in any order; a panic inside `work` is re-thrown here
    /// after the wave drains (the pool survives it).
    pub fn dispatch<F>(&self, chunks: usize, work: &F)
    where
        F: Fn(usize, &mut Scratch) + Sync,
    {
        if chunks == 0 {
            return;
        }
        /// Re-type the erased context back to `&F` and call it.
        unsafe fn trampoline<F: Fn(usize, &mut Scratch) + Sync>(
            ctx: *const (),
            chunk: usize,
            scratch: &mut Scratch,
        ) {
            // Safety: `ctx` is the `&F` borrow taken in `dispatch`,
            // alive until the wave latch below releases the dispatcher.
            let work = unsafe { &*ctx.cast::<F>() };
            work(chunk, scratch);
        }
        let latch = Arc::new(WaveLatch::new(chunks));
        {
            let mut q = self.shared.queue.lock().expect("fabric queue");
            for chunk in 0..chunks {
                q.packets.push_back(Packet {
                    run: trampoline::<F>,
                    ctx: (work as *const F).cast(),
                    chunk,
                    wave: Arc::clone(&latch),
                });
            }
            self.shared
                .depth_hwm
                .fetch_max(q.packets.len(), Ordering::Relaxed);
        }
        self.shared.available.notify_all();
        self.shared.waves.fetch_add(1, Ordering::Relaxed);
        self.shared.chunks.fetch_add(chunks, Ordering::Relaxed);
        latch.wait();
    }

    /// Counter snapshot (`waves_inline` is always 0 here — inline waves
    /// never reach the pool; [`ShardedBackend`] merges its own inline
    /// counter in).
    ///
    /// [`ShardedBackend`]: super::backend::ShardedBackend
    pub fn stats(&self) -> FabricStats {
        FabricStats {
            workers: self.workers.len(),
            pinned: self.pinned,
            waves_inline: 0,
            waves_dispatched: self.shared.waves.load(Ordering::Relaxed),
            chunks_dispatched: self.shared.chunks.load(Ordering::Relaxed),
            max_queue_depth: self.shared.depth_hwm.load(Ordering::Relaxed),
            scratch_allocs: self.shared.scratch_allocs.load(Ordering::Relaxed),
        }
    }
}

impl Drop for ScoringPool {
    fn drop(&mut self) {
        self.shared.queue.lock().expect("fabric queue").shutdown = true;
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            // worker panics were already rethrown at dispatch; a join
            // error here cannot carry new information
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ScoringPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScoringPool")
            .field("workers", &self.workers.len())
            .field("pinned", &self.pinned)
            .finish()
    }
}

fn worker_loop(shared: &Shared, index: usize, pin: bool) {
    if pin {
        pin_to_core(index);
    }
    let mut scratch = Scratch::new();
    loop {
        let packet = {
            let mut q = shared.queue.lock().expect("fabric queue");
            loop {
                if let Some(p) = q.packets.pop_front() {
                    break p;
                }
                if q.shutdown {
                    return;
                }
                q = shared.available.wait(q).expect("fabric queue");
            }
        };
        let before = scratch.buffer_allocs();
        // Safety: see `Packet` — the dispatcher is parked on this
        // wave's latch until `complete` below, so `ctx` is alive.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe {
            (packet.run)(packet.ctx, packet.chunk, &mut scratch)
        }));
        shared
            .scratch_allocs
            .fetch_add(scratch.buffer_allocs() - before, Ordering::Relaxed);
        packet.wave.complete(result.err());
    }
}

/// Pin the calling thread to core `index % available_parallelism`.
/// Returns whether the affinity call succeeded.
#[cfg(target_os = "linux")]
fn pin_to_core(index: usize) -> bool {
    // 16 usize words of mask = 1024 CPUs, the kernel's CONFIG_NR_CPUS
    // ceiling on common distro kernels
    const MASK_WORDS: usize = 16;
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const usize) -> i32;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let core = index % cores;
    let bits = usize::BITS as usize;
    let mut mask = [0usize; MASK_WORDS];
    if core / bits >= MASK_WORDS {
        return false;
    }
    mask[core / bits] |= 1usize << (core % bits);
    // Safety: pid 0 = the calling thread; the mask buffer is a valid,
    // properly sized cpu_set_t-compatible word array.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_to_core(_index: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_runs_every_chunk_exactly_once() {
        let pool = ScoringPool::new(3);
        let hits: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
        pool.dispatch(17, &|i, _scratch| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
        }
        let st = pool.stats();
        assert_eq!(st.workers, 3);
        assert_eq!(st.waves_dispatched, 1);
        assert_eq!(st.chunks_dispatched, 17);
        assert!(st.max_queue_depth >= 1 && st.max_queue_depth <= 17);
    }

    #[test]
    fn waves_are_synchronous_barriers() {
        // every chunk of wave k must be complete before wave k+1 runs
        let pool = ScoringPool::new(4);
        let total = AtomicUsize::new(0);
        for wave in 0..5usize {
            pool.dispatch(8, &|_i, _s| {
                total.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), (wave + 1) * 8);
        }
        assert_eq!(pool.stats().waves_dispatched, 5);
    }

    #[test]
    fn zero_chunk_wave_is_a_noop() {
        let pool = ScoringPool::new(2);
        pool.dispatch(0, &|_i, _s| panic!("must not run"));
        assert_eq!(pool.stats().waves_dispatched, 0);
    }

    #[test]
    fn worker_panic_is_rethrown_and_pool_survives() {
        let pool = ScoringPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.dispatch(12, &|i, _s| {
                if i == 7 {
                    panic!("chunk 7 exploded");
                }
            });
        }));
        let payload = caught.expect_err("panic must propagate to the dispatcher");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("chunk 7"), "unexpected payload: {msg}");
        // the pool is still alive and consistent after the panic wave
        let ran = AtomicUsize::new(0);
        pool.dispatch(6, &|_i, _s| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn worker_scratch_is_long_lived() {
        // the same workers keep their scratch across waves: after a
        // warm-up wave has touched every worker at most `workers`
        // creations can ever appear, no matter how many waves follow
        let pool = ScoringPool::new(2);
        for _ in 0..10 {
            pool.dispatch(4, &|_i, scratch| {
                let a = scratch.take_f64(256);
                let b = scratch.take_f64(256);
                scratch.put_f64(a);
                scratch.put_f64(b);
            });
        }
        let st = pool.stats();
        // ≤ 2 buffers per worker, ever; 40 chunks would naively be 80
        assert!(
            st.scratch_allocs <= 2 * st.workers,
            "scratch not reused: {} allocs across 10 waves",
            st.scratch_allocs
        );
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ScoringPool::new(3);
        pool.dispatch(3, &|_i, _s| {});
        drop(pool); // must not hang or leak threads
    }

    #[test]
    fn degenerate_worker_count_is_clamped() {
        let pool = ScoringPool::new(0);
        assert_eq!(pool.workers(), 1);
        let ran = AtomicUsize::new(0);
        pool.dispatch(4, &|_i, _s| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 4);
    }
}
