//! Grid moments, quantiles and CDF/PDF conversions — the same trapezoid /
//! central-difference conventions as `python/compile/kernels/ref.py`.

use crate::compose::scratch::Scratch;

/// Trapezoid cumulative integral of a PDF grid, clipped to [0, 1].
pub fn cdf_from_pdf(pdf: &[f64], dt: f64) -> Vec<f64> {
    let mut acc = 0.0;
    let p0 = pdf.first().copied().unwrap_or(0.0);
    pdf.iter()
        .map(|&p| {
            acc += p * dt;
            (acc - dt * (p + p0) / 2.0).clamp(0.0, 1.0)
        })
        .collect()
}

/// [`cdf_from_pdf`] into a caller buffer (same length as `pdf`) — the
/// same running trapezoid sum, bit-identical.
pub fn cdf_from_pdf_into(pdf: &[f64], dt: f64, out: &mut [f64]) {
    assert_eq!(out.len(), pdf.len(), "output grid must match");
    let mut acc = 0.0;
    let p0 = pdf.first().copied().unwrap_or(0.0);
    for (o, &p) in out.iter_mut().zip(pdf.iter()) {
        acc += p * dt;
        *o = (acc - dt * (p + p0) / 2.0).clamp(0.0, 1.0);
    }
}

/// (mean, variance) of a PDF grid by Riemann sums, normalized by the
/// captured mass (grid truncation must not bias the retained part).
pub fn moments(pdf: &[f64], dt: f64) -> (f64, f64) {
    let mut mass = 0.0;
    let mut m1 = 0.0;
    let mut m2 = 0.0;
    for (k, &p) in pdf.iter().enumerate() {
        let t = k as f64 * dt;
        mass += p;
        m1 += t * p;
        m2 += t * t * p;
    }
    let mass = (mass * dt).max(1e-12);
    let mean = m1 * dt / mass;
    let ex2 = m2 * dt / mass;
    (mean, (ex2 - mean * mean).max(0.0))
}

/// Smallest grid time whose CDF reaches `q` (grid end if never reached).
pub fn quantile(pdf: &[f64], dt: f64, q: f64) -> f64 {
    let cdf = cdf_from_pdf(pdf, dt);
    for (k, &c) in cdf.iter().enumerate() {
        if c >= q {
            return k as f64 * dt;
        }
    }
    (pdf.len() - 1) as f64 * dt
}

/// [`quantile`] with the intermediate CDF built in a scratch buffer
/// instead of a fresh `Vec` — same trapezoid accumulation, same scan,
/// bit-identical result. (Deliberately *recomputes* the CDF from the
/// PDF rather than accepting one: [`quantile`]'s contract is defined
/// against `cdf_from_pdf(pdf)`, which differs in the last ulp from a
/// composition node's own CDF at Queue and Parallel roots.)
pub fn quantile_scratch(pdf: &[f64], dt: f64, q: f64, scratch: &mut Scratch) -> f64 {
    let mut cdf = scratch.take_f64(pdf.len());
    cdf_from_pdf_into(pdf, dt, &mut cdf);
    let mut at = (pdf.len() - 1) as f64 * dt;
    for (k, &c) in cdf.iter().enumerate() {
        if c >= q {
            at = k as f64 * dt;
            break;
        }
    }
    scratch.put_f64(cdf);
    at
}

/// Mass captured by the grid (sanity signal: < 0.99 means the grid
/// truncated real probability and scores are suspect).
pub fn captured_mass(pdf: &[f64], dt: f64) -> f64 {
    pdf.iter().sum::<f64>() * dt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ServiceDist;

    #[test]
    fn exponential_moments() {
        let (n, dt) = (8192, 0.005);
        let pdf = ServiceDist::exponential(2.0).pdf_grid(dt, n);
        let (mean, var) = moments(&pdf, dt);
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 0.25).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn cdf_matches_analytic() {
        let (n, dt) = (4096, 0.005);
        let d = ServiceDist::exponential(1.0);
        let cdf = cdf_from_pdf(&d.pdf_grid(dt, n), dt);
        for k in (1..n).step_by(211) {
            let want = d.cdf(k as f64 * dt);
            assert!((cdf[k] - want).abs() < 5e-3, "k={k}");
        }
    }

    #[test]
    fn quantile_median_of_exponential() {
        let (n, dt) = (8192, 0.002);
        let pdf = ServiceDist::exponential(1.0).pdf_grid(dt, n);
        let med = quantile(&pdf, dt, 0.5);
        assert!((med - (2.0f64).ln()).abs() < 0.01, "median {med}");
    }

    #[test]
    fn captured_mass_near_one_when_grid_covers() {
        let (n, dt) = (4096, 0.01);
        let pdf = ServiceDist::exponential(2.0).pdf_grid(dt, n);
        assert!((captured_mass(&pdf, dt) - 1.0).abs() < 0.01);
    }

    #[test]
    fn quantile_saturates_at_grid_end() {
        let (n, dt) = (64, 0.01); // deliberately truncated grid
        let pdf = ServiceDist::exponential(0.1).pdf_grid(dt, n);
        assert_eq!(quantile(&pdf, dt, 0.999), (n - 1) as f64 * dt);
    }

    #[test]
    fn scratch_variants_are_bit_identical() {
        let mut scratch = crate::compose::scratch::Scratch::new();
        let (n, dt) = (512, 0.01);
        for lam in [0.1, 1.0, 2.0, 7.5] {
            let pdf = ServiceDist::exponential(lam).pdf_grid(dt, n);
            let want_cdf = cdf_from_pdf(&pdf, dt);
            let mut got_cdf = vec![f64::NAN; n];
            cdf_from_pdf_into(&pdf, dt, &mut got_cdf);
            for (x, y) in got_cdf.iter().zip(want_cdf.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for q in [0.5, 0.99, 0.999] {
                let want = quantile(&pdf, dt, q);
                let got = quantile_scratch(&pdf, dt, q, &mut scratch);
                assert_eq!(got.to_bits(), want.to_bits(), "lam={lam} q={q}");
            }
        }
        // warm scratch ⇒ further quantiles allocate nothing
        let pdf = ServiceDist::exponential(1.0).pdf_grid(dt, n);
        let warm = scratch.buffer_allocs();
        for _ in 0..5 {
            quantile_scratch(&pdf, dt, 0.99, &mut scratch);
        }
        assert_eq!(scratch.buffer_allocs(), warm);
    }
}
