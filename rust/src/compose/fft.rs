//! Self-contained radix-2 complex FFT (iterative Cooley–Tukey).
//!
//! Backs the fast convolution path in [`super::conv`]. No external
//! dependencies; sizes must be powers of two (the conv layer pads).

/// Complex number (f64).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl C64 {
    /// Construct.
    #[inline]
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    #[inline]
    fn add(self, o: C64) -> C64 {
        C64 {
            re: self.re + o.re,
            im: self.im + o.im,
        }
    }

    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64 {
            re: self.re - o.re,
            im: self.im - o.im,
        }
    }
}

/// In-place iterative radix-2 FFT. `inverse` applies the conjugate
/// transform *without* the 1/n normalization (callers normalize once).
pub fn fft_inplace(buf: &mut [C64], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft size must be a power of two");
    if n <= 1 {
        return;
    }

    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }

    // butterflies
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2usize;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = C64::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = C64::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = buf[i + k];
                let v = buf[i + k + len / 2].mul(w);
                buf[i + k] = u.add(v);
                buf[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Real linear convolution of `a` and `b` (lengths la, lb) returning
/// `la + lb - 1` coefficients, via zero-padded complex FFT.
pub fn convolve_real(a: &[f64], b: &[f64]) -> Vec<f64> {
    let out_len = a.len() + b.len() - 1;
    let size = out_len.next_power_of_two();
    let mut fa: Vec<C64> = a.iter().map(|&x| C64::new(x, 0.0)).collect();
    fa.resize(size, C64::default());
    let mut fb: Vec<C64> = b.iter().map(|&x| C64::new(x, 0.0)).collect();
    fb.resize(size, C64::default());
    fft_inplace(&mut fa, false);
    fft_inplace(&mut fb, false);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = x.mul(*y);
    }
    fft_inplace(&mut fa, true);
    let norm = 1.0 / size as f64;
    fa[..out_len].iter().map(|c| c.re * norm).collect()
}

/// [`convolve_real`] writing the first `out.len()` coefficients into a
/// caller buffer, with the complex work buffers borrowed from
/// `scratch` — bit-identical to the allocating form (same padding,
/// same butterfly schedule, same normalization), zero fresh heap
/// buffers once the scratch is warm. `out` may be shorter than the
/// full `la + lb - 1` convolution (the conv layer truncates to the
/// grid anyway) but never longer.
pub fn convolve_real_into(
    a: &[f64],
    b: &[f64],
    out: &mut [f64],
    scratch: &mut crate::compose::scratch::Scratch,
) {
    let out_len = a.len() + b.len() - 1;
    assert!(
        out.len() <= out_len,
        "convolution of {}+{} yields {} coefficients, not {}",
        a.len(),
        b.len(),
        out_len,
        out.len()
    );
    let size = out_len.next_power_of_two();
    let mut fa = scratch.take_c64(size);
    let mut fb = scratch.take_c64(size);
    for (c, &x) in fa.iter_mut().zip(a.iter()) {
        *c = C64::new(x, 0.0);
    }
    for (c, &x) in fb.iter_mut().zip(b.iter()) {
        *c = C64::new(x, 0.0);
    }
    fft_inplace(&mut fa, false);
    fft_inplace(&mut fb, false);
    for (x, y) in fa.iter_mut().zip(fb.iter()) {
        *x = x.mul(*y);
    }
    fft_inplace(&mut fa, true);
    let norm = 1.0 / size as f64;
    for (o, c) in out.iter_mut().zip(fa.iter()) {
        *o = c.re * norm;
    }
    scratch.put_c64(fa);
    scratch.put_c64(fb);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn naive_conv(a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; a.len() + b.len() - 1];
        for (i, &x) in a.iter().enumerate() {
            for (j, &y) in b.iter().enumerate() {
                out[i + j] += x * y;
            }
        }
        out
    }

    #[test]
    fn fft_roundtrip_identity() {
        let mut buf: Vec<C64> = (0..16).map(|i| C64::new(i as f64, -(i as f64))).collect();
        let orig = buf.clone();
        fft_inplace(&mut buf, false);
        fft_inplace(&mut buf, true);
        for (a, b) in buf.iter().zip(orig.iter()) {
            assert!((a.re / 16.0 - b.re).abs() < 1e-12);
            assert!((a.im / 16.0 - b.im).abs() < 1e-12);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut buf = vec![C64::default(); 8];
        buf[0] = C64::new(1.0, 0.0);
        fft_inplace(&mut buf, false);
        for c in &buf {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn convolve_matches_naive_property() {
        prop::run("fft conv == naive conv", 30, |g| {
            let la = g.usize_in(1, 60);
            let lb = g.usize_in(1, 60);
            let a = g.vec_of(la, |g| g.f64_in(-2.0, 2.0));
            let b = g.vec_of(lb, |g| g.f64_in(-2.0, 2.0));
            let fast = convolve_real(&a, &b);
            let slow = naive_conv(&a, &b);
            assert_eq!(fast.len(), slow.len());
            for (x, y) in fast.iter().zip(slow.iter()) {
                assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            }
        });
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        let mut buf = vec![C64::default(); 12];
        fft_inplace(&mut buf, false);
    }

    #[test]
    fn convolve_into_is_bit_identical_and_allocation_free() {
        use crate::compose::scratch::Scratch;
        let mut scratch = Scratch::new();
        prop::run("convolve_real_into == convolve_real", 20, |g| {
            let la = g.usize_in(1, 80);
            let lb = g.usize_in(1, 80);
            let a = g.vec_of(la, |g| g.f64_in(-2.0, 2.0));
            let b = g.vec_of(lb, |g| g.f64_in(-2.0, 2.0));
            let want = convolve_real(&a, &b);
            let mut got = vec![f64::NAN; want.len()];
            convolve_real_into(&a, &b, &mut got, &mut scratch);
            for (x, y) in got.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
            }
        });
        // warm the scratch on the largest size, then repeats are free
        let a = vec![1.0; 80];
        let mut out = vec![0.0; 159];
        convolve_real_into(&a, &a, &mut out, &mut scratch);
        let warm = scratch.buffer_allocs();
        for _ in 0..5 {
            convolve_real_into(&a, &a, &mut out, &mut scratch);
        }
        assert_eq!(scratch.buffer_allocs(), warm, "warm FFT must not allocate");
    }
}
