//! Parallel composition (paper Eq. 3–4): fork–join completes when the
//! *last* branch finishes, so the composed CDF is the product of branch
//! CDFs. Also provides min-composition (first-finisher, the cloning /
//! speculative-execution primitive from the straggler literature [16]).

use crate::dist::central_diff;

/// CDF of `max(X_1..X_n)`: elementwise product of branch CDFs.
pub fn max_cdf(cdfs: &[Vec<f64>]) -> Vec<f64> {
    assert!(!cdfs.is_empty());
    let n = cdfs[0].len();
    assert!(cdfs.iter().all(|c| c.len() == n), "grids must match");
    let mut out = vec![1.0; n];
    for c in cdfs {
        max_cdf_fold(&mut out, c);
    }
    out
}

/// One fold step of [`max_cdf`]: multiply branch CDF `branch` into the
/// accumulator in place. Folding branches in order into a `1.0`-filled
/// accumulator is exactly what [`max_cdf`] does internally, so the
/// incremental form is bit-identical — this is the scratch scoring
/// path's parallel composition (it never materializes all branch CDFs
/// at once).
pub fn max_cdf_fold(acc: &mut [f64], branch: &[f64]) {
    assert_eq!(acc.len(), branch.len(), "grids must match");
    for (o, &x) in acc.iter_mut().zip(branch.iter()) {
        *o *= x;
    }
}

/// CDF of `min(X_1..X_n)`: `1 - prod_i (1 - F_i)`.
pub fn min_cdf(cdfs: &[Vec<f64>]) -> Vec<f64> {
    assert!(!cdfs.is_empty());
    let n = cdfs[0].len();
    assert!(cdfs.iter().all(|c| c.len() == n), "grids must match");
    let mut surv = vec![1.0; n];
    for c in cdfs {
        for (s, &x) in surv.iter_mut().zip(c.iter()) {
            *s *= 1.0 - x;
        }
    }
    surv.iter().map(|s| 1.0 - s).collect()
}

/// Parallel composition returning `(cdf, pdf)` of the max, with the PDF
/// recovered by the shared central-difference convention.
pub fn parallel_compose(cdfs: &[Vec<f64>], dt: f64) -> (Vec<f64>, Vec<f64>) {
    let cdf = max_cdf(cdfs);
    let pdf = central_diff(&cdf, dt);
    (cdf, pdf)
}

/// Cloning composition returning `(cdf, pdf)` of the min.
pub fn cloning_compose(cdfs: &[Vec<f64>], dt: f64) -> (Vec<f64>, Vec<f64>) {
    let cdf = min_cdf(cdfs);
    let pdf = central_diff(&cdf, dt);
    (cdf, pdf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::moments::moments;
    use crate::dist::ServiceDist;
    use crate::util::prop;

    #[test]
    fn max_of_two_exponentials_eq4() {
        let (n, dt) = (1024, 0.01);
        let (l1, l2) = (3.0, 7.0);
        let c1 = ServiceDist::exponential(l1).cdf_grid(dt, n);
        let c2 = ServiceDist::exponential(l2).cdf_grid(dt, n);
        let out = max_cdf(&[c1, c2]);
        for k in (0..n).step_by(53) {
            let t = k as f64 * dt;
            let want = (1.0 - (-l1 * t).exp()) * (1.0 - (-l2 * t).exp());
            assert!((out[k] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn max_mean_grows_with_fanout() {
        // Fig. 3 effect: E[max of n iid Exp(1)] = H_n (harmonic number)
        let (n, dt) = (4096, 0.005);
        let d = ServiceDist::exponential(1.0);
        let mut prev = 0.0;
        for fan in [1usize, 2, 4, 8, 16] {
            let cdfs: Vec<Vec<f64>> = (0..fan).map(|_| d.cdf_grid(dt, n)).collect();
            let (_, pdf) = parallel_compose(&cdfs, dt);
            let (mean, _) = moments(&pdf, dt);
            let harmonic: f64 = (1..=fan).map(|i| 1.0 / i as f64).sum();
            assert!((mean - harmonic).abs() < 0.05, "fan={fan}: {mean} vs {harmonic}");
            assert!(mean > prev);
            prev = mean;
        }
    }

    #[test]
    fn min_of_exponentials_is_exponential() {
        // min of Exp(a), Exp(b) = Exp(a+b)
        let (n, dt) = (2048, 0.005);
        let c1 = ServiceDist::exponential(2.0).cdf_grid(dt, n);
        let c2 = ServiceDist::exponential(3.0).cdf_grid(dt, n);
        let out = min_cdf(&[c1, c2]);
        for k in (0..n).step_by(101) {
            let t = k as f64 * dt;
            let want = 1.0 - (-5.0 * t).exp();
            assert!((out[k] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn max_dominates_every_branch() {
        prop::run("max stochastically dominates branches", 20, |g| {
            let n = 256;
            let dt = 0.05;
            let fan = g.usize_in(2, 5);
            let cdfs: Vec<Vec<f64>> = (0..fan)
                .map(|_| ServiceDist::exponential(g.rate()).cdf_grid(dt, n))
                .collect();
            let out = max_cdf(&cdfs);
            for c in &cdfs {
                for (o, x) in out.iter().zip(c.iter()) {
                    assert!(*o <= *x + 1e-12); // F_max <= F_i pointwise
                }
            }
        });
    }

    #[test]
    fn min_faster_than_max() {
        let (n, dt) = (2048, 0.005);
        let d = ServiceDist::exponential(1.0);
        let cdfs: Vec<Vec<f64>> = (0..4).map(|_| d.cdf_grid(dt, n)).collect();
        let (_, pmax) = parallel_compose(&cdfs, dt);
        let (_, pmin) = cloning_compose(&cdfs, dt);
        let (mmax, _) = moments(&pmax, dt);
        let (mmin, _) = moments(&pmin, dt);
        assert!(mmin < mmax / 4.0, "min {mmin} max {mmax}");
    }

    #[test]
    #[should_panic(expected = "grids must match")]
    fn rejects_mismatched() {
        max_cdf(&[vec![0.0; 8], vec![0.0; 9]]);
    }

    #[test]
    fn fold_is_bit_identical_to_batch_product() {
        prop::run("max_cdf_fold == max_cdf", 20, |g| {
            let n = 128;
            let dt = 0.05;
            let fan = g.usize_in(1, 6);
            let cdfs: Vec<Vec<f64>> = (0..fan)
                .map(|_| ServiceDist::exponential(g.rate()).cdf_grid(dt, n))
                .collect();
            let want = max_cdf(&cdfs);
            let mut acc = vec![1.0; n];
            for c in &cdfs {
                max_cdf_fold(&mut acc, c);
            }
            for (x, y) in acc.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }
}
