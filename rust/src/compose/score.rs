//! Allocation scoring: end-to-end response-time law of a workflow under
//! an allocation, plus the (mean, variance, p99) score triple.
//!
//! This is the native twin of the AOT fig6 scorer
//! (`python/compile/model.py::score_fig6`): identical math, arbitrary
//! topology. The PJRT path (`crate::runtime::scorer`) is preferred on
//! the hot loop for the fig6 template; this path covers everything else
//! and is the cross-check oracle.

use crate::compose::conv::{conv_auto, conv_auto_into};
use crate::compose::grid::GridSpec;
use crate::compose::maxcomp::{max_cdf, max_cdf_fold};
use crate::compose::moments::{
    captured_mass, cdf_from_pdf, cdf_from_pdf_into, moments, quantile, quantile_scratch,
};
use crate::compose::scratch::Scratch;
use crate::dist::{central_diff, central_diff_into};
use crate::flow::{Dcc, Workflow};
use crate::sched::response::{response_dist, Response, ResponseModel};
use crate::sched::server::Server;
use crate::sched::Allocation;

/// Score of one allocation.
#[derive(Clone, Debug)]
pub struct Score {
    /// Mean end-to-end response time.
    pub mean: f64,
    /// Variance of the end-to-end response time.
    pub var: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Probability mass captured by the grid (< 0.99 = suspect grid).
    pub mass: f64,
    /// End-to-end response-time PDF on the grid (Fig. 7 curves).
    pub pdf: Vec<f64>,
}

impl Score {
    /// A bare score triple with no attached PDF (full captured mass).
    /// Use this instead of building the struct by hand — tests and
    /// adapters that only carry (mean, var, p99) should not care about
    /// the grid bookkeeping fields.
    pub fn point(mean: f64, var: f64, p99: f64) -> Score {
        Score {
            mean,
            var,
            p99,
            mass: 1.0,
            pdf: Vec::new(),
        }
    }

    /// Sentinel for unstable allocations (some queue diverges), carrying
    /// a zero PDF on `grid` so downstream plotting code sees a law of
    /// the expected length.
    ///
    /// **Sentinel contract** (every [`ScoreBackend`] must honor it, and
    /// combinators like `ShardedBackend` propagate it untouched):
    /// an infeasible candidate scores `mean = var = p99 = +∞` and
    /// `mass = 0.0` — never NaN in any of the three objective
    /// components, so [`Objective::key`](crate::sched::Objective::key)
    /// ordering stays total and search loops can skip the candidate via
    /// [`Score::is_stable`] without a NaN ever reaching a comparison.
    ///
    /// [`ScoreBackend`]: crate::compose::backend::ScoreBackend
    pub fn unstable(grid: &GridSpec) -> Score {
        Score {
            pdf: vec![0.0; grid.n],
            ..Score::unstable_point()
        }
    }

    /// The PDF-less form of the [`Score::unstable`] sentinel, for
    /// backends that carry no grid law (e.g. the fused PJRT triple
    /// path). Identical infinity/mass sentinels, empty `pdf`.
    pub fn unstable_point() -> Score {
        Score {
            mean: f64::INFINITY,
            var: f64::INFINITY,
            p99: f64::INFINITY,
            mass: 0.0,
            pdf: Vec::new(),
        }
    }

    /// True when every queue in the allocation was stable. A NaN mean
    /// (a degenerate fitted law leaking through a backend) counts as
    /// unstable, so search loops discard the candidate instead of
    /// comparing NaN keys.
    pub fn is_stable(&self) -> bool {
        self.mean.is_finite()
    }
}

/// Score with the default M/M/1 response model.
pub fn score_allocation(
    wf: &Workflow,
    alloc: &Allocation,
    servers: &[Server],
    grid: &GridSpec,
) -> Score {
    score_allocation_with(wf, alloc, servers, grid, ResponseModel::Mm1)
}

/// Score with an explicit response model.
pub fn score_allocation_with(
    wf: &Workflow,
    alloc: &Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
) -> Score {
    match compose_node(wf.root(), alloc, servers, grid, model) {
        None => Score::unstable(grid),
        Some((pdf, _cdf)) => {
            let (mean, var) = moments(&pdf, grid.dt);
            Score {
                mean,
                var,
                p99: quantile(&pdf, grid.dt, 0.99),
                mass: captured_mass(&pdf, grid.dt),
                pdf,
            }
        }
    }
}

/// [`score_allocation_with`] with every intermediate grid borrowed from
/// `scratch` instead of freshly allocated — the scoring fabric's hot
/// loop ([`crate::compose::fabric::ScoringPool`] workers call this once
/// per candidate, reusing one `Scratch` per worker thread).
///
/// **Bit-identity contract**: the result is bit-for-bit equal to
/// [`score_allocation_with`] on the same inputs. Every `*_into` kernel
/// it leans on performs the exact float ops of its allocating twin in
/// the same order (property-tested per kernel and end-to-end in
/// `tests/fabric_equivalence.rs`).
///
/// After warm-up (one candidate of each grid size), the only per-call
/// heap traffic is the returned [`Score::pdf`] clone and the transient
/// response-law mixture inside `response_dist` — see
/// [`crate::compose::scratch`] for what the allocation counters cover.
pub fn score_allocation_scratch(
    wf: &Workflow,
    alloc: &Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
    scratch: &mut Scratch,
) -> Score {
    match compose_node_scratch(wf.root(), alloc, servers, grid, model, scratch) {
        None => Score::unstable(grid),
        Some((pdf, cdf)) => {
            scratch.put_f64(cdf);
            let (mean, var) = moments(&pdf, grid.dt);
            let score = Score {
                mean,
                var,
                p99: quantile_scratch(&pdf, grid.dt, 0.99, scratch),
                mass: captured_mass(&pdf, grid.dt),
                pdf: pdf.clone(),
            };
            scratch.put_f64(pdf);
            score
        }
    }
}

/// Scratch twin of [`compose_node`]: both returned grids are borrowed
/// from `scratch` and must be handed back by the caller. On the
/// unstable (`None`) path every borrowed buffer is returned before
/// bailing, so the stash stays steady-state across unstable candidates.
fn compose_node_scratch(
    node: &Dcc,
    alloc: &Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
    scratch: &mut Scratch,
) -> Option<(Vec<f64>, Vec<f64>)> {
    match node {
        Dcc::Queue { slot } => {
            let lambda = alloc.rate_for(*slot);
            let service = &servers[alloc.server_for(*slot)].dist;
            match response_dist(model, service, lambda) {
                Response::Unstable => None,
                Response::Stable(d) => {
                    let mut cdf = scratch.take_f64(grid.n);
                    d.cdf_grid_into(grid.dt, &mut cdf);
                    let mut pdf = scratch.take_f64(grid.n);
                    central_diff_into(&cdf, grid.dt, &mut pdf);
                    Some((pdf, cdf))
                }
            }
        }
        Dcc::Serial { children, .. } => {
            let mut acc: Option<Vec<f64>> = None;
            for c in children {
                let Some((pdf, cdf)) =
                    compose_node_scratch(c, alloc, servers, grid, model, scratch)
                else {
                    if let Some(prev) = acc {
                        scratch.put_f64(prev);
                    }
                    return None;
                };
                scratch.put_f64(cdf);
                acc = Some(match acc {
                    None => pdf,
                    Some(prev) => {
                        let mut out = scratch.take_f64(grid.n);
                        conv_auto_into(&prev, &pdf, grid.dt, &mut out, scratch);
                        scratch.put_f64(prev);
                        scratch.put_f64(pdf);
                        out
                    }
                });
            }
            let pdf = acc.expect("serial has children");
            let mut cdf = scratch.take_f64(grid.n);
            cdf_from_pdf_into(&pdf, grid.dt, &mut cdf);
            Some((pdf, cdf))
        }
        Dcc::Parallel { children, .. } => {
            // folding children in order into a 1.0-filled accumulator is
            // exactly max_cdf's internal loop — bit-identical
            assert!(!children.is_empty());
            let mut acc_cdf = scratch.take_f64(grid.n);
            acc_cdf.fill(1.0);
            for c in children {
                let Some((pdf, cdf)) =
                    compose_node_scratch(c, alloc, servers, grid, model, scratch)
                else {
                    scratch.put_f64(acc_cdf);
                    return None;
                };
                max_cdf_fold(&mut acc_cdf, &cdf);
                scratch.put_f64(pdf);
                scratch.put_f64(cdf);
            }
            let mut pdf = scratch.take_f64(grid.n);
            central_diff_into(&acc_cdf, grid.dt, &mut pdf);
            Some((pdf, acc_cdf))
        }
    }
}

/// End-to-end (pdf, cdf) of a subtree; None if any queue is unstable.
fn compose_node(
    node: &Dcc,
    alloc: &Allocation,
    servers: &[Server],
    grid: &GridSpec,
    model: ResponseModel,
) -> Option<(Vec<f64>, Vec<f64>)> {
    match node {
        Dcc::Queue { slot } => {
            let lambda = alloc.rate_for(*slot);
            let service = &servers[alloc.server_for(*slot)].dist;
            match response_dist(model, service, lambda) {
                Response::Unstable => None,
                Response::Stable(d) => {
                    let cdf = d.cdf_grid(grid.dt, grid.n);
                    let pdf = central_diff(&cdf, grid.dt);
                    Some((pdf, cdf))
                }
            }
        }
        Dcc::Serial { children, .. } => {
            let mut acc: Option<Vec<f64>> = None;
            for c in children {
                let (pdf, _) = compose_node(c, alloc, servers, grid, model)?;
                acc = Some(match acc {
                    None => pdf,
                    Some(prev) => conv_auto(&prev, &pdf, grid.dt),
                });
            }
            let pdf = acc.expect("serial has children");
            let cdf = cdf_from_pdf(&pdf, grid.dt);
            Some((pdf, cdf))
        }
        Dcc::Parallel { children, .. } => {
            let mut cdfs = Vec::with_capacity(children.len());
            for c in children {
                let (_, cdf) = compose_node(c, alloc, servers, grid, model)?;
                cdfs.push(cdf);
            }
            let cdf = max_cdf(&cdfs);
            let pdf = central_diff(&cdf, grid.dt);
            Some((pdf, cdf))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::analytic;
    use crate::sched::allocate_with;

    fn fig6_setup() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn fig6_paper_scheme_scores_finite() {
        let (wf, servers) = fig6_setup();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto(&alloc, &servers);
        let s = score_allocation(&wf, &alloc, &servers, &grid);
        assert!(s.is_stable());
        assert!(s.mean > 0.0 && s.var > 0.0 && s.p99 > s.mean);
        assert!(s.mass > 0.95, "grid captured {}", s.mass);
    }

    #[test]
    fn tandem_matches_hypoexponential() {
        // two-queue tandem, ServiceOnly model: conv of two exponentials
        let wf = Workflow::tandem(2, 1.0);
        let servers = Server::pool_exponential(&[2.0, 5.0]);
        let alloc = Allocation::new(vec![0, 1], vec![1.0, 1.0], &wf, 2).unwrap();
        let grid = GridSpec::new(0.01, 2048);
        let s = score_allocation_with(&wf, &alloc, &servers, &grid, ResponseModel::ServiceOnly);
        let cdf = cdf_from_pdf(&s.pdf, grid.dt);
        for k in (0..2048).step_by(173) {
            let want = analytic::hypoexp_cdf(k as f64 * grid.dt, &[2.0, 5.0]);
            assert!((cdf[k] - want).abs() < 5e-3, "k={k}");
        }
        // mean = 1/2 + 1/5
        assert!((s.mean - 0.7).abs() < 0.01, "mean {}", s.mean);
    }

    #[test]
    fn forkjoin_matches_max_law() {
        let wf = Workflow::forkjoin(2, 1.0);
        let servers = Server::pool_exponential(&[3.0, 7.0]);
        let alloc = Allocation::new(vec![0, 1], vec![0.5, 0.5], &wf, 2).unwrap();
        let grid = GridSpec::new(0.005, 2048);
        let s = score_allocation_with(&wf, &alloc, &servers, &grid, ResponseModel::ServiceOnly);
        let cdf = cdf_from_pdf(&s.pdf, grid.dt);
        for k in (8..2048).step_by(191) {
            let want = analytic::max_exp_cdf(k as f64 * grid.dt, &[3.0, 7.0]);
            assert!((cdf[k] - want).abs() < 0.01, "k={k}: {} vs {want}", cdf[k]);
        }
    }

    #[test]
    fn unstable_allocation_scores_infinite() {
        let wf = Workflow::tandem(1, 5.0);
        let servers = Server::pool_exponential(&[2.0]); // mu < lambda
        let alloc = Allocation::new(vec![0], vec![5.0], &wf, 1).unwrap();
        let grid = GridSpec::new(0.01, 1024);
        let s = score_allocation(&wf, &alloc, &servers, &grid);
        assert!(!s.is_stable());
        assert_eq!(s.mean, f64::INFINITY);
    }

    #[test]
    fn unstable_sentinels_are_never_nan() {
        // the sentinel contract: +inf triple, zero mass, both forms
        let grid = GridSpec::new(0.01, 256);
        for s in [Score::unstable(&grid), Score::unstable_point()] {
            assert_eq!(s.mean, f64::INFINITY);
            assert_eq!(s.var, f64::INFINITY);
            assert_eq!(s.p99, f64::INFINITY);
            assert_eq!(s.mass, 0.0);
            assert!(!s.is_stable());
        }
        assert_eq!(Score::unstable(&grid).pdf, vec![0.0; 256]);
        assert!(Score::unstable_point().pdf.is_empty());
    }

    #[test]
    fn nan_scores_count_as_unstable() {
        // a degenerate fitted law must be discarded, not compared
        let s = Score::point(f64::NAN, 1.0, 2.0);
        assert!(!s.is_stable());
    }

    #[test]
    fn scratch_path_is_bit_identical() {
        // fig6 (serial of parallels) under Mm1, a tandem under
        // ServiceOnly, and an unstable candidate — the scratch scorer
        // must agree with the allocating one to the last bit everywhere
        let mut scratch = Scratch::new();
        let (wf, servers) = fig6_setup();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto(&alloc, &servers);
        for model in [ResponseModel::Mm1, ResponseModel::ServiceOnly] {
            let want = score_allocation_with(&wf, &alloc, &servers, &grid, model);
            let got = score_allocation_scratch(&wf, &alloc, &servers, &grid, model, &mut scratch);
            assert_eq!(got.mean.to_bits(), want.mean.to_bits());
            assert_eq!(got.var.to_bits(), want.var.to_bits());
            assert_eq!(got.p99.to_bits(), want.p99.to_bits());
            assert_eq!(got.mass.to_bits(), want.mass.to_bits());
            assert_eq!(got.pdf.len(), want.pdf.len());
            for (x, y) in got.pdf.iter().zip(want.pdf.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // unstable sentinel propagates identically, and the fold that
        // bails mid-serial must hand every borrowed buffer back
        let wf2 = Workflow::tandem(2, 5.0);
        let servers2 = Server::pool_exponential(&[9.0, 2.0]); // 2nd queue diverges
        let alloc2 = Allocation::new(vec![0, 1], vec![5.0, 5.0], &wf2, 2).unwrap();
        let grid2 = GridSpec::new(0.01, 256);
        let s = score_allocation_scratch(
            &wf2,
            &alloc2,
            &servers2,
            &grid2,
            ResponseModel::Mm1,
            &mut scratch,
        );
        assert!(!s.is_stable());
        assert_eq!(s.pdf, vec![0.0; 256]);
        let warm = scratch.buffer_allocs();
        for _ in 0..3 {
            score_allocation_scratch(
                &wf2,
                &alloc2,
                &servers2,
                &grid2,
                ResponseModel::Mm1,
                &mut scratch,
            );
        }
        assert_eq!(scratch.buffer_allocs(), warm, "unstable path must recycle");
    }

    #[test]
    fn mm1_tandem_mean_is_sum_of_sojourns() {
        let wf = Workflow::tandem(2, 1.0);
        let servers = Server::pool_exponential(&[3.0, 4.0]);
        let alloc = Allocation::new(vec![0, 1], vec![1.0, 1.0], &wf, 2).unwrap();
        let grid = GridSpec::new(0.005, 4096);
        let s = score_allocation(&wf, &alloc, &servers, &grid);
        let want = 1.0 / (3.0 - 1.0) + 1.0 / (4.0 - 1.0);
        assert!((s.mean - want).abs() < 0.01, "mean {} want {want}", s.mean);
    }
}
