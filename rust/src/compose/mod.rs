//! Analytic composition engine: turns per-server response-time laws into
//! the workflow's end-to-end response-time distribution.
//!
//! * serial composition  — PDF convolution (paper Eq. 1–2): [`conv`]
//!   (direct, trapezoid-corrected) and an FFT fast path ([`fft`]);
//! * parallel composition — CDF product (paper Eq. 3–4): [`maxcomp`]
//!   (plus min-composition for cloning ablations);
//! * grid bookkeeping — [`grid`]; moments/quantiles — [`moments`];
//! * exponential-family closed forms for validation — [`analytic`];
//! * allocation scoring over a workflow tree — [`score`];
//! * the pluggable scoring seam every predictor sits behind —
//!   [`backend`] ([`backend::ScoreBackend`] with the analytic and
//!   empirical implementations; the PJRT one lives in
//!   [`crate::runtime::scorer`]);
//! * the persistent scoring fabric — [`fabric`] (long-lived worker
//!   pool fed from a chunk queue) and [`scratch`] (the reusable kernel
//!   buffer arena every `*_into` kernel variant borrows from).
//!
//! The numeric conventions (trapezoid cumulative integral, trapezoid
//! endpoint correction in the convolution, central-difference PDF of a
//! CDF) are **identical** to `python/compile/kernels/ref.py`, so the
//! native path and the AOT/PJRT path agree to float tolerance.

pub mod analytic;
pub mod backend;
pub mod conv;
pub mod fabric;
pub mod fft;
pub mod grid;
pub mod maxcomp;
pub mod moments;
pub mod score;
pub mod scratch;
