//! Exponential-family closed forms used to validate the numeric engine
//! (and to regenerate the paper's Fig. 2 analytically).

/// Erlang(n, lam) PDF — the law of n iid Exp(lam) in series (Fig. 2).
pub fn erlang_pdf(t: f64, n: u32, lam: f64) -> f64 {
    if t < 0.0 {
        return 0.0;
    }
    // lam^n t^(n-1) e^(-lam t) / (n-1)!  computed in log space
    let n_f = n as f64;
    let log = n_f * lam.ln() + (n_f - 1.0) * t.max(1e-300).ln() - lam * t - ln_factorial(n - 1);
    log.exp()
}

/// Erlang(n, lam) CDF: `1 - e^(-lam t) * sum_{k<n} (lam t)^k / k!`.
pub fn erlang_cdf(t: f64, n: u32, lam: f64) -> f64 {
    if t <= 0.0 {
        return 0.0;
    }
    let x = lam * t;
    let mut term = 1.0; // (lam t)^0 / 0!
    let mut sum = 1.0;
    for k in 1..n {
        term *= x / k as f64;
        sum += term;
    }
    (1.0 - (-x).exp() * sum).clamp(0.0, 1.0)
}

/// Hypoexponential CDF — series of exponentials with *distinct* rates
/// (generalizes paper Eq. 2): `F(t) = 1 - sum_i C_i e^(-lam_i t)` with
/// `C_i = prod_{j != i} lam_j / (lam_j - lam_i)`.
pub fn hypoexp_cdf(t: f64, lams: &[f64]) -> f64 {
    if t <= 0.0 {
        return 0.0;
    }
    assert!(!lams.is_empty());
    let mut acc = 1.0;
    for (i, &li) in lams.iter().enumerate() {
        let mut c = 1.0;
        for (j, &lj) in lams.iter().enumerate() {
            if i != j {
                assert!(
                    (lj - li).abs() > 1e-12,
                    "hypoexp requires distinct rates (use erlang for ties)"
                );
                c *= lj / (lj - li);
            }
        }
        acc -= c * (-li * t).exp();
    }
    acc.clamp(0.0, 1.0)
}

/// CDF of `max` of independent exponentials (generalizes paper Eq. 4).
pub fn max_exp_cdf(t: f64, lams: &[f64]) -> f64 {
    if t <= 0.0 {
        return 0.0;
    }
    lams.iter().map(|&l| 1.0 - (-l * t).exp()).product()
}

/// Mean of `max` of n iid Exp(lam): `H_n / lam` (harmonic number).
pub fn max_iid_exp_mean(n: u32, lam: f64) -> f64 {
    (1..=n).map(|i| 1.0 / i as f64).sum::<f64>() / lam
}

/// Variance of `max` of n iid Exp(lam): `sum 1/(i lam)^2`.
pub fn max_iid_exp_var(n: u32, lam: f64) -> f64 {
    (1..=n).map(|i| 1.0 / ((i as f64 * lam) * (i as f64 * lam))).sum()
}

/// M/M/1 sojourn (response) time: Exp(mu - lambda) for lambda < mu.
/// Returns the response-time *rate* parameter.
pub fn mm1_response_rate(mu: f64, lambda: f64) -> Option<f64> {
    if lambda >= mu {
        None // unstable queue
    } else {
        Some(mu - lambda)
    }
}

fn ln_factorial(n: u32) -> f64 {
    (2..=n as u64).map(|k| (k as f64).ln()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erlang_pdf_integrates_to_one() {
        let (n, dt) = (40_000, 0.001);
        let mass: f64 = (0..n).map(|k| erlang_pdf(k as f64 * dt, 5, 2.0)).sum::<f64>() * dt;
        assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
    }

    #[test]
    fn erlang_cdf_is_integral_of_pdf() {
        let dt = 0.0005;
        let mut acc = 0.0;
        for k in 0..20_000 {
            acc += erlang_pdf(k as f64 * dt, 3, 1.5) * dt;
        }
        let want = erlang_cdf(20_000.0 * dt, 3, 1.5);
        assert!((acc - want).abs() < 1e-3);
    }

    #[test]
    fn hypoexp_two_rates_matches_eq2() {
        // paper Eq. 2 exactly, lam = (2, 5)
        for t in [0.1, 0.5, 1.0, 2.0] {
            let want = 1.0 - (5.0 / 3.0) * (-2.0f64 * t).exp() + (2.0 / 3.0) * (-5.0f64 * t).exp();
            assert!((hypoexp_cdf(t, &[2.0, 5.0]) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn hypoexp_reduces_to_exponential() {
        for t in [0.2, 1.0, 3.0] {
            assert!((hypoexp_cdf(t, &[2.0]) - (1.0 - (-2.0f64 * t).exp())).abs() < 1e-12);
        }
    }

    #[test]
    fn max_exp_cdf_matches_eq4() {
        for t in [0.1, 0.6, 1.5] {
            let want = (1.0 - (-3.0f64 * t).exp()) * (1.0 - (-7.0f64 * t).exp());
            assert!((max_exp_cdf(t, &[3.0, 7.0]) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn harmonic_mean_of_max() {
        assert!((max_iid_exp_mean(1, 2.0) - 0.5).abs() < 1e-12);
        assert!((max_iid_exp_mean(3, 1.0) - (1.0 + 0.5 + 1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn mm1_stability() {
        assert_eq!(mm1_response_rate(5.0, 2.0), Some(3.0));
        assert_eq!(mm1_response_rate(2.0, 2.0), None);
        assert_eq!(mm1_response_rate(2.0, 3.0), None);
    }
}
