//! Uniform time grids for numeric distribution work.

use crate::dist::ServiceDist;
use crate::flow::Workflow;
use crate::sched::server::Server;
use crate::sched::Allocation;

/// A uniform grid `t_k = k * dt`, `k = 0..n`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GridSpec {
    /// Step size.
    pub dt: f64,
    /// Number of points.
    pub n: usize,
}

impl GridSpec {
    /// Fixed grid.
    pub fn new(dt: f64, n: usize) -> GridSpec {
        assert!(dt > 0.0 && n > 8, "grid needs dt>0 and a few points");
        GridSpec { dt, n }
    }

    /// The canonical AOT grid (matches `python/compile/aot.py: G`).
    pub const AOT_N: usize = 1024;

    /// Auto-size a grid for a workflow + allocation: the end-to-end
    /// support is at most the sum over serial depth of per-branch
    /// high quantiles; pad by 2x for convolution truncation safety.
    pub fn auto(alloc: &Allocation, servers: &[Server]) -> GridSpec {
        let horizon: f64 = alloc
            .assigned_servers()
            .map(|sid| servers[sid].dist.quantile(0.9999))
            .sum::<f64>()
            .max(1e-6)
            * 2.0;
        GridSpec {
            dt: horizon / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// Auto-size from an explicit set of laws (workflow-independent upper
    /// bound: every law could appear in series).
    pub fn auto_for(dists: &[&ServiceDist]) -> GridSpec {
        let horizon: f64 = dists
            .iter()
            .map(|d| d.quantile(0.9999))
            .sum::<f64>()
            .max(1e-6)
            * 2.0;
        GridSpec {
            dt: horizon / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// Auto-size for a whole server pool on a workflow (used before an
    /// allocation exists, e.g. by the optimal exhaustive search).
    pub fn auto_pool(_wf: &Workflow, servers: &[Server]) -> GridSpec {
        let dists: Vec<&ServiceDist> = servers.iter().map(|s| &s.dist).collect();
        Self::auto_for(&dists)
    }

    /// Auto-size from the *response* laws of an allocation under a
    /// queueing model — response tails under load are much longer than
    /// service tails, so p99-style scores need this sizing. Falls back
    /// to [`GridSpec::auto`] if any queue is unstable.
    pub fn auto_response(
        alloc: &crate::sched::Allocation,
        servers: &[Server],
        model: crate::sched::ResponseModel,
    ) -> GridSpec {
        use crate::sched::response::{response_dist, Response};
        let mut horizon = 0.0;
        for slot in 0..alloc.slot_server.len() {
            let service = &servers[alloc.server_for(slot)].dist;
            match response_dist(model, service, alloc.rate_for(slot)) {
                Response::Stable(d) => horizon += d.quantile(0.9999),
                Response::Unstable => return Self::auto(alloc, servers),
            }
        }
        GridSpec {
            dt: (horizon * 1.25).max(1e-6) / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// The largest response-aware grid over several allocations — lets a
    /// comparison score every candidate on a *common* grid.
    pub fn auto_response_common(
        allocs: &[&crate::sched::Allocation],
        servers: &[Server],
        model: crate::sched::ResponseModel,
    ) -> GridSpec {
        allocs
            .iter()
            .map(|a| Self::auto_response(a, servers, model))
            .max_by(|a, b| a.dt.partial_cmp(&b.dt).unwrap())
            .unwrap_or(GridSpec {
                dt: 0.01,
                n: Self::AOT_N,
            })
    }

    /// Grid times.
    pub fn times(&self) -> Vec<f64> {
        (0..self.n).map(|k| k as f64 * self.dt).collect()
    }

    /// Largest representable time.
    pub fn t_max(&self) -> f64 {
        (self.n - 1) as f64 * self.dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_are_uniform() {
        let g = GridSpec::new(0.5, 16);
        let t = g.times();
        assert_eq!(t.len(), 16);
        assert_eq!(t[0], 0.0);
        assert!((t[3] - 1.5).abs() < 1e-12);
        assert!((g.t_max() - 7.5).abs() < 1e-12);
    }

    #[test]
    fn auto_for_covers_tails() {
        let d1 = ServiceDist::exponential(1.0);
        let d2 = ServiceDist::delayed_exponential(0.5, 2.0);
        let g = GridSpec::auto_for(&[&d1, &d2]);
        assert_eq!(g.n, GridSpec::AOT_N);
        // t_max must exceed the sum of the 99.99% quantiles
        assert!(g.t_max() > d1.quantile(0.9999) + d2.quantile(0.9999));
    }

    #[test]
    #[should_panic(expected = "grid needs")]
    fn rejects_degenerate() {
        GridSpec::new(0.0, 100);
    }
}
